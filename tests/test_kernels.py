"""Per-kernel shape/dtype sweeps vs the pure-jnp ref.py oracles
(interpret=True executes the Pallas kernel bodies on CPU)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(7)


def rand(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, jnp.float32).astype(dtype)


def tol(dtype):
    return dict(atol=2e-2, rtol=2e-2) if dtype == jnp.bfloat16 \
        else dict(atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------- flash attn

@pytest.mark.parametrize("B,Sq,Sk,H,KVH,D", [
    (1, 64, 64, 4, 4, 32),       # MHA, square
    (2, 128, 128, 8, 2, 64),     # GQA 4:1
    (1, 96, 200, 4, 1, 64),      # MQA, ragged kv
    (2, 1, 160, 8, 4, 128),      # decode-style single query
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(B, Sq, Sk, H, KVH, D, dtype):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (B, Sq, H, D), dtype)
    k = rand(k2, (B, Sk, KVH, D), dtype)
    v = rand(k3, (B, Sk, KVH, D), dtype)
    off = Sk - Sq
    out = ops.flash_attention(q, k, v, causal=True, q_offset=off,
                              block_q=64, block_k=64, interpret=True)
    exp = ref.ref_attention(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **tol(dtype))


@pytest.mark.parametrize("window", [16, 64])
def test_flash_attention_window(window):
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (2, 128, 4, 32))
    k = rand(k2, (2, 128, 2, 32))
    v = rand(k3, (2, 128, 2, 32))
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=32, block_k=32, interpret=True)
    exp = ref.ref_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=3e-5)


def test_flash_attention_noncausal():
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (1, 48, 4, 64))
    k = rand(k2, (1, 72, 4, 64))
    v = rand(k3, (1, 72, 4, 64))
    out = ops.flash_attention(q, k, v, causal=False, block_q=16,
                              block_k=24, interpret=True)
    exp = ref.ref_attention(q, k, v, causal=False)
    np.testing.assert_allclose(out, exp, atol=3e-5, rtol=3e-5)


def test_flash_matches_chunked_jnp_path():
    """The model's default chunked-jnp attention and the Pallas kernel are
    interchangeable implementations of the same contract."""
    from repro.models.common import chunked_attention
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (2, 100, 8, 64))
    k = rand(k2, (2, 100, 4, 64))
    v = rand(k3, (2, 100, 4, 64))
    a = ops.flash_attention(q, k, v, causal=True, block_q=32, block_k=32,
                            interpret=True)
    b = chunked_attention(q, k, v, causal=True, q_block=64, kv_block=64)
    np.testing.assert_allclose(a, b, atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------- decode attention

@pytest.mark.parametrize("B,H,KVH,D,S,block", [
    (2, 4, 4, 32, 128, 32),      # MHA
    (3, 8, 2, 64, 300, 64),      # GQA, ragged cache
    (1, 4, 1, 128, 1024, 256),   # MQA, long cache
])
def test_decode_attention_kernel(B, H, KVH, D, S, block):
    from repro.models.common import decode_attention as jnp_decode
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    q = rand(k1, (B, 1, H, D))
    kc = rand(k2, (B, S, KVH, D))
    vc = rand(k3, (B, S, KVH, D))
    lengths = jax.random.randint(k4, (B,), 1, S + 1)
    out = ops.decode_attention(q, kc, vc, lengths, block_s=block,
                               interpret=True)
    exp = jnp_decode(q, kc, vc, lengths)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=3e-5, rtol=3e-5)


def test_decode_attention_kernel_window():
    from repro.models.common import decode_attention as jnp_decode
    k1, k2, k3 = jax.random.split(KEY, 3)
    q = rand(k1, (2, 1, 4, 64))
    kc = rand(k2, (2, 256, 2, 64))
    vc = rand(k3, (2, 256, 2, 64))
    lengths = jnp.array([256, 100], jnp.int32)
    out = ops.decode_attention(q, kc, vc, lengths, window=64, block_s=64,
                               interpret=True)
    exp = jnp_decode(q, kc, vc, lengths, window=64)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=3e-5, rtol=3e-5)


# ---------------------------------------------------------------- RG-LRU

@pytest.mark.parametrize("B,S,W", [(1, 64, 128), (2, 100, 96), (3, 17, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_shapes(B, S, W, dtype):
    k1, k2 = jax.random.split(KEY)
    a = jax.nn.sigmoid(rand(k1, (B, S, W))).astype(dtype)
    b = rand(k2, (B, S, W), dtype)
    out = ops.rglru(a, b, block_s=32, block_w=64, interpret=True)
    exp = ref.ref_rglru(a, b)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(exp, np.float32), **tol(dtype))


def test_rglru_matches_associative_scan():
    from jax import lax
    k1, k2 = jax.random.split(KEY)
    a = jax.nn.sigmoid(rand(k1, (2, 64, 128)))
    b = rand(k2, (2, 64, 128))
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2
    _, exp = lax.associative_scan(combine, (a, b), axis=1)
    out = ops.rglru(a, b, interpret=True)
    np.testing.assert_allclose(out, exp, atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------- SSD

@pytest.mark.parametrize("B,S,H,P,N,chunk", [
    (1, 64, 2, 16, 16, 16),
    (2, 70, 4, 32, 64, 32),      # ragged
    (1, 256, 2, 64, 128, 128),   # production-ish tile
])
def test_ssd_shapes(B, S, H, P, N, chunk):
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    x = rand(k1, (B, S, H, P))
    a = -jax.nn.softplus(rand(k2, (B, S, H)))
    Bm = rand(k3, (B, S, H, N))
    Cm = rand(k4, (B, S, H, N))
    y, st = ops.ssd(x, a, Bm, Cm, chunk=chunk, interpret=True)
    ye, ste = ref.ref_ssd(x, a, Bm, Cm)
    np.testing.assert_allclose(y, ye, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(st, ste, atol=5e-4, rtol=5e-4)


def test_ssd_matches_model_chunked_scan():
    from repro.models.ssm import ssd_scan
    k1, k2, k3, k4 = jax.random.split(KEY, 4)
    B, S, H, P, N = 2, 96, 2, 16, 32
    x = rand(k1, (B, S, H, P))
    a = -jax.nn.softplus(rand(k2, (B, S, H)))
    Bm = rand(k3, (B, S, H, N))
    Cm = rand(k4, (B, S, H, N))
    y1, s1 = ops.ssd(x, a, Bm, Cm, chunk=32, interpret=True)
    y2, s2 = ssd_scan(x, a, Bm, Cm, chunk=32)
    np.testing.assert_allclose(y1, y2, atol=5e-4, rtol=5e-4)
    np.testing.assert_allclose(s1, s2, atol=5e-4, rtol=5e-4)
