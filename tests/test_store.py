"""Intra-endpoint stores (paper §5.2): in-memory KV (Redis analogue),
shared-FS, device store."""
import threading

import numpy as np
import pytest

from repro.data import DeviceStore, InMemoryKVStore, SharedFSStore


@pytest.fixture(params=["memory", "sharedfs", "device"])
def store(request, tmp_path):
    if request.param == "memory":
        return InMemoryKVStore()
    if request.param == "sharedfs":
        return SharedFSStore(str(tmp_path / "fs"))
    return DeviceStore()


def test_set_get_delete(store):
    store.set("k", {"x": np.arange(4), "n": 3})
    out = store.get("k")
    np.testing.assert_array_equal(np.asarray(out["x"]), np.arange(4))
    assert out["n"] == 3
    assert store.exists("k")
    store.delete("k")
    assert not store.exists("k")


def test_mset_mget(store):
    store.mset({f"k{i}": i for i in range(5)})
    assert store.mget([f"k{i}" for i in range(5)]) == list(range(5))


def test_missing_key_raises(store):
    with pytest.raises(Exception):
        store.get("nope")


def test_concurrent_access(store):
    errs = []
    def writer(i):
        try:
            for j in range(50):
                store.set(f"w{i}/{j}", j)
        except Exception as e:      # pragma: no cover
            errs.append(e)
    ts = [threading.Thread(target=writer, args=(i,)) for i in range(8)]
    [t.start() for t in ts]
    [t.join() for t in ts]
    assert not errs
    assert store.get("w3/49") == 49


def test_memory_lru_eviction():
    s = InMemoryKVStore(max_bytes=5000)
    for i in range(50):
        s.set(f"k{i}", np.zeros(100, np.uint8))
    assert s.nbytes <= 5000
    assert not s.exists("k0")           # oldest evicted
    assert s.exists("k49")


def test_memory_ttl():
    import time
    s = InMemoryKVStore(default_ttl=0.05)
    s.set("k", 1)
    assert s.get("k") == 1
    time.sleep(0.08)
    with pytest.raises(KeyError):
        s.get("k")


def test_sharedfs_atomic_overwrite(tmp_path):
    s = SharedFSStore(str(tmp_path / "fs"))
    s.set("k", "v1")
    s.set("k", "v2")                     # replace must be atomic
    assert s.get("k") == "v2"


def test_stats_accounting():
    s = InMemoryKVStore()
    s.set("a", np.zeros(1000))
    s.get("a")
    assert s.stats.sets == 1 and s.stats.gets == 1
    assert s.stats.bytes_in > 1000      # includes envelope
    assert s.stats.bytes_out == s.stats.bytes_in


def test_device_store_zero_copy():
    import jax.numpy as jnp
    s = DeviceStore()
    arr = jnp.arange(8)
    s.set("x", arr)
    assert s.get("x") is arr            # by reference, no copy


def test_raw_path_stats_count_once(store):
    """The raw (wire-plane) variants account exactly once with real byte
    totals — the PR 2 fast path used to double-dip the object-layer
    counters via delegation (and DeviceStore attached zero bytes)."""
    from repro.serialization import pack
    frame = bytes(pack("hello", tag="k"))
    store.set_raw("k", frame)
    store.get_raw("k")
    snap = store.stats_snapshot()
    assert snap["sets"] == 1 and snap["gets"] == 1
    assert snap["bytes_in"] == len(frame)
    assert snap["bytes_out"] >= len(frame) - 64   # device re-packs


def test_device_store_set_raw_decodes_to_live_object():
    """A wire frame landed via set_raw surfaces as the decoded object on
    get() — not headered bytes (the old delegation bug)."""
    from repro.serialization import pack
    s = DeviceStore()
    s.set_raw("k", bytes(pack({"x": 3}, tag="k")))
    assert s.get("k") == {"x": 3}
    s.set_raw("opaque", b"not a frame")           # non-pack payloads kept
    assert s.get("opaque") == b"not a frame"


def test_inventory_version_stamps(store):
    """inventory() is version-stamped: every mutation moves the version,
    reads don't; keys/nbytes track live contents."""
    inv0 = store.inventory()
    store.set("a", b"x" * 100)
    inv1 = store.inventory()
    assert inv1.version > inv0.version
    assert inv1.keys == 1 and inv1.nbytes > 0
    store.get("a")
    assert store.inventory().version == inv1.version
    store.delete("a")
    inv2 = store.inventory()
    assert inv2.version > inv1.version
    assert inv2.keys == 0 and inv2.nbytes == 0


def test_sharedfs_live_bytes_track_overwrite(tmp_path):
    s = SharedFSStore(str(tmp_path / "fs"))
    s.set_raw("k", b"x" * 1000)
    assert s.inventory().nbytes == 1000
    s.set_raw("k", b"y" * 200)            # replace, not accumulate
    inv = s.inventory()
    assert inv.keys == 1 and inv.nbytes == 200
