"""IAM layer (paper §4.7): scoped tokens, delegation, revocation."""
import time

import pytest

from repro.core import AuthService, SCOPE_REGISTER_FUNCTION, SCOPE_RUN
from repro.core.errors import AuthError


@pytest.fixture
def auth():
    a = AuthService(ttl=10.0)
    a.register_identity("alice")
    return a


def test_issue_and_validate(auth):
    tok = auth.issue("alice", [SCOPE_RUN])
    assert auth.validate(tok, SCOPE_RUN) == "alice"


def test_missing_scope_rejected(auth):
    tok = auth.issue("alice", [SCOPE_RUN])
    with pytest.raises(AuthError, match="missing scope"):
        auth.validate(tok, SCOPE_REGISTER_FUNCTION)


def test_unknown_identity_rejected(auth):
    with pytest.raises(AuthError):
        auth.issue("mallory", [SCOPE_RUN])


def test_tampered_token_rejected(auth):
    import dataclasses
    tok = auth.issue("alice", [SCOPE_RUN])
    forged = dataclasses.replace(tok, identity="mallory")
    with pytest.raises(AuthError, match="bad signature"):
        auth.validate(forged, SCOPE_RUN)


def test_expiry():
    a = AuthService(ttl=0.05)
    a.register_identity("alice")
    tok = a.issue("alice", [SCOPE_RUN])
    time.sleep(0.1)
    with pytest.raises(AuthError, match="expired"):
        a.validate(tok, SCOPE_RUN)


def test_delegation_narrows_scopes(auth):
    tok = auth.issue("alice", [SCOPE_RUN, SCOPE_REGISTER_FUNCTION])
    d = auth.delegate(tok, "bob", [SCOPE_RUN])
    assert auth.validate(d, SCOPE_RUN) == "bob"
    assert d.issued_by == "alice"
    with pytest.raises(AuthError):
        auth.delegate(tok, "eve", [SCOPE_RUN, "urn:repro:auth:scope:endpoint"])


def test_revocation(auth):
    tok = auth.issue("alice", [SCOPE_RUN])
    auth.revoke(tok)
    with pytest.raises(AuthError, match="revoked"):
        auth.validate(tok, SCOPE_RUN)
