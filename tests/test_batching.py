"""Batching (paper §4.6 + beyond-paper request coalescing)."""
import threading
import time

import numpy as np
import pytest

from repro.core import DynamicBatcher, split_arrays, stack_arrays


def test_stack_and_split_roundtrip():
    payloads = [{"tokens": np.ones((2, 4)), "n": 3},
                {"tokens": np.zeros((1, 4)), "n": 3}]
    stacked = stack_arrays(payloads)
    assert stacked["tokens"].shape == (3, 4)
    assert stacked["n"] == 3
    parts = split_arrays({"out": np.arange(3)}, [2, 1])
    np.testing.assert_array_equal(parts[0]["out"], [0, 1])
    np.testing.assert_array_equal(parts[1]["out"], [2])


def test_stack_rejects_mismatched_scalars():
    with pytest.raises(ValueError, match="scalar field"):
        stack_arrays([{"x": np.ones((1, 2)), "n": 3},
                      {"x": np.ones((1, 2)), "n": 4}])


def test_dynamic_batcher_coalesces():
    calls = []
    lock = threading.Lock()

    def submit(payload):
        with lock:
            calls.append(payload)
        return f"task-{len(calls)}"

    def result(task_id, timeout):
        # model: double the tokens
        idx = int(task_id.split("-")[1]) - 1
        return {"tokens": np.asarray(calls[idx]["tokens"]) * 2}

    b = DynamicBatcher(submit, result, max_batch=4, max_wait=0.05)
    futs = [b.submit({"tokens": np.full((1, 3), i)}) for i in range(8)]
    outs = [f.result(timeout=10) for f in futs]
    for i, o in enumerate(outs):
        np.testing.assert_array_equal(o["tokens"], np.full((1, 3), 2 * i))
    assert b.batches_sent <= 4            # ≥2 requests per batch on average
    assert b.requests_sent == 8
    b.close()


def test_dynamic_batcher_propagates_errors():
    def submit(payload):
        raise RuntimeError("endpoint down")
    b = DynamicBatcher(submit, lambda *a: None, max_batch=2, max_wait=0.01)
    fut = b.submit({"tokens": np.ones((1, 2))})
    with pytest.raises(RuntimeError, match="endpoint down"):
        fut.result(timeout=5)
    b.close()


def test_internal_batching_amortizes_rtt(service):
    """Paper §7.5 in miniature: per-message RTT is amortized by forwarder
    batch dispatch."""
    from repro.core import FuncXClient, FuncXService
    results = {}
    for batch_size in (1, 32):
        svc = FuncXService(heartbeat_timeout=0.5, forwarder_batch=batch_size)
        tok = svc.register_user("u")
        cl = FuncXClient(svc, tok)
        fid = cl.register_function(lambda d: 0)
        eid, agent = svc.make_endpoint(tok, "ep", n_managers=1,
                                       workers_per_manager=4)
        svc.endpoints[eid].forwarder.send_rtt = 0.005    # 5 ms per message
        ids = cl.batch_run([(fid, eid, {}) for _ in range(64)])
        t0 = time.perf_counter()
        cl.get_batch_results(ids, timeout=60)
        results[batch_size] = time.perf_counter() - t0
        agent.stop()
        svc.shutdown()
    # batched dispatch must be several times faster
    assert results[32] * 3 < results[1], results
