"""Property-based tests (hypothesis) over the system's invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # not in all images
from hypothesis import given, settings, strategies as st

from repro.kernels import ref
from repro.models.common import chunked_attention, cross_entropy


# ---------------------------------------------------------------- attention

@settings(max_examples=15, deadline=None)
@given(
    b=st.integers(1, 2),
    sq=st.integers(1, 33),
    extra_k=st.integers(0, 17),
    kvh=st.sampled_from([1, 2]),
    g=st.sampled_from([1, 2, 4]),
    qb=st.sampled_from([4, 8, 16]),
    kb=st.sampled_from([4, 8, 16]),
)
def test_chunked_attention_equals_reference(b, sq, extra_k, kvh, g, qb, kb):
    """The memory-bounded chunked attention must equal naive attention for
    ANY shape/blocking combination (incl. ragged, GQA, offsets)."""
    d = 8
    sk = sq + extra_k
    key = jax.random.PRNGKey(b * 1000 + sq * 31 + extra_k)
    k1, k2, k3 = jax.random.split(key, 3)
    q = jax.random.normal(k1, (b, sq, kvh * g, d))
    k = jax.random.normal(k2, (b, sk, kvh, d))
    v = jax.random.normal(k3, (b, sk, kvh, d))
    off = sk - sq
    out = chunked_attention(q, k, v, causal=True, q_offset=off,
                            q_block=qb, kv_block=kb)
    exp = ref.ref_attention(q, k, v, causal=True, q_offset=off)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               atol=1e-4, rtol=1e-4)


# ------------------------------------------------------------------- loss

@settings(max_examples=20, deadline=None)
@given(st.integers(0, 6), st.floats(-3, 3), st.floats(0.5, 4.0))
def test_cross_entropy_decreases_with_gold_logit(gold, base, bump):
    """Raising the gold-class logit must never increase the loss."""
    logits = jnp.full((1, 1, 8), base, jnp.float32)
    labels = jnp.array([[gold]], jnp.int32)
    lo = cross_entropy(logits, labels)
    hi = cross_entropy(logits.at[0, 0, gold].add(bump), labels)
    assert float(hi) <= float(lo) + 1e-6


def test_cross_entropy_uniform_is_log_v():
    logits = jnp.zeros((2, 3, 16), jnp.float32)
    labels = jnp.zeros((2, 3), jnp.int32)
    assert float(cross_entropy(logits, labels)) == pytest.approx(
        np.log(16), rel=1e-5)


# ----------------------------------------------------------------- stores

@settings(max_examples=25, deadline=None)
@given(st.lists(
    st.tuples(st.sampled_from("abcd"),
              st.sampled_from(["set", "get", "delete"]),
              st.integers(0, 100)),
    max_size=24))
def test_store_sequence_semantics(ops):
    """The in-memory KV store behaves as a dict under any op sequence."""
    from repro.data import InMemoryKVStore
    store = InMemoryKVStore()
    shadow = {}
    for key, op, val in ops:
        if op == "set":
            store.set(key, val)
            shadow[key] = val
        elif op == "get":
            if key in shadow:
                assert store.get(key) == shadow[key]
            else:
                with pytest.raises(KeyError):
                    store.get(key)
        else:
            store.delete(key)
            shadow.pop(key, None)
    assert sorted(store.keys()) == sorted(shadow.keys())


# -------------------------------------------------------------- scheduling

@settings(max_examples=25, deadline=None)
@given(st.integers(0, 2000))
def test_lr_schedule_bounds(step):
    from repro.configs import TrainConfig
    from repro.train import lr_schedule
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=100, total_steps=1000)
    lr = float(lr_schedule(tc, jnp.int32(step)))
    assert 0.0 <= lr <= 1e-3 + 1e-9
    if step >= tc.total_steps:
        assert lr <= 1e-4 * 1.01 + 1e-9      # decayed to the floor


# -------------------------------------------------------------- task model

def test_latency_breakdown_sums_to_total(service, client):
    svc_local = service
    fid = client.register_function(lambda d: None)
    import repro.core.service as S
    svc2 = S.FuncXService(heartbeat_timeout=0.3, purge_on_get=False)
    try:
        tok = svc2.register_user("u")
        from repro.core import FuncXClient
        cl = FuncXClient(svc2, tok)
        f2 = cl.register_function(lambda d: None)
        eid, agent = svc2.make_endpoint(tok, "ep", n_managers=1)
        for _ in range(5):
            tid = cl.run(f2, eid, data={})
            cl.get_result(tid, timeout=10)
            bd = cl.task(tid).latency_breakdown()
            parts = bd["t_s"] + bd["t_f"] + bd["t_e"] + bd["t_w"] + bd["t_r"]
            assert parts == pytest.approx(bd["total"], rel=0.05)
        agent.stop()
    finally:
        svc2.shutdown()


# ---------------------------------------------------------------- sharding

@settings(max_examples=30, deadline=None)
@given(st.integers(1, 4096), st.sampled_from([2, 4, 8, 16]))
def test_spec_for_divisibility_invariant(dim, axis_size):
    """spec_for never produces a spec whose mesh product doesn't divide
    the dim."""
    import numpy as np
    from jax.sharding import Mesh
    from repro.sharding import default_rules, spec_for
    devs = np.array(jax.devices() * (axis_size * 2))[:axis_size * 2]
    mesh = Mesh(devs.reshape(axis_size, 2), ("data", "model"))
    spec = spec_for(("embed", "ffn"), (dim, dim), mesh, default_rules())
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for entry, d in zip(tuple(spec) + (None,) * 2, (dim, dim)):
        if entry is None:
            continue
        axes = entry if isinstance(entry, tuple) else (entry,)
        prod = 1
        for a in axes:
            prod *= sizes[a]
        assert d % prod == 0
