"""Routing API redesign (DESIGN.md §10): RoutingContext semantics,
jit-vs-container warmth ordering at both tiers, select_many snapshot
feedback, WarmthView builders, and the observe_build feedback path
(agent EWMA → heartbeat → service router). The PR 9 one-PR legacy
shims (string coercion, ``make_endpoint_router``) are gone — pinned
below.
"""
import threading
import types

import pytest

from repro.core import (
    CostAwareRouter,
    EndpointInfo,
    ManagerInfo,
    RoutingContext,
    WarmingAwareEndpointRouter,
    WarmingAwareRouter,
    WarmthView,
    make_router,
)


def mi(mid, idle=2, queued=0, warm_idle=None, warm_total=None, cap=4):
    return ManagerInfo(mid, idle, queued, warm_idle or {},
                       warm_total or dict(warm_idle or {}), cap)


def ei(eid, warm_idle=None, warm_total=None, cap=4, queued=0, idle=2):
    return EndpointInfo(eid, service_queue=0, in_flight=0, queued=queued,
                        idle_workers=idle, capacity=cap,
                        warm_idle=warm_idle or {},
                        warm_total=warm_total or dict(warm_idle or {}))


# ---------------------------------------------------------------------------
# RoutingContext semantics
# ---------------------------------------------------------------------------

def test_ctx_key_defaults_to_container_type():
    ctx = RoutingContext(container_type="T")
    assert ctx.key == "T"
    assert ctx.warmth_keys == ("T",)


def test_ctx_explicit_warmth_key_keeps_container_fallback():
    ctx = RoutingContext(warmth_key="jit/m/gen/b16", container_type="T")
    assert ctx.key == "jit/m/gen/b16"
    assert ctx.warmth_keys == ("jit/m/gen/b16", "T")
    # degenerate refinement: no duplicate fallback
    same = RoutingContext(warmth_key="T", container_type="T")
    assert same.warmth_keys == ("T",)


# ---------------------------------------------------------------------------
# The PR 9 legacy shims stayed for exactly one PR — pin their removal so
# they don't creep back
# ---------------------------------------------------------------------------

def test_legacy_shims_are_gone():
    import repro.core
    assert not hasattr(RoutingContext, "coerce")
    assert not hasattr(repro.core, "make_endpoint_router")


def test_make_router_rejects_unknown_names_and_tiers():
    with pytest.raises(KeyError, match="unknown manager-tier router"):
        make_router("nope")
    with pytest.raises(KeyError, match="unknown routing tier"):
        make_router("random", tier="nope")


# ---------------------------------------------------------------------------
# jit warmth vs container warmth: primary key wins, container type is the
# fallback, cold is last — at both tiers
# ---------------------------------------------------------------------------

JIT = "jit/qwen1.5-0.5b/generate/b16"


def test_manager_tier_jit_warm_beats_container_warm():
    ctx = RoutingContext(warmth_key=JIT, container_type="T")
    managers = [mi("container-warm", warm_idle={"T": 3}),
                mi("jit-warm", warm_idle={JIT: 1, "T": 1})]
    assert WarmingAwareRouter().route(ctx, managers) == "jit-warm"


def test_manager_tier_container_warm_fallback_when_jit_cold():
    ctx = RoutingContext(warmth_key=JIT, container_type="T")
    managers = [mi("cold"), mi("container-warm", warm_idle={"T": 1})]
    assert WarmingAwareRouter().route(ctx, managers) == "container-warm"


def test_endpoint_tier_jit_warm_beats_container_warm():
    ctx = RoutingContext(warmth_key=JIT, container_type="T")
    eps = [ei("container-warm", warm_idle={"T": 3}),
           ei("jit-warm", warm_idle={JIT: 1, "T": 1})]
    assert WarmingAwareEndpointRouter().select(ctx, eps) == "jit-warm"


def test_endpoint_tier_warm_busy_beats_cold():
    ctx = RoutingContext(warmth_key=JIT, container_type="T")
    eps = [ei("cold"),
           ei("busy-warm", warm_idle={}, warm_total={JIT: 1}, queued=2)]
    assert WarmingAwareEndpointRouter().select(ctx, eps) == "busy-warm"


# ---------------------------------------------------------------------------
# select_many: per-pick snapshot feedback
# ---------------------------------------------------------------------------

def test_select_many_feedback_spreads_over_warm_endpoints():
    eps = [ei("a", warm_idle={"T": 1}), ei("b", warm_idle={"T": 1})]
    picks = WarmingAwareEndpointRouter().select_many(
        RoutingContext(container_type="T"), eps, 2)
    assert sorted(picks) == ["a", "b"]
    assert all(e.service_queue == 1 for e in eps)
    assert all(e.warmth.warm_idle("T") == 0 for e in eps)


def test_select_many_mixed_keys_share_one_snapshot():
    # endpoint "a" holds both artifacts warm; picking for one key must
    # leave the snapshot consistent for the next key's routing
    eps = [ei("a", warm_idle={JIT: 1, "T": 1}), ei("b")]
    r = WarmingAwareEndpointRouter()
    jit_picks = r.select_many(RoutingContext(warmth_key=JIT,
                                             container_type="T"), eps, 1)
    ct_picks = r.select_many(RoutingContext(container_type="T"), eps, 1)
    assert jit_picks == ["a"]
    assert ct_picks == ["a"]          # still container-warm, despite queue
    a = eps[0]
    assert a.service_queue == 2
    assert a.warmth.warm_idle(JIT) == 0      # consumed by the jit pick
    assert a.warmth.warm_idle("T") == 0      # consumed by the ct pick


def test_note_pick_accepts_ctx_or_str():
    e = ei("a", warm_idle={"T": 2})
    e.note_pick("T")
    e.note_pick(RoutingContext(container_type="T"))
    assert e.warmth.warm_idle("T") == 0 and e.service_queue == 2


# ---------------------------------------------------------------------------
# WarmthView: the one heartbeat-dict parsing point
# ---------------------------------------------------------------------------

def test_warmth_view_tally_and_merge():
    # manager scan: one idle worker warm on T, one busy worker warm on
    # T + a jit key
    v = WarmthView.tally([(["T"], True), (["T", JIT], False)])
    assert v.warm_idle("T") == 1 and v.warm_total("T") == 2
    assert v.warm_idle(JIT) == 0 and v.warm_total(JIT) == 1

    merged = WarmthView.merge([v, WarmthView({"T": 2}, {"T": 2})])
    assert merged.warm_idle("T") == 3 and merged.warm_total("T") == 4
    assert merged.warm_total(JIT) == 1


def test_warmth_view_is_warm_uses_fallback_keys():
    v = WarmthView({}, {"T": 1})
    assert v.is_warm(RoutingContext(warmth_key=JIT, container_type="T"))
    assert not v.is_warm(RoutingContext(warmth_key=JIT, container_type="X"))


def test_warmth_view_writes_through_to_snapshot_dicts():
    info = ei("a", warm_idle={"T": 1})
    info.warmth.note_pick("T")
    assert info.warm_idle["T"] == 0    # the snapshot dict itself changed


# ---------------------------------------------------------------------------
# observe_build feedback (DESIGN.md §10): measured cold-build costs flow
# agent → router, and heartbeat build_costs → service federation router
# ---------------------------------------------------------------------------

def test_cost_aware_observe_build_ewma():
    r = CostAwareRouter(default_cold_cost=9.0)
    assert r.cold_cost("k") == 9.0
    r.observe_build("k", 1.0)
    assert r.cold_cost("k") == pytest.approx(1.0)
    r.observe_build("k", 2.0)
    assert r.cold_cost("k") == pytest.approx(0.8 * 1.0 + 0.2 * 2.0)


def test_cost_aware_prefers_warm_once_builds_are_expensive():
    r = CostAwareRouter(default_cold_cost=0.0)
    r.observe_build(JIT, 5.0)
    ctx = RoutingContext(warmth_key=JIT, container_type="T")
    managers = [mi("cold", queued=0), mi("warm", warm_idle={JIT: 1},
                                         queued=2)]
    assert r.route(ctx, managers) == "warm"


def test_agent_observe_build_feeds_router_and_heartbeat_ewma():
    from repro.core.endpoint import EndpointAgent

    fake = types.SimpleNamespace(router=CostAwareRouter(),
                                 _build_costs={},
                                 _build_costs_lock=threading.Lock())
    EndpointAgent._observe_build(fake, JIT, 1.0)
    EndpointAgent._observe_build(fake, JIT, 2.0)
    assert fake.router.cold_cost(JIT) == pytest.approx(1.2)
    assert fake._build_costs[JIT] == pytest.approx(1.2)


def test_service_feeds_build_costs_to_endpoint_router():
    from repro.core import FuncXService

    class CostObservingEndpointRouter(WarmingAwareEndpointRouter):
        def __init__(self):
            super().__init__()
            self.seen = {}

        def observe_build(self, warmth_key, seconds):
            self.seen[warmth_key] = seconds

    router = CostObservingEndpointRouter()
    svc = FuncXService(endpoint_router=router)
    try:
        assert svc.pool.on_build_costs is not None
        svc.pool.on_build_costs({JIT: 2.5})
        assert router.seen == {JIT: 2.5}
    finally:
        svc.shutdown()
