"""Strong correctness tests: incremental decoding (prefill + decode_step
token by token) must reproduce the teacher-forced forward logits, for every
architecture family."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_reduced_config
from repro.models import get_model
from repro.models.knobs import RunKnobs

KEY = jax.random.PRNGKey(3)
KNOBS = RunKnobs(q_block=16, kv_block=16)


def _last_logits_full(model, params, batch):
    """Teacher-forced full forward; return last-position logits."""
    logits, _ = model.prefill(params, batch, knobs=KNOBS)
    return logits


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_incremental_decode_matches_prefill(arch):
    cfg = get_reduced_config(arch)
    model = get_model(cfg)
    params = model.init(KEY)
    B, S = 2, 24
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size, jnp.int32)

    def make_batch(t):
        b = {"tokens": t}
        if cfg.family == "audio":
            b["frames"] = jax.random.normal(
                KEY, (B, 8, cfg.d_model), jnp.float32).astype(jnp.bfloat16)
        if cfg.family == "vlm":
            b["patches"] = jax.random.normal(
                KEY, (B, cfg.vlm.vision_prefix_len, cfg.d_model),
                jnp.float32).astype(jnp.bfloat16)
        return b

    # reference: prefill over the full prefix
    ref_logits = _last_logits_full(model, params, make_batch(toks))

    # incremental: prefill S//2, then decode the rest token by token
    half = S // 2
    # VLM caches must cover the vision prefix slots too
    prefix = cfg.vlm.vision_prefix_len if cfg.family == "vlm" else 0
    logits, cache = model.prefill(params, make_batch(toks[:, :half]),
                                  knobs=KNOBS, cache_len=S + prefix)
    for i in range(half, S):
        logits, cache = model.decode_step(
            params, cache, {"tokens": toks[:, i:i + 1]}, knobs=KNOBS)
    # atol must absorb CPU-thread reduction-order jitter on top of the
    # bf16 path: mamba2's chunked scan occasionally lands a lone logit
    # ~0.06 off the teacher-forced value (a real cache bug skews the
    # whole row, not 1/512 elements)
    np.testing.assert_allclose(
        np.asarray(logits, np.float32), np.asarray(ref_logits, np.float32),
        atol=0.08, rtol=0.05)


def test_mla_absorbed_decode_matches_reconstructed():
    """MiniCPM3's absorbed-latent decode == full-reconstruction attention."""
    cfg = get_reduced_config("minicpm3-4b")
    model = get_model(cfg)
    params = model.init(KEY)
    B, S = 2, 16
    toks = jax.random.randint(KEY, (B, S), 0, cfg.vocab_size, jnp.int32)
    ref_logits = _last_logits_full(model, params, {"tokens": toks})
    logits, cache = model.prefill(params, {"tokens": toks[:, :S - 1]},
                                  knobs=KNOBS, cache_len=S)
    logits, cache = model.decode_step(params, cache,
                                      {"tokens": toks[:, S - 1:]},
                                      knobs=KNOBS)
    np.testing.assert_allclose(np.asarray(logits, np.float32),
                               np.asarray(ref_logits, np.float32),
                               atol=0.05, rtol=0.05)


def test_local_attention_window_respected():
    """RecurrentGemma local attention must ignore tokens beyond the window:
    perturbing a token outside the window leaves logits unchanged... within
    recurrent-state influence (so we test the attention block in isolation)."""
    from repro.models.common import chunked_attention
    q = jax.random.normal(KEY, (1, 64, 2, 16))
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (1, 64, 1, 16))
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (1, 64, 1, 16))
    out1 = chunked_attention(q, k, v, causal=True, window=8,
                             q_block=16, kv_block=16)
    # perturb k/v well outside any query's window
    k2 = k.at[:, 0].set(100.0)
    v2 = v.at[:, 0].set(-100.0)
    out2 = chunked_attention(q, k2, v2, causal=True, window=8,
                             q_block=16, kv_block=16)
    np.testing.assert_allclose(out1[:, 16:], out2[:, 16:], atol=1e-6)
