"""Pack-once data plane (DESIGN.md §5): facade fast paths, PackedBuffer,
opaque protocol frames, and the one-pack/one-decode invariant on the live
service → endpoint → worker → result path.

Unlike test_serialization.py this module is NOT hypothesis-gated — it is
the facade's baseline coverage in minimal images."""
import time

import numpy as np
import pytest

from repro.serialization import (
    PackedBuffer,
    SerializationError,
    clear_method_cache,
    pack,
    pack_buffer,
    peek_tag,
    stats,
    unpack,
    unpack_full,
)


@pytest.fixture(autouse=True)
def _fresh_dispatch_cache():
    clear_method_cache()
    yield
    clear_method_cache()


# ---------------------------------------------------------------------------
# facade coverage (satellite: zstd, bf16, peek_tag, method-cache fallback)
# ---------------------------------------------------------------------------

def test_roundtrip_plain_not_gated():
    for obj in [None, True, 42, 3.14, "hi", b"raw", [1, 2, 3],
                {"a": 1, "b": [2, {"c": 3}]}, (1, "x")]:
        out, tag = unpack(pack(obj, tag="t"))
        assert out == obj
        assert tag == "t"


def test_bfloat16_roundtrip():
    import ml_dtypes
    arr = np.arange(24, dtype=ml_dtypes.bfloat16).reshape(2, 3, 4)
    out, _, method = unpack_full(pack(arr))
    assert method == "nd"
    assert out.dtype == arr.dtype and out.shape == arr.shape
    np.testing.assert_array_equal(np.asarray(out, np.float64),
                                  np.asarray(arr, np.float64))


def test_peek_tag_without_deserializing():
    buf = pack({"big": np.zeros(1000)}, tag="endpoint-42/result")
    assert peek_tag(buf) == "endpoint-42/result"
    assert peek_tag(bytearray(buf)) == "endpoint-42/result"
    assert peek_tag(PackedBuffer.from_bytes(buf)) == "endpoint-42/result"
    with pytest.raises(SerializationError):
        peek_tag(b"XXXX????")


def test_zstd_roundtrip():
    pytest.importorskip("zstandard")
    arr = np.zeros(2 << 20, np.uint8)            # compressible
    buf = pack(arr)
    assert len(buf) < arr.nbytes // 10           # FLAG_ZSTD path taken
    out, _ = unpack(buf)
    np.testing.assert_array_equal(out, arr)
    # explicit compress of a small payload
    small = pack({"k": "v" * 64}, compress=True)
    assert unpack(small)[0] == {"k": "v" * 64}


def test_method_cache_learns_and_falls_back():
    """A type's cached method is tried first; when it stops applying to an
    instance (dict of arrays vs plain dict vs dict holding a DataRef) the
    trial loop still finds the right method — and pickle, which succeeds
    on anything, must never be cached for the whole type."""
    from repro.data import DataRef
    assert unpack_full(pack({"w": np.ones(3)}))[2] == "nd"       # cached: nd
    assert unpack_full(pack({"plain": 1}))[2] == "msgpack"       # fallback
    ref = {"arr": DataRef("globus", "ep", "k")}
    out, _, method = unpack_full(pack(ref))
    assert method == "pickle"
    assert isinstance(out["arr"], DataRef)
    # pickle was not cached for dict: arrays still get the fast method
    assert unpack_full(pack({"w": np.ones(3)}))[2] == "nd"


def test_plain_containers_use_msgpack_tuples_use_nd():
    assert unpack_full(pack({"a": [1, "x"]}))[2] == "msgpack"
    assert unpack_full(pack((1, "x")))[2] == "nd"    # tuple-ness preserved
    out, _ = unpack(pack({"p": (1, 2)}))
    assert out == {"p": (1, 2)} and isinstance(out["p"], tuple)


def test_single_array_fast_frames_boundaries():
    """The hand-rolled msgpack framing (bin8/16/32) must be byte-level
    valid at every size-class boundary, for any layout."""
    for n in [0, 1, 255, 256, 65535, 65536, 1 << 20]:
        arr = (np.arange(n) % 251).astype(np.uint8)
        out, _, method = unpack_full(pack(arr))
        assert method == "nd"
        np.testing.assert_array_equal(out, arr)
    noncontig = np.arange(100, dtype=np.float32).reshape(10, 10)[:, ::2]
    np.testing.assert_array_equal(unpack(pack(noncontig))[0], noncontig)
    fortran = np.asfortranarray(np.arange(12, dtype=np.float64).reshape(3, 4))
    np.testing.assert_array_equal(unpack(pack(fortran))[0], fortran)
    scalar = np.float32(7)                        # 0-d array path
    assert unpack(pack(np.asarray(scalar)))[0] == scalar


# ---------------------------------------------------------------------------
# PackedBuffer
# ---------------------------------------------------------------------------

def test_packed_buffer_semantics():
    pb = pack_buffer({"x": np.arange(5)}, tag="task")
    assert pb.tag == "task" and pb.method == "nd"
    assert len(pb) == len(pb.data) == pb.nbytes
    # header-only wrap: no payload decode
    pb2 = PackedBuffer.from_bytes(pb.data)
    assert pb2 == pb and pb2.tag == "task" and pb2.method == "nd"
    # decode is cached (decode-once per consumer)
    v1 = pb2.unpack()
    assert pb2.unpack() is v1
    np.testing.assert_array_equal(v1["x"], np.arange(5))
    # packing a PackedBuffer is the identity — pack-once holds on re-entry
    assert pack_buffer(pb) is pb


def test_packed_buffer_unpack_counts_once():
    stats.reset()
    pb = pack_buffer(np.ones(10), tag="task")
    pb.unpack(), pb.unpack(), pb.unpack()
    s = stats.snapshot()
    assert s["packs_by_tag"]["task"] == 1
    assert s["unpacks_by_tag"]["task"] == 1


# ---------------------------------------------------------------------------
# protocol: payloads travel as opaque byte frames
# ---------------------------------------------------------------------------

def test_taskspec_packed_payload_is_opaque_frame():
    from repro.core import Channel, TaskBatch, TaskSpec, from_wire, to_wire
    payload = pack_buffer({"arr": np.arange(6, dtype=np.float32)}, tag="task")
    batch = TaskBatch(tasks=[TaskSpec(task_id="t", function_id="f",
                                      container_type="python",
                                      payload=payload)])
    env = to_wire(batch)
    assert env["tasks"][0]["payload_b"] == payload.data   # bytes, not object
    assert "payload" not in env["tasks"][0]
    ch = Channel()
    stats.reset()
    assert ch.send_to_endpoint(env, tag="tasks")
    out_env, tag = ch.recv_at_endpoint(timeout=1)
    assert tag == "tasks"
    out = from_wire(out_env)
    got = out.tasks[0].payload
    assert isinstance(got, PackedBuffer) and got == payload
    # crossing the channel must not have re-serialized the payload
    assert stats.snapshot()["packs_by_tag"].get("task", 0) == 0
    np.testing.assert_array_equal(got.unpack()["arr"],
                                  np.arange(6, dtype=np.float32))


def test_resultmsg_packed_result_roundtrips():
    from repro.core import ResultMsg, from_wire, to_wire
    packed = pack_buffer({"y": np.ones(4)}, tag="ret")
    msg = ResultMsg(task_id="t", status="SUCCESS", result=packed)
    out = from_wire(to_wire(msg))
    assert out == msg
    assert isinstance(out.result, PackedBuffer)
    np.testing.assert_array_equal(out.result.unpack()["y"], np.ones(4))


# ---------------------------------------------------------------------------
# the live pipeline: one pack at submit, one decode at the worker,
# one pack per result, one decode at get_result
# ---------------------------------------------------------------------------

def test_pack_once_invariant_end_to_end():
    from repro.core import FuncXClient, FuncXService
    svc = FuncXService(heartbeat_timeout=0.5)
    try:
        tok = svc.register_user("u")
        cl = FuncXClient(svc, tok)
        fid = cl.register_function(
            lambda d: float(np.sum(d["x"])), name="sum")
        eid, agent = svc.make_endpoint(tok, "ep", n_managers=1,
                                       workers_per_manager=2)
        cl.get_result(cl.run(fid, eid,
                             data={"x": np.ones(4, np.float32)}), timeout=10)
        stats.reset()
        n = 8
        tids = [cl.run(fid, eid,
                       data={"x": np.arange(64, dtype=np.float32)})
                for _ in range(n)]
        outs = [cl.get_result(t, timeout=15) for t in tids]
        assert outs == [float(np.sum(np.arange(64)))] * n
        s = stats.snapshot()
        assert s["packs_by_tag"].get("task", 0) == n
        assert s["unpacks_by_tag"].get("task", 0) == n
        assert s["packs_by_tag"].get("ret", 0) == n
        assert s["unpacks_by_tag"].get("ret", 0) == n
        agent.stop()
    finally:
        svc.shutdown()


def test_prepacked_fanout_packs_once():
    from repro.core import FuncXClient, FuncXService
    svc = FuncXService(heartbeat_timeout=0.5)
    try:
        tok = svc.register_user("u")
        cl = FuncXClient(svc, tok)
        fid = cl.register_function(lambda d: int(d["k"]), name="k")
        eid, agent = svc.make_endpoint(tok, "ep", n_managers=1,
                                       workers_per_manager=2)
        stats.reset()
        pp = cl.pack_payload({"k": 42})
        tids = [cl.run(fid, eid, data=pp) for _ in range(5)]
        assert [cl.get_result(t, timeout=15) for t in tids] == [42] * 5
        assert stats.snapshot()["packs_by_tag"].get("task", 0) == 1
        agent.stop()
    finally:
        svc.shutdown()


def test_payload_limit_uses_packed_size():
    """The 10 MB check consumes the same bytes that ship — a payload whose
    packed form fits must pass even if a naive repr would not."""
    from repro.core import FuncXClient, FuncXService, PayloadTooLarge
    svc = FuncXService(heartbeat_timeout=0.5, payload_limit=1 << 16)
    try:
        tok = svc.register_user("u")
        cl = FuncXClient(svc, tok)
        fid = cl.register_function(lambda d: None, name="noop")
        eid, agent = svc.make_endpoint(tok, "ep", n_managers=1,
                                       workers_per_manager=1)
        with pytest.raises(PayloadTooLarge):
            cl.run(fid, eid, data=np.zeros(1 << 17, np.uint8))
        cl.get_result(cl.run(fid, eid, data=np.zeros(64, np.uint8)),
                      timeout=10)
        agent.stop()
    finally:
        svc.shutdown()


# ---------------------------------------------------------------------------
# satellites with observable behaviour
# ---------------------------------------------------------------------------

def test_worker_reaps_on_deadline_not_every_wakeup():
    """The worker blocks long on an empty inbox and still honours the warm
    cache's idle timeout via the reap deadline."""
    from repro.core import ContainerRegistry, Worker
    done = []
    w = Worker("w0", ContainerRegistry(), done.append,
               cache_slots=2, idle_timeout=0.15)
    w.start()
    try:
        from repro.core.worker import WorkItem
        w.submit(WorkItem(task_id="t", container_type="ct", fn=lambda d: d,
                          wants_env=False, payload=None, stamps={}))
        deadline = time.perf_counter() + 5
        while not done and time.perf_counter() < deadline:
            time.sleep(0.01)
        assert done and done[0].status == "SUCCESS"
        assert w.warm_types() == ["ct"]
        deadline = time.perf_counter() + 5
        while w.warm_types() and time.perf_counter() < deadline:
            time.sleep(0.02)
        assert w.warm_types() == []          # reaped without a task arriving
    finally:
        w.stop()


def test_corrupt_buffers_raise_serialization_error():
    """Corrupt frames must surface as SerializationError — the pool's
    single recv loop guards on that type; anything else kills it."""
    good = pack({"k": 1}, tag="result")
    for bad in [b"XXXX" + good[4:],          # bad magic
                good[:6],                    # truncated header
                good[:-3],                   # truncated payload
                good[:8] + b"\xff\xff\xff"]:  # mangled body
        with pytest.raises(SerializationError):
            unpack(bad)
    mangled = bytearray(good)
    mangled[5] = 250                         # unknown method id
    with pytest.raises(SerializationError):
        unpack(bytes(mangled))
    with pytest.raises(SerializationError):
        PackedBuffer.from_bytes(bytes(mangled))


def test_endpoint_recv_survives_poison_payload_frame():
    """A TaskBatch carrying a malformed payload_b must not kill the
    endpoint recv thread (from_wire raises SerializationError there)."""
    from repro.core import Channel
    ch = Channel()
    ch.send_to_endpoint(
        {"type": "task_batch",
         "tasks": [{"task_id": "t", "function_id": "f",
                    "container_type": "python",
                    "payload_b": b"RPX1\x00\x00\x03\x00hb\xff"}]},
        tag="tasks")
    env, _ = ch.recv_at_endpoint(timeout=1)
    from repro.core import from_wire
    with pytest.raises(SerializationError):
        from_wire(env)                        # what the guard must catch
    # raw poison bytes on the queue are dropped by recv itself
    ch._to_endpoint.put(b"RPX1\x00\x00\x03\x00hb\xff\xde\xad")
    assert ch.recv_at_endpoint(timeout=0.2) is None


def test_unserializable_result_parks_live_object_in_devicestore():
    """Pre-PR escape hatch preserved: a result that cannot serialize is
    staged as a live object behind a DataRef when the endpoint store has
    object semantics (DeviceStore)."""
    from repro.core import FuncXClient, FuncXService
    from repro.data import DataRef, DeviceStore
    svc = FuncXService(heartbeat_timeout=0.5)
    try:
        tok = svc.register_user("u")
        cl = FuncXClient(svc, tok)
        fid = cl.register_function(lambda d: (lambda x: x), name="mk_fn")
        store = DeviceStore()
        eid, agent = svc.make_endpoint(tok, "ep", n_managers=1,
                                       workers_per_manager=1, store=store)
        ref = cl.get_result(cl.run(fid, eid, data=None), timeout=15)
        assert isinstance(ref, DataRef)
        assert callable(store.get(ref.key))   # the live lambda, by reference
        agent.stop()
    finally:
        svc.shutdown()


def test_result_value_releases_wire_bytes():
    """With purge_on_get=False the service must not retain wire bytes AND
    the decoded object — the first decode replaces the buffer."""
    from repro.core import FuncXClient, FuncXService
    svc = FuncXService(heartbeat_timeout=0.5, purge_on_get=False)
    try:
        tok = svc.register_user("u")
        cl = FuncXClient(svc, tok)
        fid = cl.register_function(lambda d: {"v": 7}, name="f")
        eid, agent = svc.make_endpoint(tok, "ep", n_managers=1,
                                       workers_per_manager=1)
        tid = cl.run(fid, eid, data=None)
        assert cl.get_result(tid, timeout=15) == {"v": 7}
        t = svc.get_task(tid)
        assert not isinstance(t.result, PackedBuffer)
        assert cl.get_result(tid, timeout=1) == {"v": 7}   # repeat reads ok
        agent.stop()
    finally:
        svc.shutdown()


def test_hub_survives_poison_frame():
    """A frame with an undecodable header must be dropped by the hub —
    not kill the shared poller thread (nor force a pool restart)."""
    from repro.core import Channel, ChannelHub
    hub = ChannelHub()
    ch = Channel()
    hub.register("k", ch)
    ch._to_service.put(b"RPX1\x00\x00\x03\x00hb\xff\xde\xad")  # bad utf-8 tag
    hub._notify("k")
    assert hub.poll(timeout=0.2) == []       # dropped silently
    assert ch.send_to_service({"type": "ack", "task_ids": [],
                               "t_endpoint_recv": 0.0}, tag="ack")
    out = hub.poll(timeout=1.0)
    assert len(out) == 1 and out[0][1].tag == "ack"   # poller still alive


def test_stage_outputs_devicestore_keeps_object_semantics():
    """DeviceStore.get returns live objects; staging must not hand it wire
    bytes (and must keep arrays by reference, its whole point)."""
    from repro.data import DataRef, DeviceStore, stage_outputs
    store = DeviceStore()
    big = np.zeros(1 << 14, np.uint8)
    packed = pack_buffer(big, tag="ret")
    ref = stage_outputs(big, "ep", store, "t11", limit=1 << 10, packed=packed)
    assert isinstance(ref, DataRef)
    got = store.get("t11/result")
    assert isinstance(got, np.ndarray)       # the object, not RPX1 bytes
    assert got is big                        # by reference — zero copies
    np.testing.assert_array_equal(got, big)


def test_stats_tags_are_bounded():
    """Store writes tag buffers by key; stats must bucket unknown tags so
    the per-tag dicts stay O(1) in a long-running service."""
    stats.reset()
    for i in range(50):
        pack({"v": i}, tag=f"task/{i}/result")
    s = stats.snapshot()
    assert s["packs_by_tag"] == {"other": 50}


def test_stage_outputs_reuses_packed_bytes():
    from repro.data import DataRef, InMemoryKVStore, stage_outputs
    store = InMemoryKVStore()
    big = np.zeros(1 << 14, np.uint8)
    packed = pack_buffer(big, tag="ret")
    stats.reset()
    ref = stage_outputs(big, "ep", store, "t9", limit=1 << 10, packed=packed)
    assert isinstance(ref, DataRef)
    # staging wrote the existing bytes — no new serialization happened
    assert stats.snapshot()["packs"] == 0
    np.testing.assert_array_equal(store.get("t9/result"), big)
    small = stage_outputs({"v": 1}, "ep", store, "t10", limit=1 << 20)
    assert small == {"v": 1}
