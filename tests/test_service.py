"""Cloud service tier (paper §4.1): registration, submission, auth
enforcement, payload limits, result purge, user-facing batching."""
import numpy as np
import pytest

from repro.core import ContainerSpec, FuncXClient, FuncXService, PayloadTooLarge, TaskFailure
from repro.core.errors import AuthError


def _echo(data):
    return data


def test_register_and_run(service, client):
    fid = client.register_function(_echo)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1,
                                       workers_per_manager=2)
    tid = client.run(fid, eid, data={"v": 7})
    assert client.get_result(tid, timeout=10) == {"v": 7}
    agent.stop()


def test_function_permissions(service):
    owner_tok = service.register_user("owner")
    other_tok = service.register_user("other")
    owner = FuncXClient(service, owner_tok)
    other = FuncXClient(service, other_tok)
    private = owner.register_function(_echo, name="private")
    shared = owner.register_function(_echo, name="shared",
                                     allowed=["other"])
    eid, agent = service.make_endpoint(owner_tok, "ep", n_managers=1)
    with pytest.raises(AuthError):
        other.run(private, eid, data=1)
    tid = other.run(shared, eid, data=1)
    assert other.get_result(tid, timeout=10) == 1
    agent.stop()


def test_payload_limit_enforced(service, client):
    fid = client.register_function(_echo)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1)
    big = np.random.default_rng(0).integers(
        0, 255, 11 * 1024 * 1024, dtype=np.uint8)   # incompressible
    with pytest.raises(PayloadTooLarge):
        client.run(fid, eid, data=big)
    agent.stop()


def test_function_error_propagates(service, client):
    def boom(data):
        raise ValueError("bad input 42")
    fid = client.register_function(boom)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1)
    tid = client.run(fid, eid, data={})
    with pytest.raises(TaskFailure, match="bad input 42") as ei:
        client.get_result(tid, timeout=10)
    assert "ValueError" in ei.value.remote_traceback
    agent.stop()


def test_result_purged_after_get(service, client):
    fid = client.register_function(_echo)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1)
    tid = client.run(fid, eid, data=5)
    assert client.get_result(tid, timeout=10) == 5
    with pytest.raises(KeyError):
        service.get_task(tid)       # purged (paper §4.1)
    agent.stop()


def test_user_facing_batch(service, client):
    fid = client.register_function(lambda d: d["i"] * 2)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=2,
                                       workers_per_manager=2)
    outs = client.map(fid, eid, [{"i": i} for i in range(20)], timeout=20)
    assert outs == [2 * i for i in range(20)]
    agent.stop()


def test_latency_breakdown_fields(service):
    svc = FuncXService(heartbeat_timeout=0.3, purge_on_get=False)
    try:
        tok = svc.register_user("u")
        cl = FuncXClient(svc, tok)
        fid = cl.register_function(_echo)
        eid, agent = svc.make_endpoint(tok, "ep", n_managers=1)
        tid = cl.run(fid, eid, data=1)
        cl.get_result(tid, timeout=10)
        bd = cl.task(tid).latency_breakdown()
        for k in ("t_s", "t_f", "t_e", "t_w", "total"):
            assert bd[k] == bd[k] and bd[k] >= 0     # not NaN
        assert bd["total"] >= bd["t_w"]
        agent.stop()
    finally:
        svc.shutdown()


def test_discovery_apis(service, client):
    other_tok = service.register_user("other")
    other = FuncXClient(service, other_tok)
    f_private = client.register_function(_echo, name="ssx/process_stills")
    f_shared = client.register_function(_echo, name="ssx/solve",
                                        allowed=["other"])
    eid, agent = service.make_endpoint(client.token, "theta-ep",
                                       n_managers=1)
    # owner sees both; the other identity only the shared one
    assert {f["name"] for f in client.search_functions("ssx")} == \
        {"ssx/process_stills", "ssx/solve"}
    assert {f["name"] for f in other.search_functions("ssx")} == {"ssx/solve"}
    eps = client.list_endpoints()
    assert any(e["endpoint_id"] == eid and e["connected"] for e in eps)
    agent.stop()


def test_container_type_flows_to_worker(service, client):
    service.register_container(ContainerSpec("special",
                                             build=lambda: {"mark": 1}))
    def probe(data, env):
        return env["mark"]
    fid = client.register_function(probe, container_type="special")
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1)
    tid = client.run(fid, eid, data={})
    assert client.get_result(tid, timeout=10) == 1
    task_cold = service.submitted
    agent.stop()
