"""Hierarchical interchange (DESIGN.md §11): upstream it is one ordinary
endpoint, downstream a mini-forwarder over the identical wire protocol.
Pinned here: burst absorption into the deep backlog, credit backpressure
on the service-side forwarder, heartbeat synthesis (aggregate load +
merged warmth), exactly-once through leaf death and upstream cuts,
relay-of-relays nesting, and the elastic leaf lifecycle."""
import time

import pytest

from repro.core import (
    ElasticStrategy,
    Interchange,
    ThreadLeafProvider,
)
from conftest import wait_until


@pytest.fixture
def relay(tcp_service):
    """(svc, client, interchange) — an interchange registered upstream,
    no leaves yet (each test attaches what it needs)."""
    svc, client, (host, port) = tcp_service
    ix = Interchange(f"{host}:{port}", client.endpoint_credentials(),
                     name="relay", depth=5000, heartbeat_interval=0.05,
                     leaf_timeout=0.4)
    ix.start()
    yield svc, client, ix
    ix.stop()


def add_leaves(ix, n, *, workers=2, **kw):
    prov = ThreadLeafProvider(ix, workers_per_node=workers, **kw)
    ids = []
    for _ in range(n):
        ids += prov.start_block(ix)
    return prov, ids


# ---------------------------------------------------------------- basic relay

def test_relay_roundtrip(relay):
    svc, client, ix = relay
    prov, _ = add_leaves(ix, 2)
    try:
        fid = client.register_function(lambda d: d["i"] * 3)
        ids = client.batch_run([(fid, ix.endpoint_id, {"i": i})
                                for i in range(20)])
        assert client.get_batch_results(ids, timeout=30) == \
            [3 * i for i in range(20)]
        # pack-once held: every task crossed both hops, every result came
        # back through the relay
        assert ix.tasks_received == 20
        assert ix.results_forwarded == 20
    finally:
        prov.stop_all()


def test_result_racing_ahead_of_send_bookkeeping_does_not_leak(relay):
    """A fast leaf can return a result before the dispatcher re-acquires
    the lock after sending. The in-flight entry must exist by the time
    the result lands, or the pop misses and the leaf's dispatch window
    leaks one unit forever (at 100k scale the leaks freeze dispatch with
    work still in the backlog). Simulate the worst case: the result
    arrives synchronously *inside* the send call."""
    svc, client, ix = relay
    prov, _ = add_leaves(ix, 1)
    try:
        fid = client.register_function(lambda d: d["i"])
        line = ix.leaf_lines()[0]
        real_send = line.channel.send_parts_to_endpoint
        from repro.core.protocol import ResultBatch, ResultMsg, from_wire

        def racing_send(env, segs, tag="tasks"):
            ok = real_send(env, segs, tag=tag)
            if ok and tag == "tasks":
                batch = from_wire({**env, "_segs": segs})
                ix._leaf_results(line, ResultBatch(results=[
                    ResultMsg(task_id=s.task_id, result=None)
                    for s in batch.tasks]))
            return ok

        line.channel.send_parts_to_endpoint = racing_send
        ids = client.batch_run([(fid, ix.endpoint_id, {"i": i})
                                for i in range(8)])
        client.get_batch_results(ids, timeout=30)
        # the synchronous results must have found their in-flight entries
        assert wait_until(lambda: line.in_flight_count() == 0, timeout=5)
        assert line.window(ix.leaf_window, ix.queue_factor) > 0
    finally:
        line.channel.send_parts_to_endpoint = real_send
        prov.stop_all()


def test_heartbeat_synthesizes_subtree(relay):
    """Upstream sees one endpoint whose heartbeat aggregates the whole
    subtree: summed capacity, merged warm dicts, backlog credits."""
    svc, client, ix = relay
    prov, _ = add_leaves(ix, 2, workers=2)
    try:
        line = svc.pool.line(ix.endpoint_id)
        assert wait_until(lambda: line.advertised.capacity == 4, timeout=5)
        hb = line.advertised
        assert hb.credits >= 0                   # bounded intake advertised
        assert hb.credits <= ix.depth
        assert hb.depth == ix.depth
        # warm a container on the leaves, then the merged dicts show it
        fid = client.register_function(lambda d: d)
        ids = client.batch_run([(fid, ix.endpoint_id, i) for i in range(4)])
        assert client.get_batch_results(ids, timeout=30) == list(range(4))
        assert wait_until(
            lambda: svc.pool.line(ix.endpoint_id).advertised.warm_idle.get(
                "python", 0) > 0, timeout=5)
    finally:
        prov.stop_all()


def test_backlog_absorbs_burst_before_any_leaf_exists(relay):
    """The tentpole queueing property: a burst lands entirely in the
    interchange backlog (acked upstream, nothing dispatched) and drains
    the moment leaves appear."""
    svc, client, ix = relay
    fid = client.register_function(lambda d: d["i"])
    ids = client.batch_run([(fid, ix.endpoint_id, {"i": i})
                            for i in range(500)])
    assert wait_until(lambda: ix.backlog_peak >= 500, timeout=10)
    assert ix.tasks_dispatched == 0
    # the service-side line drained into the relay (acked, in flight)
    assert wait_until(
        lambda: svc.pool.line(ix.endpoint_id).queue_len() == 0, timeout=5)
    prov, _ = add_leaves(ix, 2)
    try:
        assert client.get_batch_results(ids, timeout=60) == list(range(500))
    finally:
        prov.stop_all()


def test_credits_backpressure_caps_service_dispatch(tcp_service):
    """A shallow relay advertises few credits; the service-side forwarder
    must stop at the advertisement instead of overrunning the bounded
    intake — the rest of the burst waits service-side."""
    svc, client, (host, port) = tcp_service
    ix = Interchange(f"{host}:{port}", client.endpoint_credentials(),
                     name="shallow", depth=50, heartbeat_interval=0.05)
    ix.start()
    try:
        line = svc.pool.line(ix.endpoint_id)
        # wait for the first credit advertisement so the cap is in force
        assert wait_until(lambda: line.advertised.credits >= 0, timeout=5)
        fid = client.register_function(lambda d: d["i"])
        ids = client.batch_run([(fid, ix.endpoint_id, {"i": i})
                                for i in range(200)])
        assert wait_until(lambda: ix.tasks_received == 50, timeout=5)
        time.sleep(0.3)                          # several credit refreshes
        assert ix.tasks_received == 50           # no overrun past depth
        assert line.queue_len() == 150
        # leaves drain the backlog; freed credits let the rest flow
        prov, _ = add_leaves(ix, 2)
        try:
            assert client.get_batch_results(ids, timeout=60) == \
                list(range(200))
        finally:
            prov.stop_all()
    finally:
        ix.stop()


# ------------------------------------------------------------- exactly-once

def test_leaf_death_requeues_and_completes_exactly_once(relay):
    """Kill one leaf mid-burst (no goodbye — heartbeats just stop): its
    in-flight specs requeue into the backlog and finish on the survivor;
    every task completes exactly once upstream."""
    svc, client, ix = relay
    prov, leaf_ids = add_leaves(ix, 2, workers=1)
    try:
        fid = client.register_function(
            lambda d: time.sleep(0.02) or d["i"])
        ids = client.batch_run([(fid, ix.endpoint_id, {"i": i})
                                for i in range(40)])
        victim = leaf_ids[0]
        assert wait_until(
            lambda: any(ln.endpoint_id == victim and ln.dispatched > 0
                        for ln in ix.leaf_lines()), timeout=10)
        # abrupt death: stop the runner without telling the interchange
        prov._runners.pop(victim).stop()
        assert client.get_batch_results(ids, timeout=60) == list(range(40))
        assert ix.requeues > 0
        # exactly once: purge-on-get means a second fetch must fail
        for tid in ids:
            with pytest.raises(KeyError):
                svc.get_task(tid)
    finally:
        prov.stop_all()


def test_upstream_cut_parks_results_and_retransmits(relay):
    """Results produced while the service link is down park in the
    interchange and retransmit after the automatic re-register — nothing
    is lost, nothing duplicates."""
    svc, client, ix = relay
    prov, _ = add_leaves(ix, 1)
    try:
        fid = client.register_function(lambda d: d["i"] * 2)
        ids = client.batch_run([(fid, ix.endpoint_id, {"i": i})
                                for i in range(10)])
        assert wait_until(lambda: ix.backlog_peak >= 1 or
                          ix.tasks_received == 10, timeout=10)
        ix.transport.disconnect()               # upstream cut
        time.sleep(0.5)                         # results finish into it
        ix.transport.reconnect()                # allow the re-dial
        assert client.get_batch_results(ids, timeout=60) == \
            [2 * i for i in range(10)]
        assert ix.re_registrations >= 1
        for tid in ids:
            with pytest.raises(KeyError):
                svc.get_task(tid)
    finally:
        prov.stop_all()


# ------------------------------------------------------------------- nesting

def test_relay_of_relays_two_levels(relay):
    """An interchange registers with another interchange exactly like a
    leaf does — the downstream handshake is the service's. Tasks cross
    service → relay → child-relay → leaf and back."""
    svc, client, ix = relay
    child = Interchange(ix.leaf_address, ix.leaf_token, name="child",
                        depth=2000, heartbeat_interval=0.05,
                        leaf_timeout=0.4)
    child.start()
    prov, _ = add_leaves(child, 2)
    try:
        # the parent sees the child's bounded intake like the service
        # sees the parent's
        assert wait_until(
            lambda: any(ln.advertised.credits >= 0
                        for ln in ix.leaf_lines()), timeout=5)
        fid = client.register_function(lambda d: d["i"] + 100)
        ids = client.batch_run([(fid, ix.endpoint_id, {"i": i})
                                for i in range(30)])
        assert client.get_batch_results(ids, timeout=60) == \
            [i + 100 for i in range(30)]
        assert child.results_forwarded == 30
        assert ix.results_forwarded == 30
    finally:
        prov.stop_all()
        child.stop()


# ------------------------------------------------------------------ elastic

def test_elastic_leaves_scale_out_on_backlog_and_reap_when_idle(relay):
    svc, client, ix = relay
    prov = ThreadLeafProvider(ix, workers_per_node=2)
    strategy = ElasticStrategy(ix, prov, min_blocks=0, max_blocks=3,
                               backlog_per_block=20, idle_timeout=0.4,
                               interval=0.03)
    ix.strategy = strategy
    strategy.start()
    fid = client.register_function(lambda d: d["i"])
    ids = client.batch_run([(fid, ix.endpoint_id, {"i": i})
                            for i in range(60)])
    # backlog depth of 60 asks for ceil(60/20)=3 blocks in one decision
    assert wait_until(lambda: strategy.scale_out_events >= 3, timeout=10)
    assert client.get_batch_results(ids, timeout=60) == list(range(60))
    # drained + idle past the timeout: leaves reap back to min_blocks
    assert wait_until(lambda: strategy.blocks() == 0, timeout=15)
    assert strategy.scale_in_events >= 3
    assert ix.leaf_lines() == []
