"""Federated learning over funcX endpoints (paper §8 / Flox) with
compressed delta exchange + error feedback."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.train.fedavg import (
    FedAvgCoordinator,
    compress_tree,
    compressed_bytes,
    decompress_tree,
)


# ---------------------------------------------------------------- codecs

def test_int8_roundtrip_accuracy():
    rng = np.random.default_rng(0)
    delta = {"w": rng.normal(0, 0.01, (64, 64)).astype(np.float32)}
    msgs, err = compress_tree(delta, "int8")
    rec = decompress_tree(msgs)
    # quantization error bounded by scale/2 per element
    scale = np.abs(delta["w"]).max() / 127
    assert np.max(np.abs(rec["w"] - delta["w"])) <= scale
    np.testing.assert_allclose(rec["w"] + err["w"], delta["w"], atol=1e-7)
    assert compressed_bytes(msgs) < delta["w"].nbytes / 3.5


def test_topk_keeps_largest():
    delta = {"w": np.array([0.0, 5.0, -0.1, -7.0, 0.2], np.float32)}
    msgs, _ = compress_tree(delta, "topk", topk_frac=0.4)
    rec = decompress_tree(msgs)
    np.testing.assert_array_equal(
        rec["w"], np.array([0.0, 5.0, 0.0, -7.0, 0.0], np.float32))


def test_error_feedback_is_unbiased_over_rounds():
    """With EF, the ACCUMULATED applied delta converges to the accumulated
    true delta (compression noise does not build up)."""
    rng = np.random.default_rng(1)
    true_total = np.zeros(256, np.float32)
    applied_total = np.zeros(256, np.float32)
    err = None
    for _ in range(50):
        d = {"w": rng.normal(0, 0.01, 256).astype(np.float32)}
        true_total += d["w"]
        msgs, err = compress_tree(d, "int8", error_state=err)
        applied_total += decompress_tree(msgs)["w"]
    resid = np.abs(applied_total - true_total).max()
    # residual is bounded by one step's quantization error, not 50 steps'
    assert resid < 0.002, resid


# ------------------------------------------------------------ end-to-end

def test_fedavg_through_faas(service, client):
    """Two endpoints federally train the smoke model; loss decreases and
    deltas travel compressed."""
    from repro.configs import TrainConfig, get_reduced_config
    from repro.models import get_model
    from repro.train import make_train_step
    from repro.train.data import SyntheticLM

    cfg = get_reduced_config("qwen1.5-0.5b")
    model = get_model(cfg)
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=0, total_steps=100)
    step_fn = jax.jit(make_train_step(model, tc))

    def local_train(data):
        params = jax.tree.map(jnp.asarray, data["params"])
        state = {"params": params,
                 "opt": jax.tree.map(jnp.zeros_like,
                                     {"m": params, "v": params}),
                 "step": jnp.zeros((), jnp.int32)}
        state["opt"] = {"m": jax.tree.map(jnp.zeros_like, params),
                        "v": jax.tree.map(jnp.zeros_like, params)}
        ds = SyntheticLM(cfg.vocab_size, 32, 8, seed=data["seed"])
        loss = 0.0
        for _, batch in zip(range(data["steps"]), ds):
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            state, m = step_fn(state, batch)
            loss = float(m["loss"])
        delta = jax.tree.map(lambda new, old: np.asarray(new) - np.asarray(old),
                             state["params"], params)
        return {"delta": delta, "loss": loss}

    fid = client.register_function(local_train)
    eids = []
    agents = []
    for name in ("edge-a", "edge-b"):
        eid, agent = service.make_endpoint(client.token, name, n_managers=1,
                                           workers_per_manager=1)
        eids.append(eid)
        agents.append(agent)

    coord = FedAvgCoordinator(client, fid, eids, method="int8")
    params = model.init(jax.random.PRNGKey(0))
    losses = []
    for r in range(3):
        params, metrics = coord.round(params, local_steps=8, seed=r)
        losses.append(metrics["mean_loss"])
    assert losses[-1] < losses[0], losses
    assert metrics["compression_ratio"] > 3.5
    for a in agents:
        a.stop()
