"""ForwarderPool (DESIGN.md §3): O(1) service threads for N endpoints,
multiplexed dispatch, requeue ordering on disconnect, and pool restart
carrying in-flight tasks."""
import threading


from repro.core import EndpointAgent, TaskStatus
from conftest import wait_until


def test_o1_service_threads_for_many_endpoints(service, client):
    """Registering N endpoints must not grow the service tier: the pool's
    three loops + the health thread serve everyone (the seed spawned 3
    dedicated threads per endpoint)."""
    before = {t.name for t in threading.enumerate()}
    for i in range(12):
        service.register_endpoint(client.token, f"ep{i}")
    after = {t.name for t in threading.enumerate()}
    assert after - before == set(), "registration spawned service threads"
    # the constant service tier is exactly the pool loops + health check
    svc_threads = [n for n in after
                   if n.startswith("pool-") or n == "svc-health"]
    assert sorted(svc_threads) == ["pool-dispatch", "pool-monitor",
                                   "pool-recv", "svc-health"]


def test_multiplexed_dispatch_across_8_endpoints(service, client):
    fid = client.register_function(lambda d: d["i"] * 10)
    eps, agents = [], []
    for i in range(8):
        eid, agent = service.make_endpoint(client.token, f"ep{i}",
                                           n_managers=1,
                                           workers_per_manager=2)
        eps.append(eid)
        agents.append(agent)
    ids = client.batch_run([(fid, eps[i % 8], {"i": i}) for i in range(48)])
    assert client.get_batch_results(ids, timeout=30) == \
        [i * 10 for i in range(48)]
    # every endpoint got its share through the one dispatch loop
    for eid in eps:
        assert service.pool.line(eid).dispatched > 0
    assert service.pool.dispatched >= 48
    for a in agents:
        a.stop()


def test_requeue_preserves_fifo_order_on_heartbeat_loss(service, client):
    """Endpoint with no agent: dispatched tasks sit in flight, the silent
    heartbeat trips the monitor, and the in-flight set returns to the head
    of the queue in original dispatch order."""
    fid = client.register_function(lambda d: d)
    eid, _ch = service.register_endpoint(client.token, "ep")
    line = service.pool.line(eid)
    ids = client.batch_run([(fid, eid, i) for i in range(6)])
    assert wait_until(lambda: line.in_flight_count() == 6, timeout=5)
    assert wait_until(lambda: not line.endpoint_connected, timeout=5)
    assert line.in_flight_count() == 0
    assert list(line.queue) == ids                # FIFO order preserved
    assert line.requeues == 6
    assert all(service.get_task(t).status is TaskStatus.PENDING
               for t in ids)


def test_disconnect_requeue_then_reconnect_completes_in_order(service,
                                                             client):
    """Channel partition mid-stream: requeued work flows again after the
    endpoint reconnects, single worker ⇒ completion order == FIFO."""
    seen = []
    fid = client.register_function(lambda d: seen.append(d["i"]) or d["i"])
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1,
                                       workers_per_manager=1)
    rec = service.endpoints[eid]
    rec.channel.disconnect()
    ids = client.batch_run([(fid, eid, {"i": i}) for i in range(5)])
    assert wait_until(lambda: not rec.connected, timeout=5)
    rec.channel.reconnect()
    assert client.get_batch_results(ids, timeout=30) == list(range(5))
    assert seen == sorted(seen)
    agent.stop()


def test_pool_restart_requeues_in_flight(service, client):
    """Satellite fix: a pool restart must not drop tasks that were already
    dispatched — they are requeued (ahead of undelivered queue) and run
    once an agent serves the endpoint."""
    fid = client.register_function(lambda d: d["i"] + 100)
    eid, channel = service.register_endpoint(client.token, "ep")
    ids = client.batch_run([(fid, eid, {"i": i}) for i in range(3)])
    old_pool = service.pool
    assert wait_until(
        lambda: old_pool.line(eid).in_flight_count() == 3, timeout=5)
    # partition the channel so the restarted pool cannot re-dispatch
    # before we observe the carried-over queue
    channel.disconnect()
    old_pool._stop.set()                 # crash the pool with tasks in flight
    assert wait_until(lambda: service.pool is not old_pool, timeout=5)
    line = service.pool.line(eid)
    assert list(line.queue) == ids       # carried over, dispatch order kept
    assert line.requeues == 3
    assert all(service.get_task(t).status is TaskStatus.PENDING
               for t in ids)
    # late-attach an agent on the same channel: the tasks drain
    channel.reconnect()
    agent = EndpointAgent(eid, channel, service.export_function,
                          registry=service.containers,
                          heartbeat_interval=service.heartbeat_timeout / 5)
    agent.add_manager(n_workers=2)
    agent.start()
    assert sorted(client.get_batch_results(ids, timeout=30)) == \
        [100, 101, 102]
    agent.stop()


def test_heartbeat_advertises_load_and_warm_state(service, client):
    from repro.core import ContainerSpec
    service.register_container(ContainerSpec("special",
                                             build=lambda: {"m": 1}))
    def probe(data, env):
        return env["m"]
    fid = client.register_function(probe, container_type="special")
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=2,
                                       workers_per_manager=2)
    # capacity shows up via heartbeats even before any task
    assert wait_until(
        lambda: service.pool.line(eid).advertised.capacity == 4, timeout=5)
    assert client.get_result(client.run(fid, eid, data={}), timeout=10) == 1
    # ...and the warmed container type is advertised afterwards
    assert wait_until(
        lambda: service.pool.line(eid).advertised.warm_total.get(
            "special", 0) > 0, timeout=5)
    agent.stop()
