"""Batched result plane (DESIGN.md §6): frames-per-task under load,
lone-task immediate flush, batch-wise retransmission exactly-once,
streaming `as_completed`/`wait_any` retrieval, bulk TaskStore ops,
harvest-then-raise batch gets, bounded endpoint dedup state, and the
manager's deferred-placement side deque."""
import time

import pytest

from repro.core import (
    ResultCoalescer,
    ResultMsg,
    Task,
    TaskFailure,
    TaskStore,
)
from repro.core.endpoint import demo_sleep
from conftest import start_tcp_endpoint, wait_until


# -- frames-per-task / coalescing -------------------------------------------

def test_frames_per_task_under_load(service, client):
    """Under load the coalescer must amortize envelopes: ≥8 results per
    result-carrying envelope at batch_size 32 (waves of simultaneous
    completions + linger fill the batches)."""
    fid = client.register_function(lambda d: time.sleep(0.01), name="s10ms")
    eid, agent = service.make_endpoint(
        client.token, "ep", n_managers=2, workers_per_manager=16,
        result_linger=0.01)
    n = 256
    ids = client.batch_run([(fid, eid, {}) for _ in range(n)])
    assert client.get_batch_results(ids, timeout=60) == [None] * n
    co = agent.coalescer
    assert co.results_sent >= n
    assert co.result_envelopes <= n // 8, (
        f"{co.result_envelopes} result envelopes for {n} tasks "
        f"(avg {co.results_sent / co.result_envelopes:.1f}/envelope)")
    # the pool saw the same batching (frames, not per-task messages)
    assert service.pool.result_envelopes <= n // 8
    assert service.pool.results_received >= n
    agent.stop()


def test_lone_task_flushes_immediately_when_idle(service, client):
    """An idle line must not pay the linger: a lone result ships on its
    own thread the moment it completes (set linger absurdly high — if the
    flush waited on it, this get would take ≥2s)."""
    fid = client.register_function(lambda d: d["x"], name="echo")
    eid, agent = service.make_endpoint(
        client.token, "ep", n_managers=1, workers_per_manager=2,
        result_linger=2.0)
    t0 = time.perf_counter()
    assert client.get_result(client.run(fid, eid, data={"x": 7}),
                             timeout=10) == 7
    assert time.perf_counter() - t0 < 1.0
    assert agent.coalescer.result_envelopes == 1
    agent.stop()


def test_coalescer_parks_refused_envelopes_and_retransmits():
    """Unit: a refused send parks the built envelope; flush_unsent ships
    it verbatim once the link accepts again."""
    sent = []
    link_up = {"v": False}

    def send(env, segments):
        if not link_up["v"]:
            return False
        sent.append(env)
        return True

    co = ResultCoalescer(send, batch_size=4, linger=0.0)
    co.add_result(ResultMsg(task_id="a", status="SUCCESS", result=1))
    co.add_result(ResultMsg(task_id="b", status="SUCCESS", result=2))
    assert co.unsent_count >= 1 and not sent
    link_up["v"] = True
    co.flush_unsent()
    assert co.unsent_count == 0
    got = [r["task_id"] for env in sent for r in env["results"]]
    assert got == ["a", "b"]          # completion order preserved
    assert co.results_sent == 2


def test_batched_retransmission_after_tcp_cut_exactly_once(tcp_service):
    """Results finished into a dead socket are parked *as batch
    envelopes* and retransmitted after the re-dial; the requeued
    re-execution's duplicates are dropped member-wise, so every task
    completes exactly once."""
    svc, client, address = tcp_service
    runner = start_tcp_endpoint(client, address, workers_per_manager=4)
    try:
        fid = client.register_function(demo_sleep)
        ids = client.batch_run([(fid, runner.endpoint_id, {"s": 0.3})
                                for _ in range(4)])
        # all four on workers (function fetched, items placed) before the
        # cut — else the cut can stall the wire fn-fetch instead, and the
        # results would ship over the healed link without ever parking
        assert wait_until(lambda: len(runner.agent._dispatched_at) >= 4,
                          timeout=5)
        runner.transport.disconnect()
        time.sleep(1.0)              # all four finish into the dead link
        co = runner.agent.coalescer
        assert co.envelopes_parked >= 1
        assert co.unsent_count >= 1
        runner.transport.reconnect()
        assert client.get_batch_results(ids, timeout=30) == [None] * 4
        assert wait_until(lambda: co.unsent_count == 0, timeout=10)
        # exactly once: every id was retrieved once and then purged
        for tid in ids:
            with pytest.raises(KeyError):
                svc.get_task(tid)
    finally:
        runner.stop()


# -- streaming retrieval -----------------------------------------------------

def test_as_completed_yields_in_completion_order(service, client):
    slow = client.register_function(lambda d: time.sleep(0.5) or "slow")
    fast = client.register_function(lambda d: "fast")
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1,
                                       workers_per_manager=2)
    tid_slow = client.run(slow, eid, data={})
    tid_fast = client.run(fast, eid, data={})
    got = list(client.as_completed([tid_slow, tid_fast], timeout=30))
    assert [tid for tid, _ in got] == [tid_fast, tid_slow]
    assert dict(got) == {tid_fast: "fast", tid_slow: "slow"}
    agent.stop()


def test_as_completed_times_out_on_pending_tasks(service, client):
    fid = client.register_function(lambda d: d)
    eid, _ch = service.register_endpoint(client.token, "dead")  # no agent
    tid = client.run(fid, eid, data=1)
    with pytest.raises(TimeoutError):
        list(service.as_completed([tid], timeout=0.3))


def test_wait_any_returns_done_subset(service, client):
    fid = client.register_function(lambda d: d["i"])
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1,
                                       workers_per_manager=2)
    ids = client.batch_run([(fid, eid, {"i": i}) for i in range(3)])
    done = set()
    deadline = time.time() + 20
    while len(done) < 3 and time.time() < deadline:
        done.update(client.wait_any(list(set(ids) - done), timeout=5))
    assert done == set(ids)
    # nothing pending → a wait on an unknown/never-submitted id times out
    assert service.wait_any(["no-such-task"], timeout=0.1) == []
    agent.stop()


# -- bulk TaskStore ops ------------------------------------------------------

def _mk_tasks(n):
    return [Task(function_id="f", endpoint_id="e", payload=None,
                 container_type="python") for _ in range(n)]


def test_batch_waiter_wakes_once_per_batch():
    store = TaskStore()
    tasks = _mk_tasks(6)
    store.put_many(tasks)
    ids = [t.task_id for t in tasks]
    w = store.make_waiter(ids)
    store.mark_done_many(ids[:3])
    assert sorted(w.wait(1.0)) == sorted(ids[:3])
    assert w.wait(0.05) == []                    # drained; no new events
    store.mark_done_many(ids[3:])
    assert sorted(w.wait(1.0)) == sorted(ids[3:])
    store.close_waiter(w)
    assert not store._watchers                   # registration fully gone


def test_make_waiter_sees_already_done_tasks():
    store = TaskStore()
    tasks = _mk_tasks(2)
    store.put_many(tasks)
    ids = [t.task_id for t in tasks]
    store.mark_done(ids[0])
    w = store.make_waiter(ids)
    assert w.wait(0.5) == [ids[0]]               # fired at registration
    store.close_waiter(w)


def test_mark_done_many_sets_per_task_events_too():
    store = TaskStore()
    tasks = _mk_tasks(2)
    store.put_many(tasks)
    store.mark_done_many([t.task_id for t in tasks])
    assert store.wait(tasks[0].task_id, timeout=0.5)
    assert store.wait(tasks[1].task_id, timeout=0.5)


# -- harvest-then-raise ------------------------------------------------------

def test_get_batch_results_failure_still_drains_store(service, client):
    """A mid-list failure used to abandon the un-harvested tail in the
    store under purge_on_get=True; now the whole batch is drained first
    and the error raises after."""
    def maybe_boom(data):
        if data["i"] == 1:
            raise ValueError("boom")
        return data["i"]
    fid = client.register_function(maybe_boom)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1,
                                       workers_per_manager=2)
    ids = client.batch_run([(fid, eid, {"i": i}) for i in range(4)])
    with pytest.raises(TaskFailure, match="boom"):
        client.get_batch_results(ids, timeout=30)
    for tid in ids:                  # every task purged, none leaked
        with pytest.raises(KeyError):
            service.get_task(tid)
    assert len(service.tasks) == 0
    agent.stop()


# -- bounded endpoint state --------------------------------------------------

def test_endpoint_dedup_state_is_bounded(service, client):
    fid = client.register_function(lambda d: None, name="noop")
    eid, agent = service.make_endpoint(
        client.token, "ep", n_managers=1, workers_per_manager=4,
        dedup_capacity=64)
    ids = client.batch_run([(fid, eid, {}) for _ in range(200)])
    assert client.get_batch_results(ids, timeout=60) == [None] * 200
    assert len(agent._completed) <= 64           # LRU bound held
    assert not agent._retries                    # popped on completion
    assert wait_until(lambda: not agent._dispatched_at, timeout=5)
    agent.stop()


def test_dispatched_sweep_evicts_stale_entries(service, client):
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1,
                                       workers_per_manager=1)
    agent.dispatched_ttl = 0.05
    agent._dispatched_at["ghost"] = (time.perf_counter() - 1.0, None, "m0")
    agent._completed.add("done-task")
    agent._dispatched_at["done-task"] = (time.perf_counter(), None, "m0")
    time.sleep(0.1)
    agent._sweep_dispatched()
    assert "ghost" not in agent._dispatched_at       # TTL eviction
    assert "done-task" not in agent._dispatched_at   # completed eviction
    agent.stop()


# -- manager deferred placement ----------------------------------------------

def test_manager_parks_unplaceable_items_without_inbox_churn(service,
                                                             client):
    """Prefetched items beyond worker capacity used to be re-cycled
    through the whole inbox (O(n²) churn); they now park in the side
    deque — every item enters the inbox exactly once."""
    fid = client.register_function(lambda d: time.sleep(0.05), name="s50ms")
    eid, agent = service.make_endpoint(
        client.token, "ep", n_managers=1, workers_per_manager=2,
        manager_kw={"prefetch": 8})
    mgr = list(agent.managers.values())[0]
    puts = []
    orig_put = mgr.inbox.put

    def counting_put(item):
        puts.append(item.task_id)
        orig_put(item)

    mgr.inbox.put = counting_put
    n = 16
    ids = client.batch_run([(fid, eid, {}) for _ in range(n)])
    assert client.get_batch_results(ids, timeout=60) == [None] * n
    assert len(puts) == n                        # one inbox entry per item
    assert mgr.deferrals > 0                     # parking actually happened
    assert not mgr._deferred
    agent.stop()
