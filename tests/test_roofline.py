"""Roofline analysis unit tests: HLO collective parsing, ring-cost math,
model-flops conventions, op-byte attribution."""
import pytest

from repro.roofline import analyze, model_flops, parse_collectives
from repro.roofline.analysis import parse_op_bytes

HLO = """
HloModule test
  %ar = f32[128,1024]{1,0} all-reduce(f32[128,1024]{1,0} %x), replica_groups={{0,1,2,3}}, to_apply=%add
  %ag = bf16[256,512]{1,0} all-gather(bf16[64,512]{1,0} %y), replica_groups=[32,8]<=[256], dimensions={0}
  %rs = f32[32,16]{1,0} reduce-scatter(f32[128,16]{1,0} %z), replica_groups={{0,1,2,3}}, dimensions={0}
  %cp = f32[64]{0} collective-permute(f32[64]{0} %w), source_target_pairs={{0,1},{1,0}}
  %a2a = f32[16,16]{1,0} all-to-all(f32[16,16]{1,0} %v), replica_groups={{0,1}}
  %dot = f32[128,128]{1,0} dot(f32[128,64]{1,0} %a, f32[64,128]{1,0} %b)
  %cv = bf16[1000]{0} convert(f32[1000]{0} %c)
"""


def test_parse_collectives_counts():
    st = parse_collectives(HLO)
    assert st.counts == {"all-reduce": 1, "all-gather": 1,
                         "reduce-scatter": 1, "collective-permute": 1,
                         "all-to-all": 1}


def test_ring_cost_formulas():
    st = parse_collectives(HLO)
    ar = 128 * 1024 * 4
    assert st.bytes_by_kind["all-reduce"] == ar
    # all-reduce wire = 2·S·(n-1)/n with n=4
    ag = 256 * 512 * 2
    rs = 32 * 16 * 4
    cp = 64 * 4
    a2a = 16 * 16 * 4
    expect = (2 * ar * 3 / 4            # all-reduce n=4
              + ag * 7 / 8              # all-gather n=8 (iota groups)
              + rs * 3                  # reduce-scatter out×(n-1), n=4
              + cp                      # permute
              + a2a * 1 / 2)            # all-to-all n=2
    assert st.wire_bytes == pytest.approx(expect)


def test_iota_replica_groups_size():
    st = parse_collectives(
        "%ag = f32[8]{0} all-gather(f32[1]{0} %x), replica_groups=[2,128]<=[256]")
    # [groups, group_size] → n = 128
    assert st.wire_bytes == pytest.approx(8 * 4 * 127 / 128)


def test_async_pairs_not_double_counted():
    hlo = """
      %s = f32[64]{0} all-gather-start(f32[16]{0} %x), replica_groups={{0,1,2,3}}
      %d = f32[64]{0} all-gather-done(f32[64]{0} %s)
    """
    st = parse_collectives(hlo)
    assert st.counts.get("all-gather", 0) == 1


def test_parse_op_bytes_attribution():
    ob = parse_op_bytes(HLO)
    assert ob["convert"] == 1000 * 2
    assert ob["dot"] == 128 * 128 * 4
    assert ob["all-reduce"] == 128 * 1024 * 4


def test_model_flops_conventions():
    n, b, s = 1_000_000, 8, 128
    assert model_flops("train", n, b, s) == 6.0 * n * b * s
    assert model_flops("prefill", n, b, s) == 2.0 * n * b * s
    assert model_flops("decode", n, b, s) == 2.0 * n * b
    with pytest.raises(ValueError):
        model_flops("nope", n, b, s)


def test_analyze_bottleneck_and_fraction():
    cost = {"flops": 197e12, "bytes accessed": 0.0}    # exactly 1 s compute
    r = analyze(cost, "", n_devices=1, model_flops_global=197e12)
    assert r.bottleneck == "compute"
    assert r.roofline_fraction == pytest.approx(1.0)
    assert r.useful_compute_ratio == pytest.approx(1.0)
    # memory-bound case
    cost = {"flops": 197e11, "bytes accessed": 819e9 * 2}
    r = analyze(cost, "", n_devices=1, model_flops_global=197e11)
    assert r.bottleneck == "memory"
    assert r.roofline_fraction == pytest.approx(0.05)
