"""Inter-endpoint transfers (paper §5.1, Globus analogue) + staging."""
import numpy as np
import pytest

from repro.data import (
    DataRef,
    InMemoryKVStore,
    SharedFSStore,
    TransferService,
    TransferStatus,
    resolve_inputs,
    stage_outputs,
)


@pytest.fixture
def fabric(tmp_path):
    ts = TransferService()
    a, b = InMemoryKVStore(), SharedFSStore(str(tmp_path / "b"))
    ts.register_endpoint("ep-a", a)
    ts.register_endpoint("ep-b", b)
    return ts, a, b


def test_transfer_roundtrip(fabric):
    ts, a, b = fabric
    payload = {"arr": np.arange(1000, dtype=np.float32)}
    a.set("data/x", payload)
    tid = ts.submit("ep-a", "data/x", "ep-b", sync=True)
    rec = ts.status(tid)
    assert rec.status == TransferStatus.SUCCEEDED
    assert rec.checksum_ok
    assert rec.bytes_done == rec.bytes_total > 0
    out = b.get("data/x")
    np.testing.assert_array_equal(out["arr"], payload["arr"])


def test_async_transfer_wait(fabric):
    ts, a, b = fabric
    a.set("k", np.zeros(10_000))
    tid = ts.submit("ep-a", "k", "ep-b")
    rec = ts.wait(tid, timeout=10)
    assert rec.status == TransferStatus.SUCCEEDED


def test_transfer_missing_key_fails(fabric):
    ts, a, b = fabric
    tid = ts.submit("ep-a", "missing", "ep-b", sync=True)
    assert ts.status(tid).status == TransferStatus.FAILED
    assert "KeyError" in ts.status(tid).error


def test_chunked_bandwidth_cap(tmp_path):
    ts = TransferService(chunk_bytes=1024, bandwidth_bps=10e6)
    a, b = InMemoryKVStore(), InMemoryKVStore()
    ts.register_endpoint("a", a)
    ts.register_endpoint("b", b)
    a.set("k", np.zeros(100_000, np.uint8))
    tid = ts.submit("a", "k", "b", sync=True)
    rec = ts.status(tid)
    assert rec.status == TransferStatus.SUCCEEDED
    # ≥ bytes/bw seconds must have elapsed
    assert rec.duration >= rec.bytes_total / 10e6 * 0.8


def test_dataref_uri_roundtrip():
    ref = DataRef("globus", "ep-1", "path/to/obj")
    assert DataRef.parse(ref.uri()) == ref


def test_resolve_inputs_intra_and_inter(fabric):
    ts, a, b = fabric
    a.set("local", 1)
    b.set("remote", 2)
    payload = {"x": DataRef("kv", "ep-a", "local"),
               "nested": [DataRef("globus", "ep-b", "remote")],
               "plain": 3}
    out = resolve_inputs(payload, "ep-a", a, ts)
    assert out == {"x": 1, "nested": [2], "plain": 3}


def test_stage_outputs_threshold(fabric):
    ts, a, b = fabric
    small = stage_outputs({"v": 1}, "ep-a", a, "t1", limit=10_000)
    assert small == {"v": 1}
    # incompressible payload: the limit applies to transported bytes
    data = np.random.default_rng(0).standard_normal(1 << 17)
    big = stage_outputs(data, "ep-a", a, "t2", limit=10_000)
    assert isinstance(big, DataRef)
    np.testing.assert_array_equal(a.get(big.key), data)
