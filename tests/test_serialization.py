"""Serialization facade (paper §4.5): speed-ordered methods, headered
buffers, routing tags, bf16 arrays, compression."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # not in all images
from hypothesis import given, settings, strategies as st

from repro.serialization import pack, peek_tag, unpack, unpack_full
from repro.serialization.facade import _METHODS


def test_roundtrip_plain():
    for obj in [None, True, 42, 3.14, "hi", b"raw", [1, 2, 3],
                {"a": 1, "b": [2, {"c": 3}]}, (1, "x")]:
        out, tag = unpack(pack(obj, tag="t"))
        assert out == obj
        assert tag == "t"


def test_roundtrip_arrays():
    import ml_dtypes
    for dtype in [np.float32, np.int32, np.float64, ml_dtypes.bfloat16]:
        arr = np.arange(24, dtype=dtype).reshape(2, 3, 4)
        out, _ = unpack(pack(arr))
        assert out.dtype == arr.dtype
        np.testing.assert_array_equal(np.asarray(out, np.float64),
                                      np.asarray(arr, np.float64))


def test_roundtrip_pytree_of_arrays():
    obj = {"w": np.ones((3, 3), np.float32),
           "meta": {"step": 7, "name": "x"},
           "pair": (np.zeros(2), [1, 2])}
    out, _, method = unpack_full(pack(obj))
    assert method == "nd"
    np.testing.assert_array_equal(out["w"], obj["w"])
    assert out["meta"] == obj["meta"]
    assert isinstance(out["pair"], tuple)


def test_jax_arrays_go_host():
    import jax.numpy as jnp
    obj = {"x": jnp.arange(5)}
    out, _ = unpack(pack(obj))
    assert isinstance(out["x"], np.ndarray)
    np.testing.assert_array_equal(out["x"], np.arange(5))


def test_pickle_fallback_for_custom_objects():
    # complex is not representable by nd/msgpack/json → pickle fallback
    out, _, method = unpack_full(pack(complex(3, 4)))
    assert method == "pickle"
    assert out == complex(3, 4)
    # same for exceptions (funcX serializes tracebacks/exceptions)
    err, _, method = unpack_full(pack(ValueError("boom")))
    assert method == "pickle"
    assert isinstance(err, ValueError) and err.args == ("boom",)


def test_dataclasses_round_trip_as_objects():
    """Regression: orjson must not silently degrade dataclasses to dicts —
    DataRefs inside payloads have to survive as objects (via pickle)."""
    from repro.data import DataRef
    ref = DataRef("globus", "ep-1", "k")
    out, _, method = unpack_full(pack({"arr": ref}))
    assert method == "pickle"
    assert isinstance(out["arr"], DataRef) and out["arr"] == ref


def test_method_order_is_speed_sorted():
    assert _METHODS.index("nd") < _METHODS.index("pickle")
    assert _METHODS.index("msgpack") < _METHODS.index("pickle")


def test_peek_tag_without_deserializing():
    buf = pack({"big": np.zeros(1000)}, tag="endpoint-42/result")
    assert peek_tag(buf) == "endpoint-42/result"


def test_compression_large_buffer():
    arr = np.zeros(2 << 20, np.uint8)   # compressible
    buf = pack(arr)
    assert len(buf) < arr.nbytes // 10
    out, _ = unpack(buf)
    np.testing.assert_array_equal(out, arr)


json_like = st.recursive(
    st.none() | st.booleans() | st.integers(-2**40, 2**40) |
    st.floats(allow_nan=False, allow_infinity=False, width=32) |
    st.text(max_size=16),
    lambda kids: st.lists(kids, max_size=4) |
    st.dictionaries(st.text(max_size=8), kids, max_size=4),
    max_leaves=12)


@settings(max_examples=40, deadline=None)
@given(json_like)
def test_property_roundtrip(obj):
    out, _ = unpack(pack(obj))
    assert out == obj


@settings(max_examples=20, deadline=None)
@given(st.binary(max_size=256), st.text(max_size=32))
def test_property_bytes_and_tags(data, tag):
    out, t = unpack(pack(data, tag=tag))
    assert out == data and t == tag
