"""Container warming (paper §6.1/§6.2): warm cache policies + proportional
allocation."""
import time

import pytest

pytest.importorskip("hypothesis")  # not in all images
from hypothesis import given, settings, strategies as st

from repro.core import ContainerRegistry, ContainerSpec, WarmCache
from repro.core.warming import proportional_allocation


@pytest.fixture
def registry():
    r = ContainerRegistry()
    r.register(ContainerSpec("A", build=lambda: "envA"))
    r.register(ContainerSpec("B", build=lambda: "envB"))
    r.register(ContainerSpec("slow", simulated_cold_start=0.05))
    return r


def test_cold_then_warm(registry):
    c = WarmCache(registry, slots=2)
    _, cold1 = c.get_or_build("A")
    _, cold2 = c.get_or_build("A")
    assert cold1 and not cold2
    assert c.stats.cold_starts == 1 and c.stats.warm_hits == 1


def test_simulated_cold_start_cost(registry):
    c = WarmCache(registry, slots=1)
    t0 = time.perf_counter()
    c.get_or_build("slow")
    assert time.perf_counter() - t0 >= 0.05
    t0 = time.perf_counter()
    c.get_or_build("slow")
    assert time.perf_counter() - t0 < 0.02


def test_lru_eviction(registry):
    c = WarmCache(registry, slots=1)
    c.get_or_build("A")
    c.get_or_build("B")              # evicts A
    assert c.warm_types() == ["B"]
    assert c.stats.evictions == 1
    _, cold = c.get_or_build("A")    # cold again
    assert cold


def test_idle_reap(registry):
    c = WarmCache(registry, slots=4, idle_timeout=0.05)
    c.get_or_build("A")
    assert c.reap() == 0
    time.sleep(0.08)
    assert c.reap() == 1             # paper §6.1: release after idle timeout
    assert c.warm_types() == []


def test_unknown_type_gets_bare_container(registry):
    c = WarmCache(registry, slots=1)
    cont, cold = c.get_or_build("unseen-type")
    assert cold and cont.env is None


# ---- proportional allocation (paper §6.2) ---------------------------------

def test_proportional_example_from_paper():
    # "if 30% of tasks are type A and manager can spawn 10 containers,
    #  spawn 3 of type A"
    alloc = proportional_allocation({"A": 30, "B": 70}, 10)
    assert alloc["A"] == 3 and alloc["B"] == 7


@settings(max_examples=60, deadline=None)
@given(st.dictionaries(st.sampled_from("ABCDEF"),
                       st.integers(1, 1000), min_size=1, max_size=6),
       st.integers(1, 32))
def test_proportional_invariants(mix, slots):
    alloc = proportional_allocation(mix, slots)
    assert sum(alloc.values()) == min(slots, max(slots, 0)) or \
        sum(alloc.values()) <= slots + len(mix)
    # never allocates to absent types
    assert set(alloc) <= set(mix)
    # monotone-ish: the max-count type gets at least the min-count type
    if len(mix) >= 2 and slots >= len(mix):
        hi = max(mix, key=mix.get)
        lo = min(mix, key=mix.get)
        assert alloc.get(hi, 0) >= alloc.get(lo, 0)


def test_proportional_exact_sum():
    for slots in (1, 3, 7, 10):
        alloc = proportional_allocation({"A": 5, "B": 3, "C": 2}, slots)
        assert sum(alloc.values()) == slots
