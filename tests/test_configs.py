"""Config registry: the assigned 40-cell grid, published dimensions, skip
logic, and input-spec construction (no allocation)."""
import jax
import pytest

from repro.configs import (
    ARCH_IDS,
    SHAPES,
    cells,
    get_config,
    get_reduced_config,
    get_shape,
    runnable_cells,
)
from repro.models import decode_cache_kwargs, get_model, input_specs


def test_grid_is_40_cells():
    all_cells = list(cells())
    assert len(all_cells) == 40                      # 10 archs × 4 shapes
    skipped = [c for c in all_cells if not c.runnable]
    assert len(skipped) == 8                         # long_500k × 8 full-attn
    assert all(c.shape == "long_500k" for c in skipped)
    runnable = {(c.arch, c.shape) for c in runnable_cells()}
    assert ("mamba2-370m", "long_500k") in runnable
    assert ("recurrentgemma-9b", "long_500k") in runnable


PUBLISHED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "qwen1.5-110b": (80, 8192, 64, 8, 49152, 152064),
    "phi4-mini-3.8b": (32, 3072, 24, 8, 8192, 200064),
    "qwen1.5-0.5b": (24, 1024, 16, 16, 2816, 151936),
    "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
    "qwen2-vl-7b": (28, 3584, 28, 4, 18944, 152064),
    "recurrentgemma-9b": (38, 4096, 16, 1, 12288, 256000),
    "granite-moe-1b-a400m": (24, 1024, 16, 8, 512, 49155),
    "llama4-scout-17b-a16e": (48, 5120, 40, 8, 8192, 202048),
    "seamless-m4t-large-v2": (24, 1024, 16, 16, 8192, 256206),
    "mamba2-370m": (48, 1024, 0, 0, 0, 50280),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_published_dims_exact(arch):
    cfg = get_config(arch)
    L, d, H, KVH, ff, V = PUBLISHED[arch]
    assert (cfg.n_layers, cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.d_ff, cfg.vocab_size) == (L, d, H, KVH, ff, V)


PARAM_RANGES = {
    "qwen1.5-110b": (100e9, 120e9),
    "phi4-mini-3.8b": (3.5e9, 4.2e9),
    "qwen1.5-0.5b": (0.4e9, 0.65e9),
    "minicpm3-4b": (3.8e9, 4.7e9),
    "qwen2-vl-7b": (6.8e9, 8.3e9),
    "recurrentgemma-9b": (7.8e9, 9.8e9),
    "granite-moe-1b-a400m": (1.2e9, 1.5e9),
    "llama4-scout-17b-a16e": (95e9, 112e9),
    "seamless-m4t-large-v2": (1.6e9, 2.4e9),
    "mamba2-370m": (0.35e9, 0.5e9),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_param_count_matches_published_size(arch):
    model = get_model(get_config(arch))
    lo, hi = PARAM_RANGES[arch]
    assert lo <= model.param_count() <= hi
    assert model.active_param_count() <= model.param_count()


def test_moe_active_counts():
    g = get_model(get_config("granite-moe-1b-a400m"))
    assert 0.3e9 <= g.active_param_count() <= 0.6e9          # "a400m"
    s = get_model(get_config("llama4-scout-17b-a16e"))
    assert 8e9 <= s.active_param_count() <= 18e9             # "17b" active


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("shape_name", list(SHAPES))
def test_input_specs_no_allocation(arch, shape_name):
    cfg = get_config(arch)
    shape = get_shape(shape_name)
    specs = input_specs(cfg, shape)
    for name, s in specs.items():
        assert isinstance(s, jax.ShapeDtypeStruct), (name, type(s))
        assert s.shape[0] == shape.global_batch
    if shape.kind == "decode":
        assert specs["tokens"].shape == (shape.global_batch, 1)
        # abstract caches build via eval_shape — no device memory
        model = get_model(cfg)
        cache = model.abstract_cache(**decode_cache_kwargs(cfg, shape))
        assert all(isinstance(l, jax.ShapeDtypeStruct)
                   for l in jax.tree.leaves(cache))


def test_reduced_configs_are_small():
    for arch in ARCH_IDS:
        model = get_model(get_reduced_config(arch))
        assert model.param_count() < 2e6, arch     # CPU-friendly
