"""Sharding rules: logical→mesh mapping, divisibility fallback, duplicate
axis prevention. (Production meshes are exercised by launch/dryrun.py.)"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.sharding import ShardCtx, default_rules, spec_for, tree_shardings


@pytest.fixture
def mesh():
    dev = np.array(jax.devices()[:1]).reshape(1, 1)
    return Mesh(dev, ("data", "model"))


def test_spec_basic(mesh):
    rules = default_rules()
    s = spec_for(("embed", "ffn"), (1024, 4096), mesh, rules)
    assert s == P("data", "model")


def test_divisibility_fallback(mesh):
    rules = {"x": ("data",), "y": ("model",)}
    dev = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    m = Mesh(dev, ("data", "model"))
    # 10 not divisible by 4 → replicate that dim
    s = spec_for(("x", "y"), (10, 16), m, rules)
    assert s == P(None, "model")


def test_batch_one_replicates():
    """long_500k (batch=1) degrades to replication automatically."""
    dev = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    m = Mesh(dev, ("data", "model"))
    rules = default_rules()
    s = spec_for(("act_batch", None, None), (1, 524288, 64), m, rules)
    assert s == P()


def test_no_duplicate_mesh_axes(mesh):
    dev = np.array(jax.devices() * 16)[:16].reshape(4, 4)
    m = Mesh(dev, ("data", "model"))
    rules = {"a": ("data",), "b": ("data", "model")}
    s = spec_for(("a", "b"), (8, 8), m, rules)
    # "data" already used by dim 0 → dim 1 only gets "model"
    assert s == P("data", "model")


def test_missing_mesh_axis_ignored(mesh):
    rules = {"batch": ("pod", "data")}        # no "pod" on this mesh
    dev = np.array(jax.devices() * 4)[:4].reshape(4,)
    m = Mesh(dev.reshape(4, 1), ("data", "model"))
    s = spec_for(("batch",), (8,), m, rules)
    assert s == P("data")


def test_multi_axis_prefix_fallback():
    """(pod,data)=8 doesn't divide 4 → falls back to the pod prefix (2)."""
    dev = np.array(jax.devices() * 8)[:8].reshape(2, 4, 1)
    m = Mesh(dev, ("pod", "data", "model"))
    rules = {"batch": ("pod", "data")}
    s = spec_for(("batch",), (4,), m, rules)
    # 4 % (2*4) != 0 but 4 % 2 == 0 → shard over pod only
    assert s == P("pod")


def test_tree_shardings_structure(mesh):
    rules = default_rules()
    axes = {"w": ("embed", "ffn"), "b": ("ffn",)}
    shapes = {"w": jax.ShapeDtypeStruct((64, 128), jnp.float32),
              "b": jax.ShapeDtypeStruct((128,), jnp.float32)}
    shd = tree_shardings(axes, shapes, mesh, rules)
    assert shd["w"].spec == P("data", "model")
    assert shd["b"].spec == P("model")


def test_shard_ctx_noop_without_mesh():
    ctx = ShardCtx()
    x = jnp.ones((4, 4))
    assert ctx.constrain(x, ("act_batch", None)) is x


def test_shard_ctx_constrain_compiles(mesh):
    ctx = ShardCtx(mesh, default_rules())
    @jax.jit
    def f(x):
        return ctx.constrain(x, ("act_batch", "act_embed")) * 2
    out = f(jnp.ones((4, 8)))
    np.testing.assert_array_equal(out, 2 * np.ones((4, 8)))


def test_policy_variants_differ():
    fsdp = default_rules("fsdp")
    tp = default_rules("fsdp_tp")
    dp = default_rules("dp")
    assert fsdp["act_heads"] == ()
    assert tp["act_heads"] == ("model",)
    assert dp["embed"] == ()
    with pytest.raises(ValueError):
        default_rules("nope")
