"""End-to-end behaviour tests for the paper's system: the full federated
path — register function → submit through the cloud service → forwarder →
endpoint → warm container → result — including a real JAX model served
through the FaaS layer and a MapReduce job using the intra-endpoint store."""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import ContainerSpec
from repro.data import DataRef


def test_model_serving_through_faas(service, client):
    """Serve a real (reduced) model: cold start == JIT compile; warm
    requests reuse the executable cache (the paper's container story)."""
    from repro.configs import get_reduced_config
    from repro.models import get_model
    from repro.models.knobs import RunKnobs
    from repro.serve import make_prefill

    cfg = get_reduced_config("qwen1.5-0.5b")
    model = get_model(cfg)

    def build():
        params = model.init(jax.random.PRNGKey(0))
        prefill = jax.jit(make_prefill(model,
                                       knobs=RunKnobs(q_block=16,
                                                      kv_block=16)))
        return {"params": params, "prefill": prefill}

    service.register_container(ContainerSpec("model/qwen-smoke",
                                             build=build))

    def serve(data, env):
        toks = jnp.asarray(np.asarray(data["tokens"]), jnp.int32)
        logits, _ = env["prefill"](env["params"], {"tokens": toks})
        return {"argmax": np.asarray(jnp.argmax(logits, -1))}

    fid = client.register_function(serve, container_type="model/qwen-smoke")
    eid, agent = service.make_endpoint(client.token, "tpu-pod",
                                       n_managers=1, workers_per_manager=1)
    toks = np.zeros((2, 8), np.int32)
    t0 = time.perf_counter()
    r1 = client.get_result(client.run(fid, eid, data={"tokens": toks}),
                           timeout=120)
    cold_t = time.perf_counter() - t0
    t0 = time.perf_counter()
    r2 = client.get_result(client.run(fid, eid, data={"tokens": toks}),
                           timeout=120)
    warm_t = time.perf_counter() - t0
    np.testing.assert_array_equal(r1["argmax"], r2["argmax"])
    assert warm_t * 3 < cold_t        # warm >> faster than JIT cold start
    agent.stop()


def test_mapreduce_wordcount_with_store(service, client):
    """MapReduce through the FaaS layer + intra-endpoint store (§7.3.1):
    map tasks shuffle word counts via the store, reduce tasks merge."""
    texts = ["the cat sat on the mat", "the dog ate the bone",
             "a cat and a dog"]

    def map_fn(data):
        from collections import Counter
        return dict(Counter(data["text"].split()))

    def reduce_fn(data):
        total = {}
        for part in data["parts"]:
            for w, c in part.items():
                total[w] = total.get(w, 0) + c
        return total

    mid = client.register_function(map_fn)
    rid = client.register_function(reduce_fn)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1,
                                       workers_per_manager=3)
    parts = client.map(mid, eid, [{"text": t} for t in texts], timeout=30)
    total = client.get_result(
        client.run(rid, eid, data={"parts": parts}), timeout=30)
    assert total["the"] == 4 and total["cat"] == 2 and total["dog"] == 2
    agent.stop()


def test_inter_endpoint_dataref_flow(service, client):
    """Function output staged on endpoint A, consumed by a function on
    endpoint B via DataRef + transfer service (paper §5.1)."""
    eidA, agentA = service.make_endpoint(client.token, "A", n_managers=1)
    eidB, agentB = service.make_endpoint(client.token, "B", n_managers=1)

    def produce(data):
        return np.arange(int(data["n"]), dtype=np.float32)

    def consume(data):
        return float(np.sum(np.asarray(data["arr"])))

    pid = client.register_function(produce)
    cid = client.register_function(consume)
    arr = client.get_result(client.run(pid, eidA, data={"n": 10}),
                            timeout=30)
    # stash on A's store and hand B a ref
    storeA = service.transfer.store_for(eidA)
    storeA.set("results/arr", arr)
    out = client.get_result(
        client.run(cid, eidB,
                   data={"arr": DataRef("globus", eidA, "results/arr")}),
        timeout=30)
    assert out == float(np.arange(10).sum())
    agentA.stop()
    agentB.stop()


def test_throughput_smoke(service, client):
    """A few hundred no-op tasks flow end to end (scaled-down §7.2)."""
    fid = client.register_function(lambda d: None)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=4,
                                       workers_per_manager=4)
    n = 300
    t0 = time.perf_counter()
    ids = client.batch_run([(fid, eid, {}) for _ in range(n)])
    client.get_batch_results(ids, timeout=60)
    dt = time.perf_counter() - t0
    rate = n / dt
    assert rate > 100, f"throughput too low: {rate:.0f}/s"
    agent.stop()
