"""FuncXExecutor + SubmitCoalescer (DESIGN.md §8): futures-native
submission, client-side submit coalescing, harvest lifecycle."""
import threading

import pytest

from repro.core import FuncXExecutor, SubmitCoalescer, TaskFailure
from tests.conftest import wait_until


def square(data):
    return data["x"] * data["x"]


def boom(data):
    raise ValueError("deliberate failure: " + data["msg"])


@pytest.fixture
def endpoint(service, client):
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=2,
                                       workers_per_manager=4)
    yield eid
    agent.stop()


# ---------------------------------------------------------------- basics
class TestExecutorBasics:
    def test_submit_returns_real_future(self, client, endpoint):
        with client.executor(endpoint_id=endpoint) as ex:
            fut = ex.submit(square, {"x": 7})
            from concurrent.futures import Future
            assert isinstance(fut, Future)
            assert fut.result(timeout=10) == 49

    def test_callable_registered_once(self, client, endpoint):
        with client.executor(endpoint_id=endpoint) as ex:
            assert ex.submit(square, {"x": 2}).result(timeout=10) == 4
            assert ex.submit(square, {"x": 3}).result(timeout=10) == 9
            assert len(ex._fn_ids) == 1

    def test_submit_by_function_id_string(self, client, endpoint):
        fid = client.register_function(square)
        with client.executor(endpoint_id=endpoint) as ex:
            assert ex.submit(fid, {"x": 5}).result(timeout=10) == 25

    def test_per_submit_endpoint_override(self, service, client):
        eid_a, agent_a = service.make_endpoint(client.token, "a",
                                               workers_per_manager=2)
        eid_b, agent_b = service.make_endpoint(client.token, "b",
                                               workers_per_manager=2)
        try:
            with client.executor(endpoint_id=eid_a) as ex:
                fa = ex.submit(square, {"x": 2})
                fb = ex.submit(square, {"x": 3}, endpoint_id=eid_b)
                assert fa.result(timeout=10) == 4
                assert fb.result(timeout=10) == 9
        finally:
            agent_a.stop()
            agent_b.stop()

    def test_routed_when_no_endpoint(self, service, client, endpoint):
        # endpoint_id=None at both construction and submit → the service
        # routes each flush across the federation
        with client.executor() as ex:
            assert ex.submit(square, {"x": 6}).result(timeout=10) == 36

    def test_map_preserves_input_order(self, client, endpoint):
        with client.executor(endpoint_id=endpoint) as ex:
            out = ex.map(square, [{"x": i} for i in range(20)])
        assert out == [i * i for i in range(20)]


# ----------------------------------------------------------- error paths
class TestExceptionPropagation:
    def test_remote_failure_sets_future_exception(self, client, endpoint):
        with client.executor(endpoint_id=endpoint) as ex:
            fut = ex.submit(boom, {"msg": "kaput"})
            with pytest.raises(TaskFailure, match="kaput"):
                fut.result(timeout=10)

    def test_failure_does_not_poison_neighbours(self, client, endpoint):
        # a failed task resolves only ITS future; tasks coalesced into
        # the same flush still succeed
        with client.executor(endpoint_id=endpoint) as ex:
            futs = [ex.submit(square, {"x": i}) for i in range(5)]
            bad = ex.submit(boom, {"msg": "one bad apple"})
            assert [f.result(timeout=10) for f in futs] == \
                [i * i for i in range(5)]
            with pytest.raises(TaskFailure):
                bad.result(timeout=10)

    def test_submit_flush_error_resolves_futures(self, client, endpoint):
        # a flush that fails at the service (unknown endpoint) must not
        # strand its futures — the exception propagates into each one
        with client.executor(endpoint_id="no-such-endpoint") as ex:
            fut = ex.submit(square, {"x": 1})
            with pytest.raises(Exception):
                fut.result(timeout=10)


# ----------------------------------------------------------------- cancel
class TestCancel:
    def test_cancel_before_flush_skips_task(self, service, client,
                                            endpoint):
        ex = client.executor(endpoint_id=endpoint)
        try:
            before = service.submitted
            # hold the coalescer's flush lock so the entry stays parked
            with ex.coalescer._flush_lock:
                fut = ex.submit(square, {"x": 1})
                assert fut.cancel()
            assert wait_until(lambda: ex.tasks_cancelled == 1)
            assert fut.cancelled()
            assert service.submitted == before  # never became a task
        finally:
            ex.shutdown(wait=False)

    def test_cancel_after_flush_fails(self, client, endpoint):
        with client.executor(endpoint_id=endpoint) as ex:
            fut = ex.submit(square, {"x": 4})
            # lone submit flushes inline → already RUNNING (or done)
            assert not fut.cancel()
            assert fut.result(timeout=10) == 16


# --------------------------------------------------------------- shutdown
class TestShutdown:
    def test_shutdown_wait_drains_everything(self, client, endpoint):
        ex = client.executor(endpoint_id=endpoint)
        futs = [ex.submit(square, {"x": i}) for i in range(40)]
        ex.shutdown(wait=True)
        assert all(f.done() for f in futs)
        assert [f.result() for f in futs] == [i * i for i in range(40)]

    def test_shutdown_nowait_returns_then_completes(self, client,
                                                    endpoint):
        ex = client.executor(endpoint_id=endpoint)
        futs = [ex.submit(square, {"x": i}) for i in range(40)]
        ex.shutdown(wait=False)
        # futures keep resolving on the harvest thread after return
        assert [f.result(timeout=10) for f in futs] == \
            [i * i for i in range(40)]

    def test_submit_after_shutdown_raises(self, client, endpoint):
        ex = client.executor(endpoint_id=endpoint)
        ex.shutdown(wait=True)
        with pytest.raises(RuntimeError):
            ex.submit(square, {"x": 1})

    def test_shutdown_cancel_futures_cancels_parked(self, service, client,
                                                    endpoint):
        ex = client.executor(endpoint_id=endpoint)
        before = service.submitted
        with ex.coalescer._flush_lock:       # park the entry
            fut = ex.submit(square, {"x": 1})
            t = threading.Thread(
                target=lambda: ex.shutdown(wait=True, cancel_futures=True))
            t.start()
        t.join(timeout=5)
        assert not t.is_alive()
        assert fut.cancelled()
        assert service.submitted == before


# ------------------------------------------------------ coalescing + storm
class TestCoalescing:
    def test_storm_amortizes_submit_envelopes(self, service, client,
                                              endpoint):
        """16 threads × 50 submits → ≤1/8 submit envelopes per task
        (ISSUE acceptance), every result correct."""
        n_threads, per_thread = 16, 50
        env0, sub0 = service.submit_envelopes, service.submitted
        with client.executor(endpoint_id=endpoint) as ex:
            all_futs, lock = [], threading.Lock()

            def storm(base):
                futs = [ex.submit(square, {"x": base + i})
                        for i in range(per_thread)]
                with lock:
                    all_futs.extend(futs)

            threads = [threading.Thread(target=storm,
                                        args=(k * per_thread,))
                       for k in range(n_threads)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            results = sorted(f.result(timeout=30) for f in all_futs)
        tasks = service.submitted - sub0
        envelopes = service.submit_envelopes - env0
        assert tasks == n_threads * per_thread
        assert envelopes / tasks <= 1 / 8, \
            f"{envelopes} envelopes for {tasks} tasks"
        assert results == sorted(i * i
                                 for i in range(n_threads * per_thread))

    def test_lone_submit_is_one_envelope(self, service, client, endpoint):
        # idle line → inline flush: exactly one envelope, no linger wait
        with client.executor(endpoint_id=endpoint) as ex:
            env0 = service.submit_envelopes
            assert ex.submit(square, {"x": 3}).result(timeout=10) == 9
            assert service.submit_envelopes - env0 == 1
            assert ex.coalescer.flushes == 1

    def test_mixed_endpoints_grouped_per_flush(self, service, client):
        # one flush containing two endpoints lands one envelope per
        # endpoint group (submit_packed_batch groups by resolved id)
        eid_a, agent_a = service.make_endpoint(client.token, "a",
                                               workers_per_manager=2)
        eid_b, agent_b = service.make_endpoint(client.token, "b",
                                               workers_per_manager=2)
        try:
            ex = client.executor()
            env0 = service.submit_envelopes
            with ex.coalescer._flush_lock:   # force one combined flush
                futs = [ex.submit(square, {"x": i},
                                  endpoint_id=eid_a if i % 2 else eid_b)
                        for i in range(8)]
            assert [f.result(timeout=10) for f in futs] == \
                [i * i for i in range(8)]
            assert service.submit_envelopes - env0 == 2
            ex.shutdown(wait=True)
        finally:
            agent_a.stop()
            agent_b.stop()


# -------------------------------------------------------- harvest lifecycle
class TestHarvestLifecycle:
    def test_harvester_stops_at_zero_outstanding(self, client, endpoint):
        ex = client.executor(endpoint_id=endpoint)
        ex.harvest_grace = 0.05              # shrink the linger for test
        try:
            assert not ex.harvest_running    # no thread before first use
            assert ex.submit(square, {"x": 2}).result(timeout=10) == 4
            assert ex.harvest_running        # lingers through the grace
            assert wait_until(lambda: not ex.harvest_running, timeout=5)
            assert ex.outstanding() == 0
            # next submit restarts it
            assert ex.submit(square, {"x": 3}).result(timeout=10) == 9
            assert wait_until(lambda: not ex.harvest_running, timeout=5)
        finally:
            ex.shutdown(wait=True)

    def test_executor_usable_across_harvest_restarts(self, client,
                                                     endpoint):
        ex = client.executor(endpoint_id=endpoint)
        ex.harvest_grace = 0.02
        try:
            for wave in range(3):
                futs = [ex.submit(square, {"x": i}) for i in range(8)]
                assert [f.result(timeout=10) for f in futs] == \
                    [i * i for i in range(8)]
                wait_until(lambda: not ex.harvest_running, timeout=5)
        finally:
            ex.shutdown(wait=True)


# ------------------------------------------------- SubmitCoalescer unit level
class TestSubmitCoalescer:
    def test_idle_line_flushes_inline(self):
        shipped = []
        c = SubmitCoalescer(shipped.append, batch_size=8)
        try:
            c.add("a")                       # idle → flushed on this thread
            assert shipped == [["a"]]
            assert c.pending() == 0
        finally:
            c.close()

    def test_loaded_line_batches(self):
        shipped = []
        c = SubmitCoalescer(shipped.append, batch_size=8, linger=0.005,
                            outstanding=lambda: 1)   # wave in flight
        try:
            for i in range(20):
                c.add(i)
            assert wait_until(lambda: sum(len(b) for b in shipped) == 20)
            assert len(shipped) < 20         # actually coalesced
            assert max(len(b) for b in shipped) <= 8
        finally:
            c.close()

    def test_close_drains_parked(self):
        shipped = []
        c = SubmitCoalescer(shipped.append, batch_size=100, linger=5.0,
                            outstanding=lambda: 1)
        c.add("x")
        c.add("y")
        c.close()                            # long linger: close must drain
        assert sum(len(b) for b in shipped) == 2

    def test_add_after_close_still_ships(self):
        shipped = []
        c = SubmitCoalescer(shipped.append, batch_size=8)
        c.close()
        c.add("late")                        # racing submit at shutdown
        assert sum(len(b) for b in shipped) == 1
