"""Typed wire protocol (DESIGN.md §1): every message kind round-trips
through to_wire/from_wire and survives the serialization facade (the
actual channel transport)."""
import numpy as np
import pytest

from repro.core import (
    Ack,
    Channel,
    Heartbeat,
    ProtocolError,
    ResultBatch,
    ResultMsg,
    TaskBatch,
    TaskSpec,
    from_wire,
    to_wire,
)

MESSAGES = [
    TaskBatch(tasks=[
        TaskSpec(task_id="t1", function_id="f1", container_type="python",
                 payload={"x": 1}, stamps={"endpoint_recv": 1.5}),
        TaskSpec(task_id="t2", function_id="f2", container_type="model/a",
                 payload=None),
    ]),
    Ack(task_ids=["t1", "t2"], t_endpoint_recv=12.25),
    Heartbeat(endpoint_id="ep1", ts=99.0, queued=3, idle_workers=2,
              capacity=8, warm_idle={"python": 2},
              warm_total={"python": 4, "model/a": 1}),
    ResultMsg(task_id="t1", status="SUCCESS", result={"y": 2},
              stamps={"worker_start": 1.0, "worker_end": 2.0},
              cold_start=True, build_time=0.5, worker_id="w0",
              manager_id="m0"),
    ResultMsg(task_id="t2", status="FAILED", error="boom",
              remote_traceback="Traceback ..."),
    ResultMsg(task_id="t3", status="LOST", error="lost after 2 retries"),
    ResultBatch(
        results=[
            ResultMsg(task_id="t1", status="SUCCESS", result={"y": 2},
                      stamps={"worker_start": 1.0}, worker_id="w0",
                      manager_id="m0"),
            ResultMsg(task_id="t2", status="FAILED", error="boom",
                      remote_traceback="Traceback ..."),
        ],
        acks=[Ack(task_ids=["t3", "t4"], t_endpoint_recv=3.5)]),
    ResultBatch(acks=[Ack(task_ids=["t9"], t_endpoint_recv=1.0)]),
]


@pytest.mark.parametrize("msg", MESSAGES, ids=lambda m: type(m).__name__)
def test_roundtrip_direct(msg):
    assert from_wire(to_wire(msg)) == msg


@pytest.mark.parametrize("msg", MESSAGES, ids=lambda m: type(m).__name__)
def test_roundtrip_through_channel(msg):
    ch = Channel()
    assert ch.send_to_service(to_wire(msg), tag=type(msg).kind)
    env, tag = ch.recv_at_service(timeout=1)
    assert tag == type(msg).kind
    assert from_wire(env) == msg


def test_array_payload_roundtrips_through_channel():
    arr = np.arange(12, dtype=np.float32).reshape(3, 4)
    batch = TaskBatch(tasks=[TaskSpec(task_id="t", function_id="f",
                                      container_type="python",
                                      payload={"arr": arr})])
    ch = Channel()
    ch.send_to_endpoint(to_wire(batch), tag="tasks")
    env, _ = ch.recv_at_endpoint(timeout=1)
    out = from_wire(env)
    np.testing.assert_array_equal(out.tasks[0].payload["arr"], arr)


def test_resolved_is_endpoint_internal_only():
    spec = TaskSpec(task_id="t", function_id="f", container_type="python",
                    resolved=(lambda: None, False))
    wire = to_wire(TaskBatch(tasks=[spec]))
    assert "resolved" not in wire["tasks"][0]
    assert from_wire(wire).tasks[0].resolved is None


def test_unknown_wire_type_rejected():
    with pytest.raises(ProtocolError):
        from_wire({"type": "no_such_kind"})
    with pytest.raises(ProtocolError):
        to_wire(object())
