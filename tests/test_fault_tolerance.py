"""Fault tolerance (paper §4.1/§4.3): manager loss → re-execution;
endpoint disconnect → forwarder requeue; retry budget → LOST; straggler
speculation; elastic provisioning; socket-transport faults (mid-frame
disconnect, partial length prefix, reconnect after a service restart)."""
import socket
import struct
import time

import pytest

from repro.core import ElasticStrategy, LocalProvider, SimCloudProvider, SimSlurmProvider, TaskLost, TcpListener
from repro.core.comms import TO_SERVICE
from repro.core.endpoint import demo_sleep, demo_square
from conftest import start_tcp_endpoint, wait_until


def test_manager_kill_reexecutes(service, client):
    def slow(data):
        time.sleep(0.2)
        return data["i"]
    fid = client.register_function(slow)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=2,
                                       workers_per_manager=2,
                                       manager_timeout=0.4)
    ids = client.batch_run([(fid, eid, {"i": i}) for i in range(8)])
    time.sleep(0.15)
    agent.kill_manager(list(agent.managers)[0])
    res = client.get_batch_results(ids, timeout=30)
    assert sorted(res) == list(range(8))
    assert agent.tasks_reexecuted > 0
    agent.stop()


def test_all_managers_dead_then_lost(service, client):
    def slow(data):
        time.sleep(10)
        return 1
    fid = client.register_function(slow)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1,
                                       workers_per_manager=1,
                                       manager_timeout=0.3, max_retries=0)
    tid = client.run(fid, eid, data={})
    time.sleep(0.15)
    agent.kill_manager(list(agent.managers)[0])
    with pytest.raises(TaskLost):
        client.get_result(tid, timeout=30)
    agent.stop()


def test_disconnect_requeues_and_recovers(service, client):
    fid = client.register_function(lambda d: d["i"])
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1,
                                       workers_per_manager=2)
    rec = service.endpoints[eid]
    rec.channel.disconnect()
    ids = client.batch_run([(fid, eid, {"i": i}) for i in range(5)])
    time.sleep(0.5)              # tasks parked service-side
    assert all(not service.get_task(t).done for t in ids)
    rec.channel.reconnect()
    res = client.get_batch_results(ids, timeout=30)
    assert sorted(res) == list(range(5))
    agent.stop()


def test_heartbeat_detects_disconnect(service, client):
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1)
    rec = service.endpoints[eid]
    assert wait_until(lambda: rec.connected, timeout=2)
    rec.channel.disconnect()
    assert wait_until(lambda: not rec.forwarder.endpoint_connected,
                      timeout=3)
    rec.channel.reconnect()
    assert wait_until(lambda: rec.forwarder.endpoint_connected, timeout=3)
    agent.stop()


def test_speculation_rescues_straggler(service, client):
    fid = client.register_function(lambda d: 1)
    eid, agent = service.make_endpoint(
        client.token, "ep", n_managers=2, workers_per_manager=2,
        speculation=True, speculation_min=0.3)
    slow_mgr = list(agent.managers.values())[0]
    for w in slow_mgr.workers:
        w.slowdown = 3.0
    ids = client.batch_run([(fid, eid, {}) for _ in range(16)])
    t0 = time.perf_counter()
    res = client.get_batch_results(ids, timeout=60)
    took = time.perf_counter() - t0
    assert res == [1] * 16
    # without speculation the slow manager's share would cost ~9 s
    # (6 tasks × 3 s / 2 workers); speculation reroutes the stragglers
    assert agent.speculative_dispatches > 0
    assert took < 6.0
    agent.stop()


def test_elastic_scale_out_and_in(service, client):
    def work(data):
        time.sleep(0.05)
        return 0
    fid = client.register_function(work)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=0)
    strat = ElasticStrategy(agent, LocalProvider(workers_per_node=2),
                            min_blocks=1, max_blocks=4, idle_timeout=0.4,
                            interval=0.03)
    agent.strategy = strat
    strat.start()
    assert wait_until(lambda: strat.blocks() >= 1, timeout=3)
    ids = client.batch_run([(fid, eid, {}) for _ in range(40)])
    res = client.get_batch_results(ids, timeout=60)
    assert len(res) == 40
    assert strat.scale_out_events > 0
    assert wait_until(lambda: strat.blocks() == 1, timeout=10)
    assert strat.scale_in_events > 0
    agent.stop()


def test_provider_delays():
    slurm = SimSlurmProvider(mean_wait=0.05, jitter=0.0)
    cloud = SimCloudProvider(boot_delay=0.03)
    assert slurm.acquisition_delay() >= 0.05
    assert cloud.acquisition_delay() == 0.03


# -- socket transport faults -------------------------------------------------

class _Grab:
    def __init__(self):
        self.transport = None

    def __call__(self, transport, peer):
        self.transport = transport


def test_tcp_partial_length_prefix_is_dropped():
    """A connection that dies inside the 4-byte length prefix delivers
    nothing — no truncated frame, no reader crash."""
    grab = _Grab()
    listener = TcpListener("127.0.0.1", 0, grab)
    try:
        s = socket.create_connection(listener.address)
        assert wait_until(lambda: grab.transport is not None, timeout=5)
        s.sendall(b"\x00\x00")                       # 2 of 4 length bytes
        s.close()
        assert wait_until(lambda: not grab.transport.connected, timeout=5)
        assert grab.transport.frames_in == 0
        assert grab.transport.recv(TO_SERVICE, timeout=0.1) is None
    finally:
        listener.close()


def test_tcp_midframe_disconnect_is_dropped():
    """A frame cut short mid-body is discarded with the connection; the
    frames before the cut still arrive intact."""
    grab = _Grab()
    listener = TcpListener("127.0.0.1", 0, grab)
    try:
        s = socket.create_connection(listener.address)
        assert wait_until(lambda: grab.transport is not None, timeout=5)
        whole = b"intact-frame"
        s.sendall(struct.pack(">I", len(whole)) + whole)
        s.sendall(struct.pack(">I", 100) + b"only ten b")   # then die
        s.close()
        assert wait_until(lambda: grab.transport.frames_in == 1, timeout=5)
        assert grab.transport.recv(TO_SERVICE, timeout=1.0) == whole
        assert wait_until(lambda: not grab.transport.connected, timeout=5)
        assert grab.transport.recv(TO_SERVICE, timeout=0.1) is None
    finally:
        listener.close()


def test_tcp_connection_kill_midload_completes_exactly_once(tcp_service):
    """Kill the socket while a batch is in flight: requeue-on-disconnect +
    re-dial + re-register deliver every submitted task exactly one
    completion (duplicate executions are deduped at the result store)."""
    svc, client, address = tcp_service
    runner = start_tcp_endpoint(client, address)
    try:
        fid = client.register_function(demo_square)
        ids = client.batch_run([(fid, runner.endpoint_id, {"x": i})
                                for i in range(30)])
        runner.transport.disconnect()                # mid-stream cut
        runner.transport.reconnect()                 # allow the re-dial
        res = client.get_batch_results(ids, timeout=60)
        assert res == [i * i for i in range(30)]
        assert runner.re_registrations >= 1
        # exactly once: every id was retrieved once and then purged
        for tid in ids:
            with pytest.raises(KeyError):
                svc.get_task(tid)
    finally:
        runner.stop()


def test_results_finished_during_outage_are_retransmitted(tcp_service):
    """A result produced while the link is down must be parked and
    retransmitted after the re-dial — not swallowed by the duplicate
    filter when the requeued task re-executes (regression: these tasks
    used to hang forever)."""
    svc, client, address = tcp_service
    runner = start_tcp_endpoint(client, address, workers_per_manager=4)
    try:
        fid = client.register_function(demo_sleep)
        ids = client.batch_run([(fid, runner.endpoint_id, {"s": 0.3})
                                for _ in range(4)])
        # cut the link while all four are mid-execution
        assert wait_until(lambda: runner.agent.tasks_received >= 4,
                          timeout=5)
        runner.transport.disconnect()
        time.sleep(1.0)          # tasks finish into a dead link
        runner.transport.reconnect()
        res = client.get_batch_results(ids, timeout=30)
        assert res == [None] * 4
    finally:
        runner.stop()


def test_tcp_reconnect_after_service_restart_completes_all(tcp_service):
    """Service network tier goes down (listener closed, channel dead) and
    comes back on the same port: the endpoint re-dials, re-registers under
    its old id, in-flight work is requeued, and everything submitted —
    before and during the outage — completes exactly once."""
    svc, client, address = tcp_service
    host, port = address
    runner = start_tcp_endpoint(client, address)
    try:
        fid = client.register_function(demo_square)
        before = client.batch_run([(fid, runner.endpoint_id, {"x": i})
                                   for i in range(10)])
        rec = svc.endpoints[runner.endpoint_id]
        svc.stop_listening()
        rec.channel.close()                          # "service restart"
        during = client.batch_run([(fid, runner.endpoint_id, {"x": i})
                                   for i in range(10, 20)])
        time.sleep(0.3)                              # endpoint is re-dialing
        svc.listen(host, port)                       # service back up
        res = client.get_batch_results(before + during, timeout=60)
        assert res == [i * i for i in range(20)]
        assert runner.re_registrations >= 1
        assert svc.endpoints[runner.endpoint_id].channel is not rec.channel \
            or rec.channel.connected
    finally:
        runner.stop()


def test_forwarder_pool_restart_by_health_check(service, client):
    fid = client.register_function(lambda d: d)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1)
    old_pool = service.pool
    old_pool._stop.set()             # simulates crashed loops → unhealthy
    assert wait_until(lambda: service.pool is not old_pool, timeout=5)
    assert service.forwarder_restarts >= 1
    # the record's line was swapped onto the new pool
    assert service.endpoints[eid].line is service.pool.line(eid)
    tid = client.run(fid, eid, data=9)
    assert client.get_result(tid, timeout=10) == 9
    agent.stop()
