"""Fault tolerance (paper §4.1/§4.3): manager loss → re-execution;
endpoint disconnect → forwarder requeue; retry budget → LOST; straggler
speculation; elastic provisioning."""
import time

import pytest

from repro.core import (
    ElasticStrategy,
    FuncXClient,
    FuncXService,
    LocalProvider,
    SimCloudProvider,
    SimSlurmProvider,
    TaskLost,
)
from conftest import wait_until


def test_manager_kill_reexecutes(service, client):
    def slow(data):
        time.sleep(0.2)
        return data["i"]
    fid = client.register_function(slow)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=2,
                                       workers_per_manager=2,
                                       manager_timeout=0.4)
    ids = client.batch_run([(fid, eid, {"i": i}) for i in range(8)])
    time.sleep(0.15)
    agent.kill_manager(list(agent.managers)[0])
    res = client.get_batch_results(ids, timeout=30)
    assert sorted(res) == list(range(8))
    assert agent.tasks_reexecuted > 0
    agent.stop()


def test_all_managers_dead_then_lost(service, client):
    def slow(data):
        time.sleep(10)
        return 1
    fid = client.register_function(slow)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1,
                                       workers_per_manager=1,
                                       manager_timeout=0.3, max_retries=0)
    tid = client.run(fid, eid, data={})
    time.sleep(0.15)
    agent.kill_manager(list(agent.managers)[0])
    with pytest.raises(TaskLost):
        client.get_result(tid, timeout=30)
    agent.stop()


def test_disconnect_requeues_and_recovers(service, client):
    fid = client.register_function(lambda d: d["i"])
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1,
                                       workers_per_manager=2)
    rec = service.endpoints[eid]
    rec.channel.disconnect()
    ids = client.batch_run([(fid, eid, {"i": i}) for i in range(5)])
    time.sleep(0.5)              # tasks parked service-side
    assert all(not service.get_task(t).done for t in ids)
    rec.channel.reconnect()
    res = client.get_batch_results(ids, timeout=30)
    assert sorted(res) == list(range(5))
    agent.stop()


def test_heartbeat_detects_disconnect(service, client):
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1)
    rec = service.endpoints[eid]
    assert wait_until(lambda: rec.connected, timeout=2)
    rec.channel.disconnect()
    assert wait_until(lambda: not rec.forwarder.endpoint_connected,
                      timeout=3)
    rec.channel.reconnect()
    assert wait_until(lambda: rec.forwarder.endpoint_connected, timeout=3)
    agent.stop()


def test_speculation_rescues_straggler(service, client):
    fid = client.register_function(lambda d: 1)
    eid, agent = service.make_endpoint(
        client.token, "ep", n_managers=2, workers_per_manager=2,
        speculation=True, speculation_min=0.3)
    slow_mgr = list(agent.managers.values())[0]
    for w in slow_mgr.workers:
        w.slowdown = 3.0
    ids = client.batch_run([(fid, eid, {}) for _ in range(16)])
    t0 = time.perf_counter()
    res = client.get_batch_results(ids, timeout=60)
    took = time.perf_counter() - t0
    assert res == [1] * 16
    # without speculation the slow manager's share would cost ~9 s
    # (6 tasks × 3 s / 2 workers); speculation reroutes the stragglers
    assert agent.speculative_dispatches > 0
    assert took < 6.0
    agent.stop()


def test_elastic_scale_out_and_in(service, client):
    def work(data):
        time.sleep(0.05)
        return 0
    fid = client.register_function(work)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=0)
    strat = ElasticStrategy(agent, LocalProvider(workers_per_node=2),
                            min_blocks=1, max_blocks=4, idle_timeout=0.4,
                            interval=0.03)
    agent.strategy = strat
    strat.start()
    assert wait_until(lambda: strat.blocks() >= 1, timeout=3)
    ids = client.batch_run([(fid, eid, {}) for _ in range(40)])
    res = client.get_batch_results(ids, timeout=60)
    assert len(res) == 40
    assert strat.scale_out_events > 0
    assert wait_until(lambda: strat.blocks() == 1, timeout=10)
    assert strat.scale_in_events > 0
    agent.stop()


def test_provider_delays():
    slurm = SimSlurmProvider(mean_wait=0.05, jitter=0.0)
    cloud = SimCloudProvider(boot_delay=0.03)
    assert slurm.acquisition_delay() >= 0.05
    assert cloud.acquisition_delay() == 0.03


def test_forwarder_pool_restart_by_health_check(service, client):
    fid = client.register_function(lambda d: d)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1)
    old_pool = service.pool
    old_pool._stop.set()             # simulates crashed loops → unhealthy
    assert wait_until(lambda: service.pool is not old_pool, timeout=5)
    assert service.forwarder_restarts >= 1
    # the record's line was swapped onto the new pool
    assert service.endpoints[eid].line is service.pool.line(eid)
    tid = client.run(fid, eid, data=9)
    assert client.get_result(tid, timeout=10) == 9
    agent.stop()
