"""Elastic provisioning strategy (paper §6.3, DESIGN.md §11): scaling
decisions read queued backlog depth, acquisitions run off-loop (a slow
scheduler cannot stall scale-in or the next tick), clamps hold, and the
interchange-driven path provisions whole leaf endpoints."""
import threading
import time

from repro.core import ElasticStrategy, Provider
from conftest import wait_until


class FakeEndpoint:
    """Just the surface ElasticStrategy reads."""

    def __init__(self, pending=0, idle=0):
        self.endpoint_id = "fake-ep"
        self.pending = pending
        self.idle = idle
        self.idle_blocks = True

    def pending_tasks(self):
        return self.pending

    def idle_workers(self):
        return self.idle

    def block_idle(self, ids):
        return self.idle_blocks


class RecordingProvider(Provider):
    """Instant blocks; records acquisition/release timing."""

    def __init__(self, delay=0.0, **kw):
        super().__init__(**kw)
        self.delay = delay
        self.starts = []
        self.stops = []
        self._n = 0
        self._lock = threading.Lock()

    def start_block(self, endpoint):
        t = time.monotonic()
        if self.delay:
            time.sleep(self.delay)
        with self._lock:
            self._n += 1
            bid = [f"blk{self._n}"]
        self.starts.append(t)
        return bid

    def stop_block(self, endpoint, ids):
        self.stops.append(ids)


def run_strategy(ep, prov, **kw):
    kw.setdefault("interval", 0.02)
    s = ElasticStrategy(ep, prov, **kw)
    s.start()
    return s


# ------------------------------------------------------- backlog-depth sizing

def test_backlog_depth_provisions_whole_shortfall_in_one_decision():
    """350 queued tasks at 100 per block ⇒ 4 blocks wanted; all land from
    one observation tick, not one-per-tick trickle."""
    ep, prov = FakeEndpoint(pending=350), RecordingProvider()
    s = run_strategy(ep, prov, min_blocks=0, max_blocks=8,
                     backlog_per_block=100)
    try:
        assert wait_until(lambda: s.blocks() == 4, timeout=5)
        assert s.scale_out_events == 4
        # the four acquisitions launched together (off-loop, same tick)
        assert max(prov.starts) - min(prov.starts) < 0.5
    finally:
        s.stop()


def test_max_blocks_clamps_backlog_demand():
    ep, prov = FakeEndpoint(pending=10_000), RecordingProvider()
    s = run_strategy(ep, prov, min_blocks=0, max_blocks=3,
                     backlog_per_block=10)
    try:
        assert wait_until(lambda: s.blocks() == 3, timeout=5)
        time.sleep(0.2)
        assert s.blocks() == 3 and s.scale_out_events == 3
    finally:
        s.stop()


def test_min_blocks_floor_holds_with_empty_backlog():
    ep, prov = FakeEndpoint(pending=0), RecordingProvider()
    s = run_strategy(ep, prov, min_blocks=2, max_blocks=4,
                     backlog_per_block=100, idle_timeout=0.1)
    try:
        assert wait_until(lambda: s.blocks() == 2, timeout=5)
        time.sleep(0.4)                  # idle well past the timeout
        assert s.blocks() == 2           # never reaped below the floor
        assert s.scale_in_events == 0
    finally:
        s.stop()


def test_legacy_pending_vs_idle_mode_still_scales_one_block_per_tick():
    ep, prov = FakeEndpoint(pending=10, idle=0), RecordingProvider()
    s = run_strategy(ep, prov, min_blocks=0, max_blocks=2)   # no backlog_per_block
    try:
        assert wait_until(lambda: s.blocks() == 2, timeout=5)
    finally:
        s.stop()


# ------------------------------------------------------- off-loop acquisition

def test_slow_acquisitions_run_concurrently_not_serialized():
    """Three 0.3s acquisitions must overlap (≈0.3s wall), not serialize
    inside the strategy loop (≈0.9s)."""
    ep, prov = FakeEndpoint(pending=300), RecordingProvider(delay=0.3)
    s = run_strategy(ep, prov, min_blocks=0, max_blocks=4,
                     backlog_per_block=100)
    try:
        t0 = time.monotonic()
        assert wait_until(lambda: s.blocks() == 3, timeout=5)
        assert time.monotonic() - t0 < 0.7
        assert max(prov.starts) - min(prov.starts) < 0.2
    finally:
        s.stop()


def test_pending_acquisitions_prevent_overprovisioning():
    """While blocks are still in the provider's queue-wait sleep, ticks
    keep firing — but in-flight acquisitions count toward 'have', so the
    demand is satisfied exactly once."""
    ep, prov = FakeEndpoint(pending=200), RecordingProvider(delay=0.25)
    s = run_strategy(ep, prov, min_blocks=0, max_blocks=8,
                     backlog_per_block=100, interval=0.01)
    try:
        time.sleep(0.1)                  # many ticks mid-acquisition
        assert s.pending_blocks() == 2
        assert wait_until(lambda: s.blocks() == 2, timeout=5)
        time.sleep(0.1)
        assert s.scale_out_events == 2   # never re-ordered what was coming
    finally:
        s.stop()


def test_scale_in_keeps_running_while_acquisition_sleeps():
    """A stuck acquisition (slurm queue wait) must not freeze scale-in:
    an idle block is reaped while another is still being acquired."""
    ep = FakeEndpoint(pending=0)
    prov = RecordingProvider()
    s = run_strategy(ep, prov, min_blocks=1, max_blocks=4,
                     backlog_per_block=50, idle_timeout=0.1)
    try:
        assert wait_until(lambda: s.blocks() == 1, timeout=5)
        ep.pending = 120                 # ask for 3 blocks...
        assert wait_until(lambda: s.blocks() == 3, timeout=5)
        prov.delay = 10.0                # ...then make acquisitions hang
        ep.pending = 200
        assert wait_until(lambda: s.pending_blocks() == 1, timeout=5)
        ep.pending = 0                   # backlog drained; blocks idle
        assert wait_until(lambda: s.scale_in_events >= 1, timeout=5)
        assert s.blocks() < 3            # reaped despite the hung acquire
    finally:
        prov.delay = 0.0
        s.stop()


def test_scale_in_waits_for_idle_timeout():
    ep, prov = FakeEndpoint(pending=0), RecordingProvider()
    ep.idle_blocks = False
    s = run_strategy(ep, prov, min_blocks=0, max_blocks=4,
                     backlog_per_block=10, idle_timeout=0.15)
    try:
        ep.pending = 20
        assert wait_until(lambda: s.blocks() == 2, timeout=5)
        ep.pending = 0
        time.sleep(0.4)
        assert s.blocks() == 2           # busy blocks are never reaped
        ep.idle_blocks = True
        assert wait_until(lambda: s.blocks() == 0, timeout=5)
        assert s.scale_in_events == 2
    finally:
        s.stop()


# ------------------------------------------------ interchange-driven path

def test_interchange_backlog_drives_leaf_provisioning(tcp_service):
    """End to end: a burst absorbed by an interchange with zero leaves
    provisions leaf endpoints via the strategy, drains, and reaps."""
    from repro.core import Interchange, ThreadLeafProvider

    svc, client, (host, port) = tcp_service
    ix = Interchange(f"{host}:{port}", client.endpoint_credentials(),
                     name="elastic-relay", depth=5000,
                     heartbeat_interval=0.05, leaf_timeout=0.4)
    ix.start()
    prov = ThreadLeafProvider(ix, workers_per_node=2)
    s = ElasticStrategy(ix, prov, min_blocks=0, max_blocks=2,
                        backlog_per_block=40, idle_timeout=0.4,
                        interval=0.03)
    ix.strategy = s
    s.start()
    try:
        fid = client.register_function(lambda d: d["i"])
        ids = client.batch_run([(fid, ix.endpoint_id, {"i": i})
                                for i in range(80)])
        assert wait_until(lambda: s.blocks() == 2, timeout=10)
        assert client.get_batch_results(ids, timeout=60) == list(range(80))
        assert wait_until(lambda: s.blocks() == 0, timeout=15)
        assert ix.leaf_lines() == []
    finally:
        ix.stop()
