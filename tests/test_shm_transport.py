"""Scatter-gather zero-copy frames + shared-memory transport (DESIGN.md §7):
segmented-vs-legacy envelope identity, borrowed (uncopied) payload segments,
partial vectored send/recv, shm ring streaming, attach-failure TCP fallback,
and endpoint crash with a ring attached (exactly-once preserved)."""
import socket
import threading
import time

import pytest

from repro.core import (
    Channel,
    ShmRing,
    ShmTransport,
    TaskBatch,
    TaskSpec,
    TcpTransport,
    WIRE_STATS,
    decode_frame,
    from_wire,
    segment_parts,
    to_wire,
    to_wire_parts,
)
from repro.core.comms import (
    TO_ENDPOINT, TO_SERVICE, _FrameAssembler, _LEN_PREFIX)
from repro.core.endpoint import demo_sleep, demo_square
from repro.core.protocol import SEGMENT_MIN
from repro.serialization import PackedBuffer, pack_buffer
from conftest import start_tcp_endpoint, wait_until


def _spec(payload_obj, task_id="t0"):
    return TaskSpec(task_id=task_id, function_id="f",
                    container_type="python",
                    payload=pack_buffer(payload_obj, tag="task"))


# -- envelope encoding: segmented vs legacy -----------------------------------

def test_small_payload_embeds_identical_to_legacy():
    """Below SEGMENT_MIN nothing changes: to_wire_parts yields no
    segments and an envelope byte-for-byte equal to the legacy encoder's
    — mixed-version peers see exactly the old wire format."""
    batch = TaskBatch(tasks=[_spec({"x": 1})])
    legacy = to_wire(batch)
    env, segs = to_wire_parts(batch)
    assert segs == []
    assert env == legacy
    assert "payload_b" in env["tasks"][0]


def test_large_payload_rides_as_borrowed_segment():
    """At or above SEGMENT_MIN the packed payload is *borrowed* — the
    segment list holds the PackedBuffer's own bytes object (no copy), and
    the envelope carries only the segment index."""
    buf = pack_buffer({"blob": b"x" * (4 * SEGMENT_MIN)}, tag="task")
    spec = TaskSpec(task_id="t", function_id="f", container_type="python",
                    payload=buf)
    WIRE_STATS.reset()
    env, segs = to_wire_parts(TaskBatch(tasks=[spec]))
    assert len(segs) == 1
    assert segs[0] is buf.data                 # borrowed, not copied
    d = env["tasks"][0]
    assert d.get("payload_seg") == 0 and "payload_b" not in d
    assert WIRE_STATS.embedded_payload_bytes == 0
    assert WIRE_STATS.segment_payload_bytes == len(buf.data)


def test_segmented_byte_stream_decodes_identical_to_legacy():
    """The same message, shipped segmented over a byte stream and shipped
    legacy-embedded, decodes to identical task payload bytes."""
    payload_obj = {"blob": b"y" * (2 * SEGMENT_MIN), "k": 3}
    batch = TaskBatch(tasks=[_spec(payload_obj)])

    # segmented path: envelope + borrowed segment, gathered into one body
    env, segs = to_wire_parts(batch)
    header = pack_buffer(env, tag="tasks", method_hint="msgpack")
    parts = segment_parts(header.data, segs)
    body = b"".join(bytes(p) for p in parts)
    frame = decode_frame(body)
    assert frame.tag == "tasks"
    seg_msg = from_wire(frame.unpack())

    # legacy path: everything embedded in one envelope
    legacy_env = to_wire(batch)
    legacy_frame = decode_frame(
        pack_buffer(legacy_env, tag="tasks", method_hint="msgpack").data)
    assert isinstance(legacy_frame, PackedBuffer)
    leg_msg = from_wire(legacy_frame.unpack())

    a, b = seg_msg.tasks[0], leg_msg.tasks[0]
    assert bytes(a.payload.data) == bytes(b.payload.data)
    assert a.payload.unpack() == payload_obj == b.payload.unpack()


def test_mixed_version_legacy_envelope_still_decodes():
    """An envelope from an old peer (always-embedded, no ``_segs``)
    decodes on the new side unchanged — including large payloads."""
    env = to_wire(TaskBatch(tasks=[_spec({"big": b"z" * (8 * SEGMENT_MIN)})]))
    assert "payload_b" in env["tasks"][0]      # legacy embeds regardless
    msg = from_wire(env)
    assert msg.tasks[0].payload.unpack() == {"big": b"z" * (8 * SEGMENT_MIN)}


def test_local_transport_passes_segment_list_untouched():
    """LocalTransport never joins: the part list crosses the in-memory
    queue as-is, and the decoder hands back the *sender's own* payload
    buffer (zero copies end to end)."""
    buf = pack_buffer({"blob": b"q" * (4 * SEGMENT_MIN)}, tag="task")
    spec = TaskSpec(task_id="t", function_id="f", container_type="python",
                    payload=buf)
    env, segs = to_wire_parts(TaskBatch(tasks=[spec]))
    ch = Channel()
    assert ch.send_parts_to_endpoint(env, segs, tag="tasks")
    raw = ch.transport.recv_nowait(TO_ENDPOINT)
    frame = decode_frame(raw)
    assert frame.segments[0] is buf.data       # same object, no copy
    msg = from_wire(frame.unpack())
    assert msg.tasks[0].payload.data is buf.data


# -- frame assembly under partial reads ---------------------------------------

def test_frame_assembler_single_byte_dribble():
    """A stream of legacy frame + doorbell + segmented frame + a
    direct-buffer-sized frame, fed one byte at a time, reassembles every
    frame intact — partial recv never corrupts framing."""
    legacy = b"legacy-frame-body"
    hdr = pack_buffer({"h": 1}, tag="x").data
    seg_body = b"".join(bytes(p) for p in segment_parts(
        hdr, [b"a" * 2000, b"b" * 3000]))
    big = bytes(range(256)) * ((_FrameAssembler.DIRECT_MIN // 256) + 1)
    stream = (_LEN_PREFIX.pack(len(legacy)) + legacy
              + _LEN_PREFIX.pack(0)                       # doorbell
              + _LEN_PREFIX.pack(len(seg_body)) + seg_body
              + _LEN_PREFIX.pack(len(big)) + big)
    asm = _FrameAssembler()
    for i in range(len(stream)):
        assert asm.feed(stream[i:i + 1])
    frames = list(asm.frames)
    assert len(frames) == 4
    assert bytes(frames[0]) == legacy
    assert frames[1] == b""                               # doorbell marker
    assert bytes(frames[2]) == seg_body
    assert bytes(frames[3]) == big
    # the segmented body decodes with its segments sliced back out
    fr = decode_frame(frames[2])
    assert fr.tag == "x" and fr.header.unpack() == {"h": 1}
    assert [bytes(s) for s in fr.segments] == [b"a" * 2000, b"b" * 3000]


def test_frame_assembler_rejects_oversized_frame():
    asm = _FrameAssembler(max_frame=1024)
    assert not asm.feed(_LEN_PREFIX.pack(4096))           # poisoned stream


def test_vectored_send_survives_partial_writes():
    """``send_parts`` over a real socket with a tiny send buffer and a
    slow reader: sendmsg partial writes must resume mid-iovec, and the
    bytes on the wire must equal prefix + joined parts exactly."""
    a, b = socket.socketpair()
    a.setsockopt(socket.SOL_SOCKET, socket.SO_SNDBUF, 8192)
    tr = TcpTransport(sock=a)
    try:
        parts = segment_parts(b"H" * 100,
                              [b"\x5a" * 200_000, b"\x7e" * 300_000])
        total = sum(len(p) for p in parts)
        expect = _LEN_PREFIX.pack(total) + b"".join(bytes(p) for p in parts)
        got = bytearray()

        def reader():
            while len(got) < len(expect):
                chunk = b.recv(4096)
                if not chunk:
                    break
                got.extend(chunk)
                time.sleep(0.0002)             # keep the sender blocked
        t = threading.Thread(target=reader, daemon=True)
        t.start()
        assert tr.send_parts(TO_SERVICE, parts)
        t.join(timeout=30)
        assert bytes(got) == expect
    finally:
        tr.close()
        b.close()


# -- shm ring -----------------------------------------------------------------

def test_shm_ring_streams_frames_larger_than_capacity():
    """The ring is a byte stream, not a mailbox: a frame bigger than the
    ring flows through in pieces while the reader drains, wrapping the
    circular buffer multiple times, and reassembles intact."""
    ring = ShmRing.create(4096)
    peer = ShmRing.attach(ring.name)
    try:
        frames = [b"\xab" * 10_000, b"tiny", b"\xcd" * 5_000]
        stream = b"".join(_LEN_PREFIX.pack(len(f)) + f for f in frames)
        asm = _FrameAssembler()

        def reader():
            while len(asm.frames) < len(frames):
                if peer.read_some(lambda v: asm.feed(v)) == 0:
                    time.sleep(0.0002)
        t = threading.Thread(target=reader, daemon=True)
        t.start()
        view = memoryview(stream)
        deadline = time.time() + 10
        while view.nbytes and time.time() < deadline:
            k = ring.write_some(view)
            view = view[k:] if k else view
            if not k:
                time.sleep(0.0002)
        t.join(timeout=10)
        assert [bytes(f) for f in asm.frames] == frames
    finally:
        peer.close()
        ring.close()
        ring.unlink()


# -- negotiation: upgrade, fallback, crash ------------------------------------

def test_same_host_negotiation_upgrades_both_sides(tcp_service):
    """A same-host dialer auto-negotiates the shm fast path at Register
    time: both sides swap to ShmTransport and the full task round-trip —
    including a >SEGMENT_MIN payload — runs through the rings."""
    svc, client, address = tcp_service
    runner = start_tcp_endpoint(client, address)
    try:
        assert wait_until(lambda: runner.shm_attached, timeout=5)
        assert isinstance(runner.channel.transport, ShmTransport)
        assert wait_until(lambda: isinstance(
            svc.endpoints[runner.endpoint_id].channel.transport,
            ShmTransport), timeout=5)
        assert not svc._pending_shm            # offer confirmed + installed
        fid = client.register_function(demo_square)
        ids = client.batch_run([(fid, runner.endpoint_id, {"x": i})
                                for i in range(40)])
        big = client.run(fid, runner.endpoint_id,
                         data={"x": 2, "pad": b"p" * 100_000})
        assert client.get_batch_results(ids, timeout=30) == \
            [i * i for i in range(40)]
        assert client.get_result(big, timeout=30) == 4
    finally:
        runner.stop()


def test_shm_attach_failure_falls_back_to_tcp(tcp_service, monkeypatch):
    """If the endpoint cannot map the offered rings (stale name, shm
    exhausted...), it declines over TCP and keeps the socket: tasks still
    complete, and the service reaps the unconfirmed rings."""
    svc, client, address = tcp_service

    def boom(name):
        raise FileNotFoundError(f"no such segment: {name}")
    monkeypatch.setattr(ShmRing, "attach", staticmethod(boom))
    runner = start_tcp_endpoint(client, address)
    try:
        assert not runner.shm_attached
        assert isinstance(runner.channel.transport, TcpTransport)
        assert not isinstance(runner.channel.transport, ShmTransport)
        fid = client.register_function(demo_square)
        ids = client.batch_run([(fid, runner.endpoint_id, {"x": i})
                                for i in range(20)])
        assert client.get_batch_results(ids, timeout=30) == \
            [i * i for i in range(20)]
        tr = svc.endpoints[runner.endpoint_id].channel.transport
        assert not isinstance(tr, ShmTransport)
        # the declined offer's rings were closed and unlinked
        assert wait_until(lambda: not svc._pending_shm, timeout=5)
    finally:
        runner.stop()


def test_endpoint_crash_with_ring_attached_exactly_once(tcp_service):
    """Kill the link while a batch is mid-flight *through the rings*:
    requeue + re-register recovers every task exactly once, the dead
    rings are unlinked, and a fresh pair is negotiated."""
    svc, client, address = tcp_service
    runner = start_tcp_endpoint(client, address, workers_per_manager=4)
    try:
        assert wait_until(lambda: runner.shm_attached, timeout=5)
        assert wait_until(lambda: isinstance(
            svc.endpoints[runner.endpoint_id].channel.transport,
            ShmTransport), timeout=5)
        old = svc.endpoints[runner.endpoint_id].channel.transport
        old_names = (old._tx.name, old._rx.name)
        fid = client.register_function(demo_sleep)
        ids = client.batch_run([(fid, runner.endpoint_id, {"s": 0.2})
                                for _ in range(8)])
        assert wait_until(lambda: runner.agent.tasks_received >= 1,
                          timeout=10)
        runner.channel.transport.disconnect()  # crash: both media die
        runner.transport.reconnect()
        assert client.get_batch_results(ids, timeout=60) == [None] * 8
        assert runner.re_registrations >= 1
        for tid in ids:                        # exactly once, then purged
            with pytest.raises(KeyError):
                svc.get_task(tid)
        # a new ring pair was negotiated for the new connection...
        # (shm_attached flips when the endpoint *sends* its ShmAttach
        # confirm; the service installs its ShmTransport when the pool
        # recv-loop *processes* it — wait for both sides, as above)
        assert wait_until(lambda: runner.shm_attached, timeout=10)
        assert wait_until(lambda: isinstance(
            svc.endpoints[runner.endpoint_id].channel.transport,
            ShmTransport), timeout=10)
        new = svc.endpoints[runner.endpoint_id].channel.transport
        assert (new._tx.name, new._rx.name) != old_names

        # ...and the crashed pair's segments are gone from /dev/shm
        def unlinked(name):
            try:
                r = ShmRing.attach(name)
            except FileNotFoundError:
                return True
            r.close()
            return False
        assert wait_until(lambda: all(unlinked(n) for n in old_names),
                          timeout=10)
    finally:
        runner.stop()
