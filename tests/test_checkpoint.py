"""Checkpoint/restart substrate (fault-tolerance deliverable)."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.train import checkpoint as ckpt


def _state():
    return {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
                       "b": jnp.ones(3, jnp.bfloat16)},
            "step": jnp.int32(5)}


def _assert_equal(a, b):
    for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
        assert x.dtype == y.dtype


def test_roundtrip(tmp_path):
    s = _state()
    ckpt.save(str(tmp_path), s, 5)
    out = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: s))
    _assert_equal(s, out)
    assert ckpt.latest_step(str(tmp_path)) == 5


def test_latest_and_gc(tmp_path):
    s = _state()
    for step in (1, 2, 3, 4):
        ckpt.save(str(tmp_path), s, step)
    ckpt.gc_old(str(tmp_path), max_to_keep=2)
    assert ckpt.available_steps(str(tmp_path)) == [3, 4]
    assert ckpt.latest_step(str(tmp_path)) == 4


def test_restore_specific_step(tmp_path):
    s1, s2 = _state(), _state()
    s2["step"] = jnp.int32(9)
    ckpt.save(str(tmp_path), s1, 1)
    ckpt.save(str(tmp_path), s2, 2)
    out = ckpt.restore(str(tmp_path), jax.eval_shape(lambda: s1), step=1)
    assert int(out["step"]) == 5


def test_shape_mismatch_rejected(tmp_path):
    ckpt.save(str(tmp_path), _state(), 1)
    bad = {"params": {"w": jax.ShapeDtypeStruct((3, 3), jnp.float32),
                      "b": jax.ShapeDtypeStruct((3,), jnp.bfloat16)},
           "step": jax.ShapeDtypeStruct((), jnp.int32)}
    with pytest.raises(ValueError, match="shape"):
        ckpt.restore(str(tmp_path), bad)


def test_missing_checkpoint_raises(tmp_path):
    with pytest.raises(FileNotFoundError):
        ckpt.restore(str(tmp_path / "empty"), _state())


def test_atomic_no_partial_dirs(tmp_path):
    ckpt.save(str(tmp_path), _state(), 7)
    assert not any(p.endswith(".tmp") for p in os.listdir(tmp_path))


def test_async_checkpointer(tmp_path):
    saver = ckpt.AsyncCheckpointer(str(tmp_path), max_to_keep=2)
    s = _state()
    futs = [saver.save(s, i) for i in (1, 2, 3)]
    saver.wait()
    assert all(f.done() for f in futs)
    assert ckpt.available_steps(str(tmp_path)) == [2, 3]
    saver.close()


def test_async_snapshot_consistency(tmp_path):
    """Mutating state after save() must not corrupt the snapshot."""
    saver = ckpt.AsyncCheckpointer(str(tmp_path))
    s = {"w": np.zeros(4, np.float32)}
    fut = saver.save(s, 1)
    s["w"] += 99.0          # mutate the live buffer
    fut.result()
    out = ckpt.restore(str(tmp_path), jax.eval_shape(
        lambda: {"w": jnp.zeros(4)}))
    np.testing.assert_array_equal(np.asarray(out["w"]), np.zeros(4))
    saver.close()
