"""Peer data plane (DESIGN.md §9): direct endpoint↔endpoint DataRef
resolution with service-brokered signaling, HMAC peer-tokens, and the
hub-relay fallback ladder."""
import time

import pytest

from repro.core.auth import (
    AuthError,
    mint_peer_token,
    validate_peer_token,
)
from repro.core.peer import PeerClient, PeerError, PeerServer
from repro.core.protocol import ResolvePeerAck
from repro.data import DataRef, InMemoryKVStore
from conftest import start_tcp_endpoint, wait_until


def produce_blob(data):
    n = data["n"] if isinstance(data, dict) else data
    return bytes((i * 31 + 7) % 251 for i in range(n))


def blob_len(data):
    blob = data["blob"] if isinstance(data, dict) else data
    return len(blob)


# -------------------------------------------------------------- peer tokens
def test_peer_token_roundtrip():
    secret = b"s" * 32
    token, expires = mint_peer_token(secret, "prod", "cons")
    assert expires > time.time()
    assert validate_peer_token(secret, token, "prod") == "cons"


def test_peer_token_refusals():
    secret = b"s" * 32
    token, _ = mint_peer_token(secret, "prod", "cons")
    with pytest.raises(AuthError):                 # wrong producer
        validate_peer_token(secret, token, "other")
    with pytest.raises(AuthError):                 # wrong secret
        validate_peer_token(b"x" * 32, token, "prod")
    with pytest.raises(AuthError):                 # garbage
        validate_peer_token(secret, "not json", "prod")
    expired, _ = mint_peer_token(secret, "prod", "cons", ttl=-1.0)
    with pytest.raises(AuthError):                 # expired
        validate_peer_token(secret, expired, "prod")


# ------------------------------------------------- standalone server/client
def test_direct_fetch_via_location_hint():
    """No service in the loop: a tokenless PeerServer serves its store to
    a client that dials the ref's ``location`` hint."""
    store = InMemoryKVStore()
    blob = bytes(range(256)) * 1200
    store.set_raw("k", blob)
    server = PeerServer("prod", store)
    client = PeerClient("cons")
    try:
        ref = DataRef("globus", "prod", "k", server.address)
        assert client.fetch_raw(ref) == blob
        assert client.stats.direct_fetches == 1
        assert client.stats.direct_bytes == len(blob)
        assert server.serves == 1
    finally:
        client.close()
        server.close()


def test_bad_token_refused_by_armed_server():
    """A secret-armed PeerServer refuses forged and expired tokens; the
    client retries once with a fresh grant, then surfaces PeerError."""
    store = InMemoryKVStore()
    store.set_raw("k", b"payload")
    secret = b"z" * 32
    server = PeerServer("prod", store, secret=secret)
    client = PeerClient("cons")
    try:
        # forge a grant with the wrong secret: both the first try and the
        # forced re-resolve (same poisoned cache path) must be refused
        bad, expires = mint_peer_token(b"wrong" * 8, "prod", "cons")
        client._grants["prod"] = ResolvePeerAck(
            endpoint_id="prod", ok=True, addr=server.address,
            token=bad, expires=expires)
        with pytest.raises(PeerError):
            client.fetch_direct("prod", "k")
        assert server.refused >= 1
        assert server.serves == 0

        # a correctly minted token is accepted
        tok, expires = mint_peer_token(secret, "prod", "cons")
        client._grants["prod"] = ResolvePeerAck(
            endpoint_id="prod", ok=True, addr=server.address,
            token=tok, expires=expires)
        assert client.fetch_direct("prod", "k") == b"payload"
    finally:
        client.close()
        server.close()


# ----------------------------------------------- full federation, real TCP
def _two_endpoints(svc, client, address, **kw):
    kw.setdefault("stage_limit", 1024)
    a = start_tcp_endpoint(client, address, name="prod", **kw)
    b = start_tcp_endpoint(client, address, name="cons", **kw)
    return a, b


def test_cross_endpoint_ref_resolves_peer_to_peer(tcp_service):
    """The happy path: a staged-out result crosses endpoints over direct
    peer TCP — zero relay bytes transit the hub."""
    svc, client, address = tcp_service
    a, b = _two_endpoints(svc, client, address)
    try:
        fid_p = client.register_function(produce_blob)
        fid_c = client.register_function(blob_len)
        ref = client.get_result(
            client.run(fid_p, a.endpoint_id, data={"n": 64 * 1024}),
            timeout=15)
        assert isinstance(ref, DataRef)
        assert ref.endpoint == a.endpoint_id
        assert ref.location == a.peer_server.address
        n = client.get_result(
            client.run(fid_c, b.endpoint_id, data={"blob": ref}),
            timeout=15)
        assert n == 64 * 1024
        assert svc.hub_relays == 0
        assert svc.hub_relay_bytes == 0
        assert b.peer_client.stats.direct_fetches == 1
        assert b.peer_client.stats.direct_bytes >= 64 * 1024
    finally:
        a.stop()
        b.stop()


def test_producer_death_falls_back_to_relay_exactly_once(tcp_service):
    """Kill the producer's peer listener between two fetches: the cached
    connection dies, the re-dial fails, and the consumer relays through
    the hub — exactly one relay, not a retry storm."""
    svc, client, address = tcp_service
    a, b = _two_endpoints(svc, client, address)
    try:
        fid_p = client.register_function(produce_blob)
        fid_c = client.register_function(blob_len)
        refs = [client.get_result(
                    client.run(fid_p, a.endpoint_id, data={"n": 32 * 1024}),
                    timeout=15) for _ in range(2)]
        # first ref: direct fetch, connection cached
        assert client.get_result(
            client.run(fid_c, b.endpoint_id, data={"blob": refs[0]}),
            timeout=15) == 32 * 1024
        assert b.peer_client.stats.direct_fetches == 1
        assert svc.hub_relays == 0
        # producer's peer plane dies (agent + hub channel stay up)
        a.agent.peer_server.close()
        assert client.get_result(
            client.run(fid_c, b.endpoint_id, data={"blob": refs[1]}),
            timeout=15) == 32 * 1024
        stats = b.peer_client.stats
        assert stats.relay_fetches == 1          # fallback fired once
        assert stats.direct_fetches == 1         # and only after direct
        # the direct rung definitively failed first — either the cached
        # connection died mid-fetch (no re-dial: dials stays 1) or the
        # re-dial was refused (dial_failures counts it); anything beyond
        # one extra dial would be a retry storm
        assert stats.dial_failures >= 1 or stats.dials == 1
        assert stats.dials <= 2
        assert svc.hub_relays == 1
        assert svc.hub_relay_bytes >= 32 * 1024
    finally:
        a.stop()
        b.stop()


def test_conn_cache_survives_reregistration(tcp_service):
    """A producer re-registering at the same peer address must not force
    consumers to re-dial: the grant is re-minted but the cached
    connection keeps serving."""
    svc, client, address = tcp_service
    a, b = _two_endpoints(svc, client, address)
    try:
        fid_p = client.register_function(produce_blob)
        fid_c = client.register_function(blob_len)
        ref = client.get_result(
            client.run(fid_p, a.endpoint_id, data={"n": 8 * 1024}),
            timeout=15)
        assert client.get_result(
            client.run(fid_c, b.endpoint_id, data={"blob": ref}),
            timeout=15) == 8 * 1024
        assert b.peer_client.stats.dials == 1
        # the producer re-registers (connection loss) at the same address
        svc.pool.reattach(a.endpoint_id, svc.endpoints[a.endpoint_id]
                          .channel)
        svc._note_peer_addr(a.endpoint_id, a.peer_server.address)
        # force the consumer's grant stale so the next fetch re-resolves
        b.peer_client._grants.clear()
        ref2 = client.get_result(
            client.run(fid_p, a.endpoint_id, data={"n": 8 * 1024}),
            timeout=15)
        assert client.get_result(
            client.run(fid_c, b.endpoint_id, data={"blob": ref2}),
            timeout=15) == 8 * 1024
        stats = b.peer_client.stats
        assert stats.direct_fetches == 2
        assert stats.dials == 1                  # no re-dial
        assert svc.hub_relays == 0
    finally:
        a.stop()
        b.stop()


def test_heartbeat_inventory_gc_of_stale_grants(tcp_service):
    """Heartbeats advertise the store's version-stamped inventory; when a
    producer's store mutates, the service GCs the cached signaling grant
    keyed on the old version (satellite: evicted-refs cleanup)."""
    svc, client, address = tcp_service
    a, b = _two_endpoints(svc, client, address)
    try:
        fid_p = client.register_function(produce_blob)
        fid_c = client.register_function(blob_len)
        ref = client.get_result(
            client.run(fid_p, a.endpoint_id, data={"n": 4 * 1024}),
            timeout=15)
        assert client.get_result(
            client.run(fid_c, b.endpoint_id, data={"blob": ref}),
            timeout=15) == 4 * 1024
        line = svc.pool.line(a.endpoint_id)
        assert wait_until(lambda: line.advertised.store_version > 0)
        assert line.advertised.store_keys >= 1
        assert line.advertised.store_bytes > 0
        key = (a.endpoint_id, b.endpoint_id)
        assert key in svc._peer_grants
        # the producer's store mutates → version moves → grant GC'd
        a.agent.store.set("other", b"x" * 64)
        old_version = line.advertised.store_version
        assert wait_until(
            lambda: line.advertised.store_version > old_version)
        svc._sweep_peer_state()
        assert key not in svc._peer_grants
    finally:
        a.stop()
        b.stop()
