"""Optimizer substrate: AdamW + clipping + schedule built from scratch."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # not in all images
from hypothesis import given, settings, strategies as st

from repro.configs import TrainConfig
from repro.train import (
    adamw_update,
    clip_by_global_norm,
    global_norm,
    init_opt_state,
    lr_schedule,
)


def test_adamw_converges_quadratic():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=200,
                     weight_decay=0.0, grad_clip=1e9)
    target = jnp.array([1.5, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)
    for step in range(200):
        grads = {"w": 2 * (params["w"] - target)}
        params, opt, _ = adamw_update(grads, opt, params,
                                      jnp.int32(step), tc)
    np.testing.assert_allclose(params["w"], target, atol=1e-2)


def test_weight_decay_applies_to_matrices_only():
    tc = TrainConfig(learning_rate=0.1, warmup_steps=0, total_steps=10,
                     weight_decay=1.0, grad_clip=1e9)
    params = {"mat": jnp.ones((2, 2)), "vec": jnp.ones(2)}
    opt = init_opt_state(params)
    zero_g = jax.tree.map(jnp.zeros_like, params)
    new, _, _ = adamw_update(zero_g, opt, params, jnp.int32(0), tc)
    assert float(jnp.max(jnp.abs(new["mat"]))) < 1.0      # decayed
    np.testing.assert_allclose(new["vec"], params["vec"])  # untouched


def test_clip_by_global_norm():
    g = {"a": jnp.full(4, 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(global_norm(clipped)) == pytest.approx(1.0, rel=1e-5)
    # below threshold → untouched
    g2 = {"a": jnp.full(4, 0.01)}
    c2, _ = clip_by_global_norm(g2, 1.0)
    np.testing.assert_allclose(c2["a"], g2["a"])


def test_lr_schedule_shape():
    tc = TrainConfig(learning_rate=1e-3, warmup_steps=10, total_steps=100)
    lrs = [float(lr_schedule(tc, jnp.int32(s))) for s in range(100)]
    assert lrs[0] == 0.0
    assert lrs[10] == pytest.approx(1e-3, rel=1e-3)        # peak post-warmup
    assert lrs[99] < lrs[10]                               # decayed
    assert lrs[99] >= 0.1 * 1e-3 * 0.9                     # floor ≈ 10%


@settings(max_examples=20, deadline=None)
@given(st.floats(0.1, 100.0), st.integers(1, 64))
def test_property_clip_never_increases_norm(scale, n):
    g = {"x": jnp.ones(n) * scale}
    clipped, pre = clip_by_global_norm(g, 1.0)
    assert float(global_norm(clipped)) <= float(pre) + 1e-6
    assert float(global_norm(clipped)) <= 1.0 + 1e-5


def test_microbatch_equivalence():
    """Gradient accumulation over k microbatches == full-batch step."""
    from repro.configs import get_reduced_config
    from repro.models import get_model, concrete_batch
    from repro.configs import SMOKE_SHAPES
    from repro.train import init_train_state, make_train_step

    cfg = get_reduced_config("qwen1.5-0.5b")
    model = get_model(cfg)
    key = jax.random.PRNGKey(0)
    batch = concrete_batch(cfg, SMOKE_SHAPES["train_4k"], key)

    # tiny lr: Adam's first-step update is ±lr per element, so any
    # microbatch/full-batch divergence is bounded by 2·lr — a tight check
    # that accumulation produces the same mean gradients up to bf16 noise.
    outs, losses = {}, {}
    for mb in (None, 1):
        tc = TrainConfig(learning_rate=1e-5, warmup_steps=0, total_steps=2,
                         microbatch=mb)
        state = init_train_state(model, key)
        step = jax.jit(make_train_step(model, tc))
        new_state, m = step(state, batch)
        outs[mb] = new_state["params"]
        losses[mb] = float(m["loss"])
    assert abs(losses[None] - losses[1]) < 5e-3
    for a, b in zip(jax.tree.leaves(outs[None]), jax.tree.leaves(outs[1])):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=3e-5)
