"""Per-arch smoke tests (deliverable f): every assigned architecture, as a
REDUCED config of the same family, runs one forward/train step on CPU with
finite outputs and correct shapes. Full configs are exercised only via the
dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import (
    ARCH_IDS,
    SMOKE_SHAPES,
    TrainConfig,
    get_config,
    get_reduced_config,
)
from repro.models import concrete_batch, get_model, input_specs
from repro.train import init_train_state, make_train_step

KEY = jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_reduced_config(arch)
    model = get_model(cfg)
    state = init_train_state(model, KEY)
    # warmup_steps=0 → full lr at step 0, so one step must move params
    step = jax.jit(make_train_step(model, TrainConfig(warmup_steps=0,
                                                      total_steps=4)))
    batch = concrete_batch(cfg, SMOKE_SHAPES["train_4k"], KEY)
    new_state, metrics = step(state, batch)
    assert jnp.isfinite(metrics["loss"])
    assert jnp.isfinite(metrics["grad_norm"])
    assert int(new_state["step"]) == 1
    # params actually moved
    moved = any(
        not jnp.array_equal(a, b)
        for a, b in zip(jax.tree.leaves(state["params"]),
                        jax.tree.leaves(new_state["params"])))
    assert moved


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_prefill_decode_shapes(arch):
    cfg = get_reduced_config(arch)
    model = get_model(cfg)
    params = model.init(KEY)
    batch = concrete_batch(cfg, SMOKE_SHAPES["prefill_32k"], KEY,
                           kind="prefill")
    logits, cache = model.prefill(params, batch)
    B = batch["tokens"].shape[0]
    assert logits.shape == (B, cfg.padded_vocab())
    assert jnp.isfinite(logits).all()
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, {"tokens": tok})
    assert logits2.shape == (B, cfg.padded_vocab())
    assert jnp.isfinite(logits2).all()
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_abstract_only(arch):
    """Full published configs must build abstract params without allocating."""
    import math
    cfg = get_config(arch)
    model = get_model(cfg)
    abs_params = model.abstract_params()
    n = sum(math.prod(l.shape) for l in jax.tree.leaves(abs_params))
    assert n == model.param_count()
    from repro.configs import SHAPES
    specs = input_specs(cfg, list(SHAPES.values())[0])
    assert all(hasattr(s, "shape") for s in specs.values())


@pytest.mark.parametrize("arch", ["qwen1.5-0.5b", "mamba2-370m",
                                  "recurrentgemma-9b"])
def test_unrolled_matches_scan(arch):
    """scan_layers=False (roofline analysis path) must agree numerically."""
    from repro.models.knobs import RunKnobs
    cfg = get_reduced_config(arch)
    model = get_model(cfg)
    params = model.init(KEY)
    batch = concrete_batch(cfg, SMOKE_SHAPES["train_4k"], KEY)
    l1, _ = model.loss(params, batch,
                       knobs=RunKnobs(q_block=32, kv_block=32,
                                      scan_layers=True))
    l2, _ = model.loss(params, batch,
                       knobs=RunKnobs(q_block=32, kv_block=32,
                                      scan_layers=False))
    # bf16 compute: unrolled vs scan changes XLA fusion/reassociation order
    assert abs(float(l1) - float(l2)) < 5e-3
