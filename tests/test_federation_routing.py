"""Federation-level routing (DESIGN.md §4): submit without an endpoint and
let the service's EndpointRouter place the task across the fleet using
heartbeat-advertised load + warm-container state."""
import pytest

from repro.core import (
    ContainerSpec,
    EndpointInfo,
    EndpointUnavailable,
    FuncXClient,
    FuncXService,
    LeastLoadedEndpointRouter,
    RandomEndpointRouter,
    RoutingContext,
    WarmingAwareEndpointRouter,
    make_router,
)
from conftest import wait_until


# ------------------------------------------------------------------ unit level

def _info(eid, **kw):
    return EndpointInfo(endpoint_id=eid, **kw)


def _ctx(container_type):
    return RoutingContext(container_type=container_type)


def test_warming_aware_picks_warm_endpoint_over_cold():
    eps = [
        _info("cold", capacity=8, idle_workers=8),
        _info("warm", capacity=4, idle_workers=2,
              warm_idle={"model/x": 2}, warm_total={"model/x": 2}),
        _info("warm_other", capacity=4, idle_workers=4,
              warm_idle={"model/y": 4}, warm_total={"model/y": 4}),
    ]
    r = WarmingAwareEndpointRouter()
    assert r.select(_ctx("model/x"), eps) == "warm"
    assert r.select(_ctx("model/y"), eps) == "warm_other"
    # no warm anywhere: falls back to least loaded, not an error
    assert r.select(_ctx("model/z"), eps) in {"cold", "warm", "warm_other"}


def test_warming_aware_prefers_warm_busy_over_cold_start():
    eps = [
        _info("cold_idle", capacity=8, idle_workers=8),
        _info("warm_busy", capacity=4, queued=1,
              warm_total={"model/x": 3}),
    ]
    assert WarmingAwareEndpointRouter().select(_ctx("model/x"),
                                               eps) == "warm_busy"


def test_least_loaded_normalizes_by_capacity():
    eps = [
        _info("big_busy", capacity=16, queued=16),       # load 1.0
        _info("small_idle", capacity=2, queued=0),       # load 0.0
        _info("small_swamped", capacity=2, queued=10),   # load 5.0
    ]
    assert LeastLoadedEndpointRouter().select(_ctx("python"),
                                              eps) == "small_idle"


def test_routers_skip_disconnected_endpoints():
    eps = [
        _info("down", connected=False, capacity=8,
              warm_idle={"python": 8}, warm_total={"python": 8}),
        _info("up", capacity=2),
    ]
    for name in ("random", "least_loaded", "warming_aware"):
        assert make_router(name, tier="endpoint").select(
            _ctx("python"), eps) == "up"


def test_random_router_covers_fleet():
    eps = [_info(f"e{i}") for i in range(4)]
    r = RandomEndpointRouter(seed=1)
    picked = {r.select(_ctx("python"), eps) for _ in range(100)}
    assert picked == {"e0", "e1", "e2", "e3"}


# ----------------------------------------------------------------- integration

def test_submit_without_endpoint_routes_and_completes(service, client):
    fid = client.register_function(lambda d: d["i"] * 3)
    _, a1 = service.make_endpoint(client.token, "ep1", n_managers=1)
    _, a2 = service.make_endpoint(client.token, "ep2", n_managers=1)
    ids = client.batch_run([(fid, None, {"i": i}) for i in range(10)])
    assert client.get_batch_results(ids, timeout=30) == \
        [3 * i for i in range(10)]
    a1.stop()
    a2.stop()


def test_submit_without_endpoints_raises(service, client):
    fid = client.register_function(lambda d: d)
    with pytest.raises(EndpointUnavailable):
        client.run(fid, None, data=1)


def test_federation_warming_aware_picks_warm_endpoint():
    svc = FuncXService(heartbeat_timeout=0.3, purge_on_get=False,
                       endpoint_router="warming_aware")
    try:
        tok = svc.register_user("u")
        cl = FuncXClient(svc, tok)
        svc.register_container(ContainerSpec("special",
                                             build=lambda: {"m": 1}))
        def probe(data, env):
            return env["m"]
        fid = cl.register_function(probe, container_type="special")
        eid_warm, a1 = svc.make_endpoint(tok, "warm", n_managers=1,
                                         workers_per_manager=1)
        eid_cold, a2 = svc.make_endpoint(tok, "cold", n_managers=1,
                                         workers_per_manager=1)
        # warm one endpoint by targeting it directly...
        assert cl.get_result(cl.run(fid, eid_warm, data={}), timeout=10) == 1
        # ...and wait for its heartbeat to advertise the warm container
        assert wait_until(
            lambda: svc.pool.line(eid_warm).advertised.warm_idle.get(
                "special", 0) > 0, timeout=5)
        # routed submissions now all land on the warm endpoint
        ids = [cl.run(fid, None, data={}) for _ in range(4)]
        assert all(svc.get_task(t).endpoint_id == eid_warm for t in ids)
        assert cl.get_batch_results(ids, timeout=30) == [1] * 4
        assert all(not svc.get_task(t).cold_start for t in ids)
        a1.stop()
        a2.stop()
    finally:
        svc.shutdown()


def test_batch_submit_groups_by_endpoint(service, client):
    fid = client.register_function(lambda d: d["i"])
    eid1, a1 = service.make_endpoint(client.token, "ep1", n_managers=1)
    eid2, a2 = service.make_endpoint(client.token, "ep2", n_managers=1)
    reqs = [(fid, [eid1, eid2, None][i % 3], {"i": i}) for i in range(12)]
    ids = client.batch_run(reqs)
    assert client.get_batch_results(ids, timeout=30) == list(range(12))
    a1.stop()
    a2.stop()


def test_routed_batch_spreads_over_fleet():
    """A routed batch must not collapse onto the momentary best endpoint:
    each pick feeds back into the batch-local snapshot."""
    svc = FuncXService(heartbeat_timeout=0.5, endpoint_router="least_loaded")
    try:
        tok = svc.register_user("u")
        cl = FuncXClient(svc, tok)
        fid = cl.register_function(lambda d: d)
        eids = [svc.register_endpoint(tok, f"ep{i}")[0] for i in range(4)]
        cl.batch_run([(fid, None, i) for i in range(12)])
        per_ep = [svc.pool.line(e).queue_len() +
                  svc.pool.line(e).in_flight_count() for e in eids]
        assert per_ep == [3, 3, 3, 3]
    finally:
        svc.shutdown()


def test_failed_batch_orphans_no_tasks(service, client):
    """A bad request anywhere in the batch fails the whole call before any
    task is stored — nothing is left PENDING and unreachable."""
    from repro.core import RegistrationError
    fid = client.register_function(lambda d: d)
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1)
    n_before = len(service.tasks)
    with pytest.raises(RegistrationError):
        client.batch_run([(fid, eid, 1), ("no-such-function", eid, 2)])
    assert len(service.tasks) == n_before
    assert service.pool.line(eid).queue_len() == 0
    agent.stop()


def test_batch_submit_validates_token_once(service, client, monkeypatch):
    fid = client.register_function(lambda d: d["i"])
    eid, agent = service.make_endpoint(client.token, "ep", n_managers=1)
    calls = []
    orig = service.auth.validate
    monkeypatch.setattr(service.auth, "validate",
                        lambda *a, **k: calls.append(1) or orig(*a, **k))
    ids = service.submit_batch(client.token,
                               [(fid, eid, {"i": i}) for i in range(16)])
    assert len(calls) == 1
    assert client.get_batch_results(ids, timeout=30) == list(range(16))
    agent.stop()
