"""End-to-end driver tests: the training loop (with crash-restart) and the
federated serving driver, as subprocess invocations of the public CLIs."""
import os
import subprocess
import sys
import tempfile


ENV = {**os.environ, "PYTHONPATH": "src"}
ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def run_cli(args, timeout=420):
    return subprocess.run([sys.executable] + args, cwd=ROOT, env=ENV,
                          capture_output=True, text=True, timeout=timeout)


def test_train_driver_smoke_and_resume():
    with tempfile.TemporaryDirectory() as d:
        r = run_cli(["-m", "repro.launch.train", "--arch", "qwen1.5-0.5b",
                     "--smoke", "--steps", "20", "--batch", "4",
                     "--seq", "32", "--ckpt-dir", d, "--ckpt-every", "10"])
        assert r.returncode == 0, r.stderr[-2000:]
        assert "loss" in r.stdout
        r2 = run_cli(["-m", "repro.launch.train", "--arch", "qwen1.5-0.5b",
                      "--smoke", "--steps", "30", "--batch", "4",
                      "--seq", "32", "--ckpt-dir", d, "--resume"])
        assert r2.returncode == 0, r2.stderr[-2000:]
        assert "resumed from step 20" in r2.stdout


def test_serve_driver_smoke():
    r = run_cli(["-m", "repro.launch.serve", "--arch", "qwen1.5-0.5b",
                 "--requests", "6", "--tokens", "3", "--prompt-len", "8"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "cold request" in r.stdout
    assert "warm requests" in r.stdout


def test_quickstart_example():
    r = run_cli(["examples/quickstart.py"])
    assert r.returncode == 0, r.stderr[-2000:]
    assert "'spots': 2" in r.stdout
