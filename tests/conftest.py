import threading
import time

import pytest

# NOTE: XLA_FLAGS device-count override is deliberately NOT set here —
# tests must see the real single CPU device (only launch/dryrun.py uses
# the 512-device placeholder world).


@pytest.fixture
def service():
    """A FuncXService with fast heartbeats + cleanup."""
    from repro.core import FuncXService
    svc = FuncXService(heartbeat_timeout=0.3)
    yield svc
    svc.shutdown()
    time.sleep(0.05)


@pytest.fixture
def client(service):
    from repro.core import FuncXClient
    token = service.register_user("tester")
    return FuncXClient(service, token)


def wait_until(pred, timeout=5.0, interval=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return False
