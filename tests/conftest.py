import time

import pytest

# NOTE: XLA_FLAGS device-count override is deliberately NOT set here —
# tests must see the real single CPU device (only launch/dryrun.py uses
# the 512-device placeholder world).


@pytest.fixture
def service():
    """A FuncXService with fast heartbeats + cleanup."""
    from repro.core import FuncXService
    svc = FuncXService(heartbeat_timeout=0.3)
    yield svc
    svc.shutdown()
    time.sleep(0.05)


@pytest.fixture
def client(service):
    from repro.core import FuncXClient
    token = service.register_user("tester")
    return FuncXClient(service, token)


def wait_until(pred, timeout=5.0, interval=0.01):
    deadline = time.time() + timeout
    while time.time() < deadline:
        if pred():
            return True
        time.sleep(interval)
    return False


@pytest.fixture
def tcp_service():
    """A FuncXService with its TCP listener open: (service, client,
    (host, port)). Remote endpoints dial the address and register over
    the wire."""
    from repro.core import FuncXClient, FuncXService
    svc = FuncXService(heartbeat_timeout=0.3)
    token = svc.register_user("tester")
    address = svc.listen()
    yield svc, FuncXClient(svc, token), address
    svc.shutdown()
    time.sleep(0.05)


def start_tcp_endpoint(client, address, **kw):
    """An in-thread endpoint agent on the dialing side of a real TCP
    socket — the federated deployment without the subprocess cost."""
    from repro.core import RemoteEndpointRunner
    kw.setdefault("n_managers", 1)
    kw.setdefault("workers_per_manager", 2)
    kw.setdefault("heartbeat_interval", 0.05)
    runner = RemoteEndpointRunner(
        address, client.endpoint_credentials(), **kw)
    runner.start()
    return runner
