"""Pluggable socket transport (DESIGN.md §2): LocalTransport default,
TcpTransport framing + reconnect, registration handshake, hub integration,
and the multi-process federated deployment."""
import socket
import subprocess
import time

import pytest

from repro.core import (
    Channel,
    ChannelHub,
    LocalTransport,
    RegistrationError,
    ShmTransport,
    TcpListener,
    TcpTransport,
    parse_hostport,
)
from repro.core.comms import TO_SERVICE
from repro.core.endpoint import demo_noop, demo_square
from repro.serialization import pack_buffer
from conftest import start_tcp_endpoint, wait_until


def test_parse_hostport():
    assert parse_hostport("127.0.0.1:9000") == ("127.0.0.1", 9000)
    assert parse_hostport(":9000") == ("127.0.0.1", 9000)
    assert parse_hostport("9000") == ("127.0.0.1", 9000)
    assert parse_hostport("example.org:80") == ("example.org", 80)


def test_local_transport_is_default_and_byte_identical():
    """Channel() keeps the in-memory queue pair, and a pre-packed buffer
    crosses it byte-identical (pack-once, DESIGN.md §5)."""
    ch = Channel()
    assert isinstance(ch.transport, LocalTransport)
    buf = pack_buffer({"x": 1}, tag="task")
    assert ch.send_to_service(buf)
    raw = ch.transport.recv_nowait(TO_SERVICE)
    assert raw == buf.data


class _Accepted:
    """Capture the transport the listener accepts."""

    def __init__(self):
        self.transport = None

    def __call__(self, transport, peer):
        self.transport = transport


def _tcp_pair():
    acc = _Accepted()
    listener = TcpListener("127.0.0.1", 0, acc)
    client = TcpTransport(connect=listener.address)
    assert wait_until(lambda: acc.transport is not None and client.connected,
                      timeout=5)
    return listener, acc.transport, client


def test_tcp_frames_byte_identical():
    """The bytes on the wire ARE the PackedBuffer bytes the facade
    produced — the pack-once invariant extends across the socket."""
    listener, server, client = _tcp_pair()
    try:
        ch_client = Channel(transport=client)
        buf = pack_buffer({"payload": b"\x00" * 1024}, tag="task")
        assert ch_client.send_to_service(buf)
        raw = None
        deadline = time.time() + 5
        while raw is None and time.time() < deadline:
            raw = server.recv(TO_SERVICE, timeout=0.2)
        assert raw == buf.data
    finally:
        client.close()
        server.close()
        listener.close()


def test_tcp_dial_backoff_until_listener_appears():
    """Nonblocking connect: the dialing side retries with backoff and
    attaches as soon as a listener shows up."""
    probe = socket.socket()
    probe.bind(("127.0.0.1", 0))
    port = probe.getsockname()[1]
    probe.close()                         # port free again; nothing listens
    client = TcpTransport(connect=("127.0.0.1", port), backoff=0.02)
    try:
        time.sleep(0.15)
        assert not client.connected       # still dialing into the void
        acc = _Accepted()
        listener = TcpListener("127.0.0.1", port, acc)
        assert wait_until(lambda: client.connected, timeout=5)
        assert client.dials >= 1
        listener.close()
    finally:
        client.close()


def test_hub_polls_socket_channel_through_token_path():
    """A socket-backed channel registers with the same ChannelHub and its
    frames surface through the same readiness-token poll — the pool adds
    no threads for TCP endpoints."""
    listener, server, client = _tcp_pair()
    try:
        hub = ChannelHub()
        ch_server = Channel(transport=server)
        hub.register("remote", ch_server)
        ch_client = Channel(transport=client)
        buf = pack_buffer({"hello": 1}, tag="hb")
        assert ch_client.send_to_service(buf)
        out = []
        deadline = time.time() + 5
        while not out and time.time() < deadline:
            out = hub.poll(timeout=0.2)
        assert len(out) == 1
        key, packed = out[0]
        assert key == "remote" and packed.tag == "hb"
        assert packed.data == buf.data    # still the producer's bytes
    finally:
        client.close()
        server.close()
        listener.close()


def test_register_handshake_rejects_bad_token(tcp_service):
    svc, client, address = tcp_service
    from repro.core import RemoteEndpointRunner
    runner = RemoteEndpointRunner(address, '{"not": "a token"}',
                                  register_timeout=5.0)
    with pytest.raises(RegistrationError):
        runner.start()
    runner.stop()


def test_tcp_endpoint_thread_roundtrip(tcp_service):
    """Full stack over a real socket, agent in a thread: submit → TCP →
    managers/workers → TCP → result."""
    svc, client, address = tcp_service
    runner = start_tcp_endpoint(client, address)
    try:
        fid = client.register_function(demo_square)
        ids = client.batch_run([(fid, runner.endpoint_id, {"x": i})
                                for i in range(40)])
        res = client.get_batch_results(ids, timeout=30)
        assert res == [i * i for i in range(40)]
        rec = svc.endpoints[runner.endpoint_id]
        # same-host dialers auto-negotiate the shm fast path; either way
        # a real socket (possibly ring-wrapped) carries the channel
        assert isinstance(rec.channel.transport, (TcpTransport,
                                                  ShmTransport))
    finally:
        runner.stop()


def test_lambda_ships_over_wire_via_cloudpickle(tcp_service):
    pytest.importorskip("cloudpickle")
    svc, client, address = tcp_service
    runner = start_tcp_endpoint(client, address)
    try:
        fid = client.register_function(lambda d: d["x"] + 1, name="inc")
        tid = client.run(fid, runner.endpoint_id, data={"x": 41})
        assert client.get_result(tid, timeout=15) == 42
    finally:
        runner.stop()


def test_unserializable_function_fails_task_not_agent(tcp_service):
    """A function body the service cannot serialize fails that one task
    with the wire error — the agent and its shared recv loop keep
    serving."""
    import threading
    from repro.core import TaskFailure
    svc, client, address = tcp_service
    runner = start_tcp_endpoint(client, address)
    try:
        ghost = client.register_function(demo_square, name="ghost")
        svc.functions[ghost].fn = threading.Lock()   # unpicklable body
        bad = client.run(ghost, runner.endpoint_id, data={"x": 1})
        with pytest.raises(TaskFailure):
            client.get_result(bad, timeout=15)
        fid = client.register_function(demo_noop)    # agent still alive
        good = client.run(fid, runner.endpoint_id, data={})
        assert client.get_result(good, timeout=15) is None
    finally:
        runner.stop()


def test_accepted_connections_share_one_reactor(tcp_service):
    """Service-side thread cost of a TCP fleet is O(1): every accepted
    connection is fed by the one shared SocketReactor — dedicated
    `tcp-reader` threads exist only on the dialing (endpoint) side."""
    import threading
    svc, client, address = tcp_service
    runners = [start_tcp_endpoint(client, address) for _ in range(3)]
    try:
        names = [t.name for t in threading.enumerate()]
        assert names.count("socket-reactor") == 1
        # the 3 reader threads belong to the 3 dialing runners (which
        # stand in for remote processes); accepted sockets add none
        assert names.count("tcp-reader") == 3
        for r in runners:
            tr = svc.endpoints[r.endpoint_id].channel.transport
            assert tr._reactor is svc._reactor
    finally:
        for r in runners:
            r.stop()


@pytest.mark.multiprocess
@pytest.mark.slow
def test_subprocess_endpoint_200_task_roundtrip(tcp_service):
    """Acceptance: a TcpTransport endpoint in a separate OS process
    completes a 200-task submit_batch round-trip."""
    from repro.core.endpoint import spawn_endpoint_process
    svc, client, address = tcp_service
    proc, eid = spawn_endpoint_process(
        address, client.endpoint_credentials(), name="subproc", workers=4)
    try:
        assert eid in svc.endpoints
        fid = client.register_function(demo_square)
        ids = client.batch_run([(fid, eid, {"x": i}) for i in range(200)])
        res = client.get_batch_results(ids, timeout=60)
        assert res == [i * i for i in range(200)]
        # the endpoint really is out-of-process; same-host negotiation
        # upgrades the socket channel to the shared-memory fast path
        assert isinstance(svc.endpoints[eid].channel.transport,
                          (TcpTransport, ShmTransport))
    finally:
        proc.terminate()
        try:
            proc.wait(timeout=5)
        except subprocess.TimeoutExpired:
            proc.kill()
