"""Function routing (paper §6.2): warming-aware beats random; tie-breaks;
beyond-paper cost/locality routers."""

from repro.core import (
    CostAwareRouter,
    LocalityAwareRouter,
    ManagerInfo,
    RandomRouter,
    RoutingContext,
    WarmingAwareRouter,
)


def mi(mid, idle=2, queued=0, warm_idle=None, warm_total=None, cap=4,
       keys=()):
    return ManagerInfo(mid, idle, queued, warm_idle or {},
                       warm_total or (warm_idle or {}), cap,
                       frozenset(keys))


def ctx(container_type="T", **kw):
    return RoutingContext(container_type=container_type, **kw)


def test_warming_aware_prefers_warm():
    r = WarmingAwareRouter()
    managers = [mi("cold"), mi("warm", warm_idle={"T": 1})]
    assert r.route(ctx(), managers) == "warm"


def test_warming_aware_load_balances_by_warm_count():
    r = WarmingAwareRouter()
    managers = [mi("m1", warm_idle={"T": 1}), mi("m2", warm_idle={"T": 3})]
    # paper: "priority to the one with the most available container workers"
    assert r.route(ctx(), managers) == "m2"


def test_warming_aware_second_chance_warm_busy():
    r = WarmingAwareRouter()
    managers = [mi("busywarm", idle=0, queued=2,
                   warm_idle={}, warm_total={"T": 2}),
                mi("cold", idle=2)]
    assert r.route(ctx(), managers) == "busywarm"


def test_warming_aware_random_fallback():
    r = WarmingAwareRouter(seed=1)
    managers = [mi("a"), mi("b"), mi("c")]
    picks = {r.route(ctx(), managers) for _ in range(30)}
    assert len(picks) > 1            # actually random among cold managers


def test_random_router_spreads():
    r = RandomRouter(seed=0)
    managers = [mi("a"), mi("b")]
    picks = {r.route(ctx(), managers) for _ in range(30)}
    assert picks == {"a", "b"}


def test_random_router_avoids_full():
    r = RandomRouter(seed=0)
    managers = [mi("full", idle=0, queued=4, cap=4), mi("free")]
    assert all(r.route(ctx(), managers) == "free" for _ in range(10))


def test_cost_aware_uses_measured_build_times():
    r = CostAwareRouter(mean_task_s=0.01)
    r.observe_build("T", 5.0)
    managers = [mi("cold"), mi("warm", queued=3, warm_total={"T": 1},
                               warm_idle={})]
    # queue wait (3/4 * 0.01) << cold start (5s) → pick warm-but-queued
    assert r.route(ctx(), managers) == "warm"


def test_cost_aware_prefers_short_queue_when_cold_cheap():
    r = CostAwareRouter(default_cold_cost=0.0001, mean_task_s=1.0)
    managers = [mi("empty", queued=0), mi("busy", queued=4)]
    assert r.route(ctx(), managers) == "empty"


def test_locality_breaks_warm_ties():
    r = LocalityAwareRouter()
    managers = [mi("far", warm_idle={"T": 2}),
                mi("near", warm_idle={"T": 2}, keys={"input/x"})]
    assert r.route(ctx(input_keys=frozenset({"input/x"})),
                   managers) == "near"


def test_empty_managers_returns_none():
    for r in (RandomRouter(), WarmingAwareRouter(), CostAwareRouter(),
              LocalityAwareRouter()):
        assert r.route(ctx(), []) is None
