"""Quickstart — the funcX SDK flow from the paper's Listing 1, runnable
end to end on one machine:

    PYTHONPATH=src python examples/quickstart.py

1. stand up the cloud service
2. register a function
3. deploy an endpoint ("turn this machine into a function-serving system")
4. run the function remotely, retrieve the result asynchronously
"""
import time

import numpy as np

from repro.core import FuncXClient, FuncXService


def process_stills(data):
    """Stand-in for the SSX pipeline's dials.stills_process (Listing 1)."""
    img = np.asarray(data["image"])
    # "analysis": background-subtract and count bright spots
    bg = np.median(img)
    spots = int((img > bg + 3 * img.std()).sum())
    return {"spots": spots, "bg": float(bg)}


def main():
    # --- cloud service + identity (Globus Auth analogue) -------------------
    service = FuncXService()
    token = service.register_user("scientist@aps.anl.gov")
    fc = FuncXClient(service, token)

    # --- register the function ---------------------------------------------
    func_id = fc.register_function(process_stills)
    print(f"registered function {func_id[:8]}…")

    # --- deploy an endpoint (this laptop) -----------------------------------
    endpoint_id, agent = service.make_endpoint(
        token, "my-laptop", n_managers=1, workers_per_manager=4)
    print(f"endpoint {endpoint_id[:8]}… online "
          f"({sum(len(m.workers) for m in agent.managers.values())} workers)")

    # --- run -----------------------------------------------------------------
    rng = np.random.default_rng(0)
    image = rng.normal(100.0, 5.0, (256, 256))
    image[64, 64] = image[128, 200] = 10_000.0        # two bright spots

    task_id = fc.run(func_id, endpoint_id, data={"image": image})
    print(f"submitted task {task_id[:8]}… (async)")
    result = fc.get_result(task_id, timeout=30)
    print(f"result: {result}")

    # --- batch (paper §4.6) ---------------------------------------------------
    t0 = time.perf_counter()
    outs = fc.map(func_id, endpoint_id,
                  [{"image": rng.normal(100, 5, (128, 128))}
                   for _ in range(32)])
    print(f"batch of 32 images in {time.perf_counter()-t0:.2f}s "
          f"→ {sum(o['spots'] for o in outs)} spots total")

    bd = None
    agent.stop()
    service.shutdown()
    print("done.")


if __name__ == "__main__":
    main()
