"""Hierarchical relay tree with a mid-burst leaf failure (paper §5,
DESIGN.md §11):

    PYTHONPATH=src python examples/hierarchical_fleet.py

The paper's interchange tier, live: the cloud service sees exactly ONE
registered endpoint, but behind it sits a two-level relay tree of real
OS processes —

    service ← interchange "site" ← interchange "rack" ← 2 leaf endpoints

Every arrow is the same wire protocol (Register/RegisterAck, packed
TaskBatch frames, synthesized heartbeats with backpressure credits), so
relays compose: the "rack" interchange registers with the "site"
interchange exactly the way a plain endpoint would.

The script bursts a batch through the tree, then — while tasks are in
flight — SIGKILLs one leaf endpoint process. No goodbye, no flush: its
heartbeats just stop. The rack-level interchange notices, requeues that
leaf's in-flight tasks into its backlog, and redispatches them to the
surviving leaf. The self-check asserts every task completed exactly
once with the right answer, and that the service's thread count never
grew — the whole tree costs the service O(1) threads.
"""
import signal
import threading
import time

from repro.core import FuncXClient, FuncXService, Interchange
from repro.core.endpoint import spawn_endpoint_process


def busy_square(data):
    time.sleep(0.02)                   # long enough to be in flight mid-kill
    return data["x"] * data["x"]


def main():
    service = FuncXService(heartbeat_timeout=2.0)
    leaf_procs = []
    site = rack = None
    try:
        host, port = service.listen()
        token = service.register_user("fleet-admin")
        client = FuncXClient(service, token)
        fid = client.register_function(busy_square)
        threads_before = threading.active_count()

        # --- build the two-level tree (leaves are real OS processes; the
        # relays run in-process here so we can read their gauges, but
        # `python -m repro.core.interchange` spawns the identical thing)
        site = Interchange(f"{host}:{port}", client.endpoint_credentials(),
                           name="site", depth=10_000, leaf_timeout=0.6)
        site_eid = site.start()
        rack = Interchange(site.leaf_address, site.leaf_token,
                           name="rack", depth=10_000, leaf_timeout=0.6)
        rack.start()
        for i in range(2):
            proc, leaf_eid = spawn_endpoint_process(
                rack.leaf_address, client.endpoint_credentials(),
                name=f"leaf{i}", workers=2, shm=False, peer=False)
            leaf_procs.append(proc)
            print(f"leaf{i} registered with rack as {leaf_eid}")
        print(f"service sees one endpoint: {site_eid} "
              f"(tree: site -> rack -> {len(leaf_procs)} leaves)")

        # --- burst through the tree, then kill a leaf mid-flight
        n = 60
        ids = client.batch_run([(fid, site_eid, {"x": i})
                                for i in range(n)])
        while rack.tasks_dispatched < 8:   # wait until work is in flight
            time.sleep(0.01)
        victim = leaf_procs[0]
        victim.send_signal(signal.SIGKILL)
        print(f"killed leaf pid={victim.pid} mid-burst "
              f"({rack.tasks_dispatched} tasks already dispatched)")

        results = client.get_batch_results(ids, timeout=120)

        # --- self-checks: exactly-once, rerouted, O(1) service threads
        assert results == [i * i for i in range(n)], "wrong results"
        purged = 0
        for tid in ids:                    # purge-on-get ⇒ second fetch fails
            try:
                service.get_task(tid)
            except KeyError:
                purged += 1
        assert purged == n, "a task resolved more than once"
        assert rack.requeues > 0, "leaf death never triggered a requeue"
        threads_added = threading.active_count() - threads_before
        print(f"all {n} tasks completed exactly once; "
              f"{rack.requeues} requeued off the dead leaf; "
              f"dedup dropped {rack.dedup_dropped + site.dedup_dropped}")
        # in-process relays add their own threads; only the *service*
        # stays O(1) — with subprocess relays (the normal deployment,
        # see benchmarks/interchange_bench.py) the delta is 0.
        print(f"relay tree gauges: site backlog_peak={site.backlog_peak} "
              f"rack backlog_peak={rack.backlog_peak} "
              f"(threads incl. in-process relays: +{threads_added})")
        print("OK")
    finally:
        for p in leaf_procs:
            if p.poll() is None:
                p.terminate()
        if rack is not None:
            rack.stop()
        if site is not None:
            site.stop()
        service.shutdown()


if __name__ == "__main__":
    main()
