"""Federated deployment (DESIGN.md §2): endpoints as separate OS
processes over the TCP transport — the paper's actual topology, where the
cloud service and the edge endpoint agents share only a socket.

    PYTHONPATH=src python examples/remote_endpoint.py [--endpoints 2]

The service opens a TCP listener; each endpoint agent is spawned as

    python -m repro.core.endpoint --connect HOST:PORT --token @FILE

registers over the wire (Register/RegisterAck handshake), pulls function
bodies on demand (FnRequest/FnResponse), executes with its local
managers/workers, and streams results back over the same socket. The
client side drives it all through the futures-native FuncXExecutor
(DESIGN.md §8) and harvests in completion order. Midway through, the
demo kills one endpoint's connection to show the requeue-on-disconnect +
re-dial + re-register recovery path — futures for the orphaned tasks
resolve once the endpoint recovers.
"""
import argparse
import tempfile
import time
from concurrent.futures import as_completed

from repro.core import FuncXClient, FuncXService
from repro.core.endpoint import demo_square, spawn_endpoint_process


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--endpoints", type=int, default=2)
    p.add_argument("--tasks", type=int, default=60)
    p.add_argument("--workers", type=int, default=4)
    args = p.parse_args()

    service = FuncXService(heartbeat_timeout=1.0)
    token = service.register_user("edge-team")
    client = FuncXClient(service, token)
    host, port = service.listen()
    print(f"service listening on {host}:{port}")

    with tempfile.NamedTemporaryFile("w", suffix=".token") as tf:
        tf.write(client.endpoint_credentials())
        tf.flush()
        procs, eids = [], []
        try:
            for i in range(args.endpoints):
                # == python -m repro.core.endpoint --connect host:port \
                #        --token @token-file --name edge-i --workers N
                proc, eid = spawn_endpoint_process(
                    (host, port), "@" + tf.name, name=f"edge-{i}",
                    workers=args.workers)
                procs.append(proc)
                eids.append(eid)
                print(f"endpoint {i}: pid={proc.pid} id={eid[:8]}…")

            fid = client.register_function(demo_square)
            with client.executor() as ex:
                t0 = time.perf_counter()
                futs = [ex.submit(fid, {"x": i},
                                  endpoint_id=eids[i % len(eids)])
                        for i in range(args.tasks)]
                res = [f.result(timeout=120) for f in futs]
                dt = time.perf_counter() - t0
                assert res == [i * i for i in range(args.tasks)]
                print(f"{args.tasks} tasks across {args.endpoints} "
                      f"processes in {dt:.2f}s "
                      f"({args.tasks / dt:.0f} tasks/s)")

                # fault demo: cut endpoint 0's socket mid-batch; the
                # futures stay pending until recovery re-runs the tasks
                rec = service.endpoints[eids[0]]
                futs = [ex.submit(fid, {"x": i}, endpoint_id=eids[0])
                        for i in range(10)]
                rec.channel.transport.disconnect()  # service-side cut
                print("cut endpoint 0's connection mid-batch…")
                n_done = 0
                for fut in as_completed(futs, timeout=120):
                    fut.result()
                    n_done += 1
                assert sorted(f.result() for f in futs) == \
                    sorted(i * i for i in range(10))
                print(f"…re-dial + re-register + requeue recovered all "
                      f"{n_done} tasks")
        finally:
            for proc in procs:
                proc.terminate()
            service.shutdown()
    print("done")


if __name__ == "__main__":
    main()
