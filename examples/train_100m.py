"""Train a ~100M-parameter model for a few hundred steps (deliverable b).

    PYTHONPATH=src python examples/train_100m.py [--steps 300]

Uses a scaled qwen1.5-family config (~100M params), the from-scratch AdamW,
synthetic copy-task data (loss provably decreases), async checkpointing,
and a mid-run simulated crash + restart from the latest checkpoint.
"""
import argparse
import tempfile
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ModelConfig, TrainConfig
from repro.models import get_model
from repro.models.knobs import RunKnobs
from repro.train import (
    abstract_train_state,
    checkpoint,
    init_train_state,
    make_train_step,
)
from repro.train.data import SyntheticLM


def config_100m() -> ModelConfig:
    # ~103M params: 16L, d=576, 8H, ffn 2304, vocab 32k (qwen-family block)
    return ModelConfig(
        name="qwen-100m", family="dense", n_layers=16, d_model=576,
        n_heads=8, n_kv_heads=8, d_ff=2304, vocab_size=32_000,
        qkv_bias=True, tie_embeddings=True)


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--steps", type=int, default=300)
    p.add_argument("--batch", type=int, default=16)
    p.add_argument("--seq", type=int, default=256)
    p.add_argument("--lr", type=float, default=6e-4)
    p.add_argument("--ckpt-dir", default=None)
    args = p.parse_args()
    ckpt_dir = args.ckpt_dir or tempfile.mkdtemp(prefix="ckpt100m_")

    cfg = config_100m()
    model = get_model(cfg)
    print(f"model: {cfg.name}  params={model.param_count()/1e6:.1f}M")

    tc = TrainConfig(learning_rate=args.lr, warmup_steps=30,
                     total_steps=args.steps)
    knobs = RunKnobs(remat="none", q_block=256, kv_block=256)
    step_fn = jax.jit(make_train_step(model, tc, knobs=knobs),
                      donate_argnums=(0,))
    state = init_train_state(model, jax.random.PRNGKey(0))
    saver = checkpoint.AsyncCheckpointer(ckpt_dir, max_to_keep=2)
    ds = SyntheticLM(cfg.vocab_size, args.seq, args.batch, seed=1)

    ckpt_every = max(min(50, args.steps // 3), 1)
    crash_at = 2 * ckpt_every               # always after a checkpoint
    losses = []
    t0 = time.perf_counter()
    step = 0
    for raw in ds:
        if step >= args.steps:
            break
        batch = {k: jnp.asarray(v) for k, v in raw.items()}
        state, m = step_fn(state, batch)
        step += 1
        losses.append(float(m["loss"]))
        if step % 25 == 0 or step == args.steps:
            tok_s = step * args.batch * args.seq / (time.perf_counter() - t0)
            print(f"step {step:4d}  loss {losses[-1]:.4f}  tok/s {tok_s:,.0f}")
        if step % ckpt_every == 0:
            saver.save(state, step)
        if step == crash_at:
            saver.wait()
            print(f"--- simulated crash at step {step}; restarting from "
                  f"checkpoint ---")
            state = checkpoint.restore(ckpt_dir, abstract_train_state(model))
            step = int(np.asarray(state["step"]))

    saver.save(state, step)
    saver.close()
    print(f"final loss {losses[-1]:.4f} (start {losses[0]:.4f}); "
          f"checkpoints: {checkpoint.available_steps(ckpt_dir)}")
    assert losses[-1] < losses[0], "loss must decrease"


if __name__ == "__main__":
    main()
