"""Colmena-style AI-directed campaign (paper §7.3.2 / §8) on funcX.

    PYTHONPATH=src python examples/colmena_steering.py

A *Thinker* (decision policy) steers a computational campaign across a
small federation: "simulation" tasks run on an HPC endpoint and return a
trajectory too large for the service payload path, so each result leaves
the endpoint as a **cross-endpoint DataRef** (DESIGN.md §9). A separate
"learn" endpoint fits the surrogate: its task consumes the accumulated
refs — stage-in dials the simulation endpoint directly over the peer
data plane; the hub only brokers addresses — and returns the small
steering summary (best points) the Thinker uses to pick the next batch.
The classic simulate → learn → steer loop, with intermediates never
transiting the cloud service.

The campaign optimizes a noisy 2-D function; steering must beat its own
first (random) round, and the self-check asserts zero hub-relay bytes.
"""
import time

import numpy as np

from repro.core import FuncXClient, FuncXService, RemoteEndpointRunner
from repro.data import DataRef


def simulate(data):
    """Expensive 'simulation': evaluate the hidden landscape at x, and
    emit a trajectory big enough to stage out as a ref."""
    import numpy as np
    x = np.asarray(data["x"])
    val = -np.sum((x - np.array([0.7, -0.3])) ** 2) + \
        0.05 * np.sin(13 * x).sum()
    time.sleep(0.005)
    traj = np.cumsum(np.sin(np.linspace(0, 40, 2048)[:, None] + x), axis=0)
    return {"x": x.tolist(), "y": float(val), "traj": traj}


def fit_surrogate(data):
    """'Train' on every simulation so far (refs resolved at stage-in) and
    hand the Thinker its steering summary: the top-3 points."""
    results = data["results"]
    top = sorted(results, key=lambda o: -o["y"])[:3]
    return {"best_y": top[0]["y"],
            "top_xs": [t["x"] for t in top],
            "n_seen": len(results)}


def main():
    service = FuncXService()
    token = service.register_user("thinker")
    client = FuncXClient(service, token)
    address = service.listen()
    creds = client.endpoint_credentials()

    def endpoint(name):
        r = RemoteEndpointRunner(address, creds, name=name, n_managers=1,
                                 workers_per_manager=4, stage_limit=1024)
        r.start()
        return r

    hpc = endpoint("hpc")        # simulations; results park in its store
    learn = endpoint("learn")    # surrogate fits; pulls refs peer-to-peer
    rng = np.random.default_rng(0)

    # the Thinker drives everything through futures-native executors
    # (DESIGN.md §8): submit, harvest as the simulations land
    ex_sim = client.executor(endpoint_id=hpc.endpoint_id)
    ex_fit = client.executor(endpoint_id=learn.endpoint_id)

    refs = []

    def run_round(xs):
        """One campaign round: simulate the batch, then fit the surrogate
        on everything so far. Returns the Thinker's steering summary."""
        futs = [ex_sim.submit(simulate, {"x": x.tolist()}) for x in xs]
        outs = [f.result(timeout=60) for f in futs]
        assert all(isinstance(o, DataRef) for o in outs), \
            "simulation outputs should stage out as refs"
        refs.extend(outs)
        fit = ex_fit.submit(fit_surrogate, {"results": list(refs)})
        return fit.result(timeout=60)

    t0 = time.perf_counter()
    history_best = []
    best = -1e9
    xs = rng.uniform(-2, 2, (8, 2))
    for rnd in range(6):
        summary = run_round(xs)
        best = max(best, summary["best_y"])
        history_best.append(best)
        # steer: perturbations of the surrogate's top points (exploit)
        # plus fresh uniform draws (explore)
        centers = np.array(summary["top_xs"])
        exploit = centers[rng.integers(0, len(centers), 6)] + \
            rng.normal(0, 0.3 / (rnd + 1), (6, 2))
        explore = rng.uniform(-2, 2, (2, 2))
        xs = np.concatenate([exploit, explore])
    t_steer = time.perf_counter() - t0

    stats = learn.peer_client.stats
    print(f"steered: best={best:.4f} in {t_steer:.2f}s (48 sims, "
          f"optimum ~0.1 at x*=[0.7,-0.3])")
    print(f"peer plane: {stats.direct_fetches} direct fetches, "
          f"{stats.direct_bytes / 1e6:.1f} MB simulation trajectories "
          f"endpoint-to-endpoint, hub relay bytes="
          f"{service.hub_relay_bytes}")
    ex_sim.shutdown(wait=True)
    ex_fit.shutdown(wait=True)
    hpc.stop()
    learn.stop()
    service.shutdown()
    # steering must improve on its own first (random) round, every sim
    # result must have crossed as a ref exactly once, and none of those
    # bytes may have transited the hub
    assert best >= history_best[0]
    assert stats.direct_fetches == len(refs), stats.as_dict()
    assert service.hub_relays == 0 and service.hub_relay_bytes == 0


if __name__ == "__main__":
    main()
