"""Colmena-style AI-directed campaign (paper §7.3.2 / §8) on funcX.

    PYTHONPATH=src python examples/colmena_steering.py

A *Thinker* (decision policy) steers a computational campaign: it submits
"simulation" tasks to a CPU endpoint, periodically "trains" a surrogate on
results from the store, and uses it to pick the next batch — the classic
simulate → learn → steer loop, with funcX as the execution fabric and the
in-memory store carrying task payloads (Table 2's communication stages).

The campaign optimizes a noisy 2-D function; steering must beat random.
"""
import time

import numpy as np

from repro.core import FuncXClient, FuncXService


def simulate(data):
    """Expensive 'simulation': evaluate the hidden landscape at x."""
    x = np.asarray(data["x"])
    val = -np.sum((x - np.array([0.7, -0.3])) ** 2) + \
        0.05 * np.sin(13 * x).sum()
    time.sleep(0.005)
    return {"x": x, "y": float(val)}


def main():
    service = FuncXService()
    token = service.register_user("thinker")
    client = FuncXClient(service, token)
    sim_id = client.register_function(simulate)
    eid, agent = service.make_endpoint(token, "hpc", n_managers=2,
                                       workers_per_manager=4)
    store = service.transfer.store_for(eid)
    rng = np.random.default_rng(0)

    # the Thinker drives everything through one futures-native executor
    # (DESIGN.md §8): submit by registered function id, harvest as the
    # simulations land instead of blocking on a whole-batch wave
    ex = client.executor(endpoint_id=eid)

    def run_batch(xs):
        futs = [ex.submit(sim_id, {"x": x}) for x in xs]
        outs = [f.result(timeout=60) for f in futs]
        for i, o in enumerate(outs):
            store.set(f"results/{time.monotonic():.6f}/{i}", o)
        return outs

    # --- random baseline ------------------------------------------------------
    t0 = time.perf_counter()
    random_best = -1e9
    for _ in range(6):
        outs = run_batch(rng.uniform(-2, 2, (8, 2)))
        random_best = max(random_best, max(o["y"] for o in outs))
    t_random = time.perf_counter() - t0

    # --- steered campaign -----------------------------------------------------
    t0 = time.perf_counter()
    history = []
    best = first_round_best = -1e9
    xs = rng.uniform(-2, 2, (8, 2))
    for rnd in range(6):
        outs = run_batch(xs)
        history.extend(outs)
        best = max(best, max(o["y"] for o in outs))
        if rnd == 0:
            first_round_best = best
        # "surrogate": local quadratic fit around the top-3 points;
        # next batch = perturbations of the best (exploit) + random (explore)
        top = sorted(history, key=lambda o: -o["y"])[:3]
        centers = np.stack([t["x"] for t in top])
        exploit = centers[rng.integers(0, 3, 6)] + \
            rng.normal(0, 0.3 / (rnd + 1), (6, 2))
        explore = rng.uniform(-2, 2, (2, 2))
        xs = np.concatenate([exploit, explore])
    t_steer = time.perf_counter() - t0

    print(f"random:  best={random_best:.4f} in {t_random:.2f}s (48 sims)")
    print(f"steered: best={best:.4f} in {t_steer:.2f}s (48 sims)")
    print(f"(optimum ≈ 0.1 at x*=[0.7,-0.3]; steering should get closer)")
    print(f"store carried {store.stats.sets} result objects, "
          f"{store.stats.bytes_in/1e3:.0f} kB")
    print(f"executor landed {ex.tasks_submitted} sims in "
          f"{ex.coalescer.flushes} coalesced flushes")
    ex.shutdown(wait=True)
    agent.stop()
    service.shutdown()
    # steering must improve on its own first (random) round
    assert best >= first_round_best


if __name__ == "__main__":
    main()
