"""Federated learning over funcX endpoints (paper §8 — the Flox case
study), on the real fabric:

    PYTHONPATH=src python examples/federated_learning.py

Two "edge" endpoints run as separate OS processes connected over TCP.
Each round, ``fedavg_local_train`` fans out through the futures-native
FuncXExecutor with a ``warmth_key`` naming the jitted train step
(DESIGN.md §10), so round 2+ lands on the worker that already compiled
it. The endpoints' ``stage_limit`` sits below the raw delta size, so
every local delta leaves its endpoint as a cross-endpoint **DataRef** —
the aggregation task (pinned to edge-0) pulls the other endpoints'
deltas peer-direct over the data plane (DESIGN.md §9), and only the
int8-compressed mean rides the hub back to the coordinator. The
self-check asserts the transport shape: deltas travelled as refs, and
zero delta bytes transited the hub relay.
"""
import subprocess
import time

import jax
import numpy as np

from repro.configs import get_reduced_config
from repro.core import FuncXClient, FuncXService
from repro.core.endpoint import spawn_endpoint_process
from repro.data import DataRef
from repro.models import get_model
from repro.train import (
    FedAvgCoordinator,
    fedavg_aggregate,
    fedavg_local_train,
    train_warmth_key,
)

ARCH = "qwen1.5-0.5b"
N_ENDPOINTS = 2
ROUNDS = 3


def main():
    cfg = get_reduced_config(ARCH)
    model = get_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    delta_nbytes = sum(np.asarray(l).astype(np.float32).nbytes
                       for l in jax.tree.leaves(params))

    service = FuncXService(heartbeat_timeout=2.0, shm=False)
    token = service.register_user("fl-coordinator")
    client = FuncXClient(service, token)
    fid_train = client.register_function(fedavg_local_train,
                                         name="flox/local_train")
    fid_agg = client.register_function(fedavg_aggregate,
                                       name="flox/aggregate")
    address = service.listen()
    cred = client.endpoint_credentials()

    # stage_limit below the raw delta size: every local_train result
    # becomes a DataRef parked in its endpoint's store; the compressed
    # mean (~4x smaller) still fits inline on the way back
    procs, eids = [], []
    for i in range(N_ENDPOINTS):
        p, eid = spawn_endpoint_process(
            address, cred, name=f"edge-{i}", workers=1, shm=False,
            stage_limit=delta_nbytes // 2)
        procs.append(p)
        eids.append(eid)
    print(f"federation: {N_ENDPOINTS} edge endpoints (subprocesses), "
          f"delta={delta_nbytes / 1e6:.2f} MB, "
          f"stage_limit={delta_nbytes // 2 / 1e6:.2f} MB")

    coord = FedAvgCoordinator(client, fid_train, eids, method="int8")
    t0 = time.perf_counter()
    try:
        with client.executor(batch_size=8) as ex:
            for rnd in range(ROUNDS):
                params, metrics, parts = coord.round_refs(
                    params, arch=ARCH, executor=ex, aggregate_fn=fid_agg,
                    local_steps=4, seed=rnd)
                assert all(isinstance(p, DataRef) for p in parts), \
                    "deltas should leave the edges as refs, not values"
                print(f"round {rnd}: mean local loss "
                      f"{metrics['mean_loss']:.4f}  compression "
                      f"{metrics['compression_ratio']:.1f}x  "
                      f"(warmth_key={train_warmth_key(ARCH, 8)})")
        # the aggregate pulled edge-1's delta peer-direct; nothing heavy
        # ever transited the hub
        assert service.hub_relays == 0 and service.hub_relay_bytes == 0, \
            "delta bytes took the hub relay"
        print(f"{ROUNDS} rounds in {time.perf_counter() - t0:.1f}s; "
              f"{coord.bytes_sent / 1e6:.2f} MB coordinator-bound "
              f"(vs {coord.bytes_uncompressed / 1e6:.2f} MB raw), "
              f"hub relay bytes={service.hub_relay_bytes}")
    finally:
        for p in procs:
            p.terminate()
        for p in procs:
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.kill()
        service.shutdown()


if __name__ == "__main__":
    main()
