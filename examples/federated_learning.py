"""Federated learning over funcX endpoints (paper §8 — the Flox case
study), with compressed delta exchange:

    PYTHONPATH=src python examples/federated_learning.py

Three "edge" endpoints hold disjoint data shards; each round they train
locally through the FaaS layer (warm container caches the jitted step),
ship int8-quantized model deltas (with error feedback) back to the
coordinator, which federated-averages and rebroadcasts. The compression
ratio is exactly what the rural-AI deployments in the paper need on weak
links.
"""
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import TrainConfig, get_reduced_config
from repro.core import FuncXClient, FuncXService
from repro.models import get_model
from repro.train import FedAvgCoordinator, init_opt_state, make_train_step
from repro.train.data import SyntheticLM


def main():
    cfg = get_reduced_config("qwen1.5-0.5b")
    model = get_model(cfg)
    tc = TrainConfig(learning_rate=5e-3, warmup_steps=0, total_steps=200)
    step_fn = jax.jit(make_train_step(model, tc))

    def local_train(data):
        params = jax.tree.map(jnp.asarray, data["params"])
        state = {"params": params, "opt": init_opt_state(params),
                 "step": jnp.zeros((), jnp.int32)}
        ds = SyntheticLM(cfg.vocab_size, 32, 8, seed=data["seed"])
        loss = 0.0
        for _, batch in zip(range(data["steps"]), ds):
            state, m = step_fn(state, {k: jnp.asarray(v)
                                       for k, v in batch.items()})
            loss = float(m["loss"])
        delta = jax.tree.map(
            lambda new, old: np.asarray(new) - np.asarray(old),
            state["params"], params)
        return {"delta": delta, "loss": loss}

    service = FuncXService()
    token = service.register_user("fl-coordinator")
    client = FuncXClient(service, token)
    fid = client.register_function(local_train, name="flox/local_train")

    eids, agents = [], []
    for i in range(3):
        eid, agent = service.make_endpoint(token, f"edge-{i}", n_managers=1,
                                           workers_per_manager=1)
        eids.append(eid)
        agents.append(agent)
    print(f"federation: {len(eids)} edge endpoints")

    coord = FedAvgCoordinator(client, fid, eids, method="int8")
    params = model.init(jax.random.PRNGKey(0))
    t0 = time.perf_counter()
    for rnd in range(4):
        params, metrics = coord.round(params, local_steps=10, seed=rnd)
        print(f"round {rnd}: mean local loss {metrics['mean_loss']:.4f}  "
              f"compression {metrics['compression_ratio']:.1f}×")
    print(f"4 rounds in {time.perf_counter()-t0:.1f}s; "
          f"{coord.bytes_sent/1e6:.2f} MB on the wire "
          f"(vs {coord.bytes_uncompressed/1e6:.2f} MB uncompressed)")
    for a in agents:
        a.stop()
    service.shutdown()


if __name__ == "__main__":
    main()
