"""End-to-end driver (deliverable b): serve a model with batched requests
through the federated FaaS layer.

    PYTHONPATH=src python examples/serve_federated.py [--arch qwen1.5-0.5b]

Two *endpoints* (≙ two pods of a TPU fleet) serve two different
architectures; the client routes per-request, a cold start is a real JIT
compile (container instantiation), warm requests hit the executable cache,
and concurrent requests are coalesced into batched executions.
"""
import argparse
import time

import numpy as np

from repro.core import FuncXClient, FuncXService
from repro.launch.serve import build_serving_container, generate_fn


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--archs", default="qwen1.5-0.5b,mamba2-370m")
    p.add_argument("--requests", type=int, default=24)
    p.add_argument("--tokens", type=int, default=8)
    args = p.parse_args()
    archs = args.archs.split(",")

    service = FuncXService(heartbeat_timeout=0.5)
    token = service.register_user("serving-team")
    client = FuncXClient(service, token)

    # one endpoint per architecture — the federation
    endpoints = {}
    for arch in archs:
        service.register_container(build_serving_container(arch, horizon=64))
        fid = client.register_function(
            generate_fn, name=f"generate/{arch}",
            container_type=f"serve/{arch}")
        eid, agent = service.make_endpoint(token, f"pod-{arch}",
                                           n_managers=1,
                                           workers_per_manager=2)
        endpoints[arch] = (fid, eid, agent)
        print(f"endpoint pod-{arch} online")

    rng = np.random.default_rng(0)
    for arch, (fid, eid, _) in endpoints.items():
        # cold start = JIT compile (the paper's Table 3 moment)
        t0 = time.perf_counter()
        client.get_result(client.run(fid, eid, data={
            "tokens": rng.integers(0, 1000, (1, 16)).astype(np.int32),
            "n_tokens": args.tokens}), timeout=600)
        print(f"[{arch}] cold request {time.perf_counter()-t0:.2f}s "
              f"(container build)")

        # warm batched traffic through the dynamic coalescer
        batcher = client.make_batcher(fid, eid, max_batch=4, max_wait=0.02)
        t0 = time.perf_counter()
        futs = [batcher.submit({
            "tokens": rng.integers(0, 1000, (1, 16)).astype(np.int32),
            "n_tokens": args.tokens}) for _ in range(args.requests)]
        outs = [f.result(timeout=600) for f in futs]
        dt = time.perf_counter() - t0
        print(f"[{arch}] {args.requests} warm requests in {dt:.2f}s "
              f"({args.requests/dt:.1f} req/s, "
              f"{batcher.batches_sent} coalesced batches); "
              f"sample: {np.asarray(outs[0]['tokens'])[0][:6]}")
        batcher.close()

    for _, (_, _, agent) in endpoints.items():
        agent.stop()
    service.shutdown()
    print("done.")


if __name__ == "__main__":
    main()
