"""Federated MapReduce over the peer data plane (paper §7.3.1 + §5).

    PYTHONPATH=src python examples/mapreduce.py

WordCount over generated text, spread across a federation: map tasks run
on two *map endpoints*, reduce tasks on a third. Each map output is
larger than the endpoint's stage-out limit, so it leaves the mapper as a
**cross-endpoint DataRef** — the bytes stay parked in the producer's
store. When a reduce task's stage-in meets those refs it dials the
producing endpoints directly over the peer data plane (DESIGN.md §9);
the service only brokers addresses and tokens. The self-check asserts
that no intermediate byte transited the hub (``hub_relay_bytes == 0``)
and that each map output crossed the wire exactly once even though every
reducer consumes it (the first fetch caches it in the reduce endpoint's
store — rung 0 for the other reducers).

The map phase still rides the futures-native FuncXExecutor (DESIGN.md
§8): refs stream back the moment each map future lands.
"""
import argparse
import time
from collections import Counter
from concurrent.futures import as_completed

import numpy as np

from repro.core import FuncXClient, FuncXService, RemoteEndpointRunner
from repro.data import DataRef


def map_fn(data):
    from collections import Counter
    counts = Counter(data["text"].split())
    # partition by reducer
    n_red = data["n_reducers"]
    parts = {}
    for w, c in counts.items():
        parts.setdefault(hash(w) % n_red, {})[w] = c
    return {"parts": parts}


def reduce_fn(data):
    total = {}
    for out in data["outputs"]:          # full map outputs (refs resolved
        part = out["parts"].get(data["reducer"], {})   # at stage-in)
        for w, c in part.items():
            total[w] = total.get(w, 0) + c
    top = sorted(total.items(), key=lambda kv: (-kv[1], kv[0]))[:5]
    return {"unique": len(total), "total": sum(total.values()), "top5": top}


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--maps", type=int, default=12)
    p.add_argument("--reducers", type=int, default=4)
    p.add_argument("--words-per-map", type=int, default=50_000)
    args = p.parse_args()

    service = FuncXService()
    token = service.register_user("mr-user")
    client = FuncXClient(service, token)
    address = service.listen()
    creds = client.endpoint_credentials()

    # two map endpoints + one reduce endpoint, all on real TCP channels;
    # the low stage_limit turns every map output into a DataRef
    def endpoint(name):
        r = RemoteEndpointRunner(address, creds, name=name, n_managers=1,
                                 workers_per_manager=4, stage_limit=2048)
        r.start()
        return r

    maps = [endpoint("map-a"), endpoint("map-b")]
    red = endpoint("reduce")

    rng = np.random.default_rng(0)
    vocab = np.array([f"word{i:05d}" for i in range(5000)])
    texts = [" ".join(rng.choice(vocab, args.words_per_map))
             for _ in range(args.maps)]

    t0 = time.perf_counter()
    refs = []
    with client.executor(endpoint_id=maps[0].endpoint_id) as ex_a, \
            client.executor(endpoint_id=maps[1].endpoint_id) as ex_b:
        futs = [
            (ex_a if m % 2 == 0 else ex_b).submit(
                map_fn, {"text": t, "n_reducers": args.reducers})
            for m, t in enumerate(texts)]
        for fut in as_completed(futs):
            refs.append(fut.result())
    t_map = time.perf_counter() - t0

    assert all(isinstance(r, DataRef) for r in refs), \
        "map outputs should leave the mapper as refs, not values"

    # reduce: every reducer consumes ALL map outputs (its partition of
    # each); stage-in resolves the refs endpoint-to-endpoint, pipelined
    # per producer, and caches them so only the first reducer pays wire
    t0 = time.perf_counter()
    with client.executor(endpoint_id=red.endpoint_id) as ex:
        red_outs = ex.map(reduce_fn, [{"outputs": refs, "reducer": r}
                                      for r in range(args.reducers)])
    t_red = time.perf_counter() - t0

    # ---- self-check -----------------------------------------------------
    expected = Counter(w for t in texts for w in t.split())
    assert sum(o["total"] for o in red_outs) == args.maps * args.words_per_map
    assert sum(o["unique"] for o in red_outs) == len(expected)
    merged = sorted((tuple(kv) for o in red_outs for kv in o["top5"]),
                    key=lambda kv: (-kv[1], kv[0]))[:5]
    assert merged == sorted(expected.items(),
                            key=lambda kv: (-kv[1], kv[0]))[:5]
    # the shuffle never transited the hub, and each map output crossed
    # the wire once (reducers 2..R hit the reduce store's cache)
    assert service.hub_relays == 0 and service.hub_relay_bytes == 0, \
        "intermediates took the hub relay"
    stats = red.peer_client.stats
    assert stats.direct_fetches == args.maps, stats.as_dict()

    print(f"map {t_map:.2f}s  reduce(+peer shuffle) {t_red:.2f}s  "
          f"unique_words={sum(o['unique'] for o in red_outs)}")
    print(f"peer shuffle: {stats.direct_fetches} direct fetches, "
          f"{stats.direct_bytes / 1e6:.1f} MB endpoint-to-endpoint, "
          f"hub relay bytes={service.hub_relay_bytes}")
    for r in maps + [red]:
        r.stop()
    service.shutdown()


if __name__ == "__main__":
    main()
