"""MapReduce through funcX + the intra-endpoint data store (paper §7.3.1).

    PYTHONPATH=src python examples/mapreduce.py [--store memory|sharedfs]

WordCount over generated text: map tasks shuffle partial counts through the
endpoint's store (Redis-analogue vs shared FS — Table 1's comparison),
reduce tasks merge. All tasks flow through the full FaaS path, driven by
the futures-native FuncXExecutor (DESIGN.md §8): the shuffle starts the
moment each map *future* completes — no barrier waiting for the slowest
mapper — and reduce results stream back the same way.
"""
import argparse
import tempfile
import time
from concurrent.futures import as_completed

import numpy as np

from repro.core import FuncXClient, FuncXService
from repro.data import InMemoryKVStore, SharedFSStore


def map_fn(data):
    from collections import Counter
    counts = Counter(data["text"].split())
    # partition by reducer
    n_red = data["n_reducers"]
    parts = {}
    for w, c in counts.items():
        parts.setdefault(hash(w) % n_red, {})[w] = c
    return {"parts": parts}


def reduce_fn(data):
    total = {}
    for part in data["parts"]:
        for w, c in part.items():
            total[w] = total.get(w, 0) + c
    top = sorted(total.items(), key=lambda kv: -kv[1])[:5]
    return {"unique": len(total), "top5": top}


def main():
    p = argparse.ArgumentParser()
    p.add_argument("--store", default="memory", choices=["memory", "sharedfs"])
    p.add_argument("--maps", type=int, default=12)
    p.add_argument("--reducers", type=int, default=4)
    p.add_argument("--words-per-map", type=int, default=50_000)
    args = p.parse_args()

    tmp = tempfile.mkdtemp(prefix="mr_")
    store = (InMemoryKVStore() if args.store == "memory"
             else SharedFSStore(tmp))

    service = FuncXService()
    token = service.register_user("mr-user")
    client = FuncXClient(service, token)
    eid, agent = service.make_endpoint(token, "cluster", n_managers=2,
                                       workers_per_manager=4, store=store)

    rng = np.random.default_rng(0)
    vocab = np.array([f"word{i:05d}" for i in range(5000)])
    texts = [" ".join(rng.choice(vocab, args.words_per_map))
             for _ in range(args.maps)]

    with client.executor(endpoint_id=eid) as ex:
        t0 = time.perf_counter()
        # map phase: one Future per mapper; the coalescer lands all of
        # them as a couple of packed batches, not args.maps submit calls
        map_futs = {ex.submit(map_fn, {"text": t,
                                       "n_reducers": args.reducers}): m
                    for m, t in enumerate(texts)}
        # shuffle each mapper's parts through the endpoint store the
        # moment its future resolves (Table 1's intermediate write)
        t_shuffle = 0.0
        for fut in as_completed(map_futs):
            m = map_futs[fut]
            ts = time.perf_counter()
            for r, part in fut.result()["parts"].items():
                store.set(f"shuffle/{m}/{r}", part)
            t_shuffle += time.perf_counter() - ts
        t_map = time.perf_counter() - t0

        ts = time.perf_counter()
        by_reducer = {r: [] for r in range(args.reducers)}
        for r in range(args.reducers):
            for m in range(args.maps):
                if store.exists(f"shuffle/{m}/{r}"):
                    by_reducer[r].append(store.get(f"shuffle/{m}/{r}"))
        t_shuffle += time.perf_counter() - ts

        t0 = time.perf_counter()
        red_outs = ex.map(reduce_fn, [{"parts": parts}
                                      for parts in by_reducer.values()])
        t_red = time.perf_counter() - t0

    unique = sum(o["unique"] for o in red_outs)
    print(f"store={args.store}: map+shuffle {t_map:.2f}s "
          f"(shuffle {t_shuffle:.3f}s)  "
          f"reduce {t_red:.2f}s  unique_words={unique}")
    print(f"store stats: {store.stats.as_dict()}")
    agent.stop()
    service.shutdown()


if __name__ == "__main__":
    main()
