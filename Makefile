# One-command entry points for the suite and benchmarks.
#
#   make test                 tier-1 test suite (ROADMAP.md verify command)
#   make test-fast            fast lane: skips tests marked `slow`
#   make lint                 ruff check (stdlib dead-import sweep if no ruff)
#   make bench-smoke          scaling benchmark in tiny mode (seconds)
#   make bench-serialization  §4.5 pack-once data plane benchmarks
#   make bench                full benchmark harness (writes BENCH_4.json)

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-fast lint bench-smoke bench-serialization bench

test:
	python -m pytest -x -q

test-fast:
	python -m pytest -x -q -m "not slow"

lint:
	python -m tools.lint

bench-smoke:
	python -m benchmarks.run --only fig4_scaling --tiny

bench-serialization:
	python -m benchmarks.run --only sec4.5_serialization

bench:
	python -m benchmarks.run
