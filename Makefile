# One-command entry points for the suite and benchmarks.
#
#   make test                 tier-1 test suite (ROADMAP.md verify command)
#   make test-fast            fast lane: skips tests marked `slow`
#   make lint                 ruff check (stdlib dead-import sweep if no ruff)
#   make bench-smoke          scaling benchmark in tiny mode (seconds)
#   make bench-serialization  §4.5 pack-once data plane benchmarks
#   make bench-results        §7.2.3 batched result plane gauges
#   make bench-results-gate   bench-results into a fresh artifact + compare
#                             against the committed BENCH_10.json baseline
#   make bench-shm            DESIGN.md §7 same-host shm vs tcp comparison
#   make bench-shm-gate       bench-shm (tiny) + gate: channels upgraded,
#                             ring path not collapsed
#   make bench-executor       DESIGN.md §8 futures-native submit coalescing
#   make bench-executor-gate  bench-executor (tiny) + gate: storm envelope
#                             ratio <= 1/8, no lone-submit linger, no
#                             throughput collapse vs per-call
#   make bench-p2p            DESIGN.md §9 peer data plane all-to-all shuffle
#   make bench-p2p-gate       bench-p2p (tiny) + gate: zero relay bytes on
#                             the peer lane, no speedup collapse vs the
#                             hub-relay path
#   make bench-serving        DESIGN.md §10 jit model zoo over socket
#                             endpoints: warmth-aware vs random routing
#   make bench-serving-gate   bench-serving (tiny) + gate: warmth-aware
#                             never loses to random on warm-hit rate, and
#                             keeps the fleet mostly jit-warm
#   make bench-interchange    DESIGN.md §11 hierarchical relay: 100k-task
#                             burst absorption + elastic leaf endpoints
#   make bench-interchange-gate bench-interchange (tiny) + gate: zero
#                             service threads added, full-burst queued
#                             depth, >=0.9x flat-fleet throughput,
#                             elastic scale-out observed
#   make bench                full benchmark harness (writes BENCH_10.json)

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test test-fast lint bench-smoke bench-serialization \
	bench-results bench-results-gate bench-shm bench-shm-gate \
	bench-executor bench-executor-gate bench-p2p bench-p2p-gate \
	bench-serving bench-serving-gate bench-interchange \
	bench-interchange-gate bench

test:
	python -m pytest -x -q

test-fast:
	python -m pytest -x -q -m "not slow"

lint:
	python -m tools.lint

bench-smoke:
	python -m benchmarks.run --only fig4_scaling --tiny

bench-serialization:
	python -m benchmarks.run --only sec4.5_serialization

bench-results:
	python -m benchmarks.run --only sec7.2.3_results

bench-results-gate:
	python -m benchmarks.run --only sec7.2.3_results --tiny \
		--artifact bench_fresh.json
	python -m tools.bench_gate --baseline BENCH_10.json \
		--fresh bench_fresh.json

bench-shm:
	python -m benchmarks.run --only sec7_shm

bench-shm-gate:
	python -m benchmarks.run --only sec7_shm --tiny \
		--artifact bench_fresh.json
	python -m tools.bench_gate --shm --fresh bench_fresh.json

bench-executor:
	python -m benchmarks.run --only sec5_executor

bench-executor-gate:
	python -m benchmarks.run --only sec5_executor --tiny \
		--artifact bench_fresh.json
	python -m tools.bench_gate --executor --fresh bench_fresh.json

bench-p2p:
	python -m benchmarks.run --only sec6_p2p

bench-p2p-gate:
	python -m benchmarks.run --only sec6_p2p --tiny \
		--artifact bench_fresh.json
	python -m tools.bench_gate --p2p --fresh bench_fresh.json

bench-serving:
	python -m benchmarks.run --only sec10_serving

bench-serving-gate:
	python -m benchmarks.run --only sec10_serving --tiny \
		--artifact bench_fresh.json
	python -m tools.bench_gate --serving --fresh bench_fresh.json

bench-interchange:
	python -m benchmarks.run --only sec5_interchange

bench-interchange-gate:
	python -m benchmarks.run --only sec5_interchange --tiny \
		--artifact bench_fresh.json
	python -m tools.bench_gate --interchange --fresh bench_fresh.json

bench:
	python -m benchmarks.run
