# One-command entry points for the suite and benchmarks.
#
#   make test         tier-1 test suite (ROADMAP.md verify command)
#   make bench-smoke  scaling benchmark in tiny mode (seconds, not minutes)
#   make bench        full benchmark harness

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test bench-smoke bench

test:
	python -m pytest -x -q

bench-smoke:
	python -m benchmarks.run --only fig4_scaling --tiny

bench:
	python -m benchmarks.run
