# One-command entry points for the suite and benchmarks.
#
#   make test                 tier-1 test suite (ROADMAP.md verify command)
#   make bench-smoke          scaling benchmark in tiny mode (seconds)
#   make bench-serialization  §4.5 pack-once data plane benchmarks
#   make bench                full benchmark harness (writes BENCH_2.json)

PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))
export PYTHONPATH

.PHONY: test bench-smoke bench-serialization bench

test:
	python -m pytest -x -q

bench-smoke:
	python -m benchmarks.run --only fig4_scaling --tiny

bench-serialization:
	python -m benchmarks.run --only sec4.5_serialization

bench:
	python -m benchmarks.run
