"""§7.5 — batching: executor-side (internal) batching amortizes dispatch
RTT (paper: 10 000 no-ops, 6.7 s batched vs 118 s unbatched), plus the
beyond-paper dynamic request coalescing for model serving."""
from __future__ import annotations

import time

from .common import emit, make_bench_service


def internal_batching(n_tasks: int = 2000, rtt_s: float = 0.002) -> None:
    for batch_size, label in ((1, "disabled"), (64, "enabled")):
        svc, client = make_bench_service(forwarder_batch=batch_size)
        try:
            fid = client.register_function(lambda d: None, name="noop")
            eid, agent = svc.make_endpoint(client.token, "ep", n_managers=4,
                                           workers_per_manager=16)
            svc.endpoints[eid].forwarder.send_rtt = rtt_s
            ids = client.batch_run([(fid, eid, {})
                                    for _ in range(min(64, n_tasks))])
            client.get_batch_results(ids, timeout=120)        # warm-up
            t0 = time.perf_counter()
            ids = client.batch_run([(fid, eid, {}) for _ in range(n_tasks)])
            client.get_batch_results(ids, timeout=600)
            took = time.perf_counter() - t0
            emit(f"sec7.5/internal_batching/{label}", took * 1e6,
                 f"tasks={n_tasks} rtt={rtt_s*1e3:.0f}ms "
                 f"(paper: 6.7s vs 118s for 10k)")
            agent.stop()
        finally:
            svc.shutdown()


def request_coalescing(n_requests: int = 64) -> None:
    """Beyond-paper: dynamic batcher coalesces tiny per-request payloads
    into batched tasks (model-serving shape without the model)."""
    import numpy as np
    svc, client = make_bench_service()
    try:
        def batched_fn(data):
            time.sleep(0.01)             # fixed per-invocation cost
            return {"out": np.asarray(data["tokens"]) * 2}
        fid = client.register_function(batched_fn)
        eid, agent = svc.make_endpoint(client.token, "ep", n_managers=1,
                                       workers_per_manager=2)
        for max_batch, label in ((1, "off"), (16, "on")):
            batcher = client.make_batcher(fid, eid, max_batch=max_batch,
                                          max_wait=0.01)
            t0 = time.perf_counter()
            futs = [batcher.submit({"tokens": np.ones((1, 8), np.int32)})
                    for _ in range(n_requests)]
            for f in futs:
                f.result(timeout=120)
            took = time.perf_counter() - t0
            emit(f"sec7.5x/coalescing/{label}", took * 1e6,
                 f"requests={n_requests} batches={batcher.batches_sent}")
            batcher.close()
        agent.stop()
    finally:
        svc.shutdown()


def run(full: bool = False) -> None:
    internal_batching(n_tasks=2000 if not full else 10_000)
    request_coalescing()
