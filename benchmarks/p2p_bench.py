"""DESIGN.md §9 — peer data plane: N-endpoint all-to-all shuffle of
staged intermediates, direct endpoint↔endpoint TCP vs the hub relay.

Two identical subprocess fleets (shm off in both, so the only variable is
the data plane): the *peer* lane runs PeerServers and resolves every
cross-endpoint DataRef with a direct fetch; the *hub* lane starts its
endpoints ``--no-peer`` (nothing listening, nothing advertised) so every
fetch falls back to the service relay. Emits the aggregate shuffle
throughput of both lanes, the speedup, and the relay-byte gauges that
``tools/bench_gate.py --p2p`` gates on (``hub_relay_bytes == 0`` on the
peer lane is the headline invariant: intermediates never transit the
hub when peers are reachable).
"""
from __future__ import annotations

import subprocess
import time

from .common import emit


def shuffle_lane(label: str, peer: bool, n_endpoints: int, blob_bytes: int,
                 partitions: int = 4, repeats: int = 2):
    """One fleet, ``repeats`` complete produce→shuffle rounds (fresh refs
    each round — consumers cache fetched keys, so reusing refs would
    measure the local store). Each producer mints ``partitions`` blobs
    per consumer; each consumer's gather pulls every one of them, so
    the shuffle is data-bound: the direct lane spreads the bytes over
    N×N independent peer sockets while the relay lane funnels every
    byte through the service's recv loop twice. Returns (best bytes/s,
    best tasks/s, relay bytes across all rounds)."""
    from repro.core import FuncXClient, FuncXService
    from repro.core.endpoint import (
        demo_gather,
        demo_produce,
        spawn_endpoint_process,
    )

    svc = FuncXService(heartbeat_timeout=1.0, purge_on_get=False, shm=False)
    procs = []
    try:
        tok = svc.register_user("bench")
        client = FuncXClient(svc, tok)
        fid_p = client.register_function(demo_produce)
        fid_g = client.register_function(demo_gather)
        address = svc.listen()
        token = client.endpoint_credentials()
        eids = []
        for i in range(n_endpoints):
            p, eid = spawn_endpoint_process(
                address, token, name=f"{label}{i}", workers=4, shm=False,
                peer=peer, stage_limit=4096)
            procs.append(p)
            eids.append(eid)

        per_cons = n_endpoints - 1
        best_bps = best_tps = 0.0
        for _ in range(repeats):
            # produce: every endpoint mints `partitions` blobs per consumer
            pids = client.batch_run([
                (fid_p, eids[i], {"n": blob_bytes, "seed": i})
                for i in range(n_endpoints)
                for _ in range(per_cons * partitions)])
            refs = client.get_batch_results(pids, timeout=120)
            span = per_cons * partitions
            per_producer = [refs[i * span:(i + 1) * span]
                            for i in range(n_endpoints)]
            # shuffle: endpoint i runs one gather pulling ALL of its
            # partitions from every OTHER endpoint — cross-endpoint refs
            # resolved at stage-in. One deep task per endpoint keeps the
            # phase data-bound: the task-pipeline constant is paid N
            # times, the fetch path (N-1)·partitions times
            payloads = []
            for i in range(n_endpoints):
                parts = [per_producer[j].pop()
                         for j in range(n_endpoints) if j != i
                         for _k in range(partitions)]
                payloads.append((fid_g, eids[i], {"parts": parts}))
            t0 = time.perf_counter()
            gids = client.batch_run(payloads)
            sizes = client.get_batch_results(gids, timeout=180)
            dt = time.perf_counter() - t0
            moved = n_endpoints * partitions * per_cons * blob_bytes
            assert sizes == [per_cons * partitions * blob_bytes] \
                * len(payloads)
            best_bps = max(best_bps, moved / dt)
            best_tps = max(best_tps, len(payloads) / dt)
        return best_bps, best_tps, svc.hub_relay_bytes
    finally:
        for p in procs:
            p.terminate()
        for p in procs:
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.kill()
        svc.shutdown()


def run(full: bool = False, tiny: bool = False) -> None:
    if tiny:
        n_endpoints, blob, parts, repeats = 2, 64 * 1024, 2, 2
    elif full:
        n_endpoints, blob, parts, repeats = 4, 256 * 1024, 24, 4
    else:
        n_endpoints, blob, parts, repeats = 4, 256 * 1024, 16, 3

    peer_bps, peer_tps, peer_relay = shuffle_lane(
        "p2p_peer", True, n_endpoints, blob, parts, repeats)
    hub_bps, hub_tps, hub_relay = shuffle_lane(
        "p2p_hub", False, n_endpoints, blob, parts, repeats)

    mb = 1024 * 1024
    emit(f"p2p/peer/shuffle_MBps/endpoints={n_endpoints}", peer_bps / mb,
         f"blob={blob}B all-to-all tasks/s={peer_tps:.1f}")
    emit(f"p2p/hub/shuffle_MBps/endpoints={n_endpoints}", hub_bps / mb,
         f"blob={blob}B all-to-all tasks/s={hub_tps:.1f}")
    emit("p2p/speedup_vs_hub", peer_bps / max(hub_bps, 1e-9),
         f"peer={peer_bps / mb:.1f}MB/s hub={hub_bps / mb:.1f}MB/s")
    # the headline invariant: with peers reachable, zero intermediate
    # bytes transit the hub (gated == 0)
    emit("p2p/peer/hub_relay_bytes", float(peer_relay),
         "must be 0: every ref resolved endpoint-to-endpoint")
    # sanity: the hub lane really did relay everything at least once
    floor = n_endpoints * (n_endpoints - 1) * parts * blob * repeats
    emit("p2p/hub/hub_relay_bytes", float(hub_relay),
         f"expected >= {floor} (all shuffle bytes, every round)")
