"""Fig. 3 — funcX latency breakdown (t_s / t_f / t_e / t_w) for a warm
container, from instrumented task-lifecycle timestamps."""
from __future__ import annotations

import numpy as np

from .common import emit


def run(n_tasks: int = 200, full: bool = False) -> None:
    if full:
        n_tasks = 1000
    from repro.core import FuncXClient, FuncXService
    from repro.serialization import stats

    svc = FuncXService(heartbeat_timeout=0.5, purge_on_get=False)
    try:
        tok = svc.register_user("bench")
        client = FuncXClient(svc, tok)
        fid = client.register_function(lambda d: None, name="noop")
        eid, agent = svc.make_endpoint(tok, "ep", n_managers=1,
                                       workers_per_manager=4)
        # warm up path + executable
        for _ in range(10):
            client.get_result(client.run(fid, eid, data={}), timeout=10)
        parts = {k: [] for k in ("t_s", "t_f", "t_e", "t_w", "t_r", "total")}
        stats.reset()
        env0 = agent.coalescer.result_envelopes
        for _ in range(n_tasks):
            tid = client.run(fid, eid, data={})
            client.get_result(tid, timeout=10)
            bd = client.task(tid).latency_breakdown()
            for k in parts:
                if bd[k] == bd[k]:
                    parts[k].append(bd[k])
        for k, vals in parts.items():
            emit(f"fig3/latency/{k}", float(np.mean(vals)) * 1e6,
                 f"p50={np.percentile(vals, 50)*1e6:.0f}us "
                 f"p99={np.percentile(vals, 99)*1e6:.0f}us n={len(vals)}")
        # pack-once gauge (DESIGN.md §5): the same tasks whose latency was
        # just decomposed must have cost exactly one payload serialization
        # and one payload decode each.
        s = stats.snapshot()
        emit("fig3/latency/payload_packs_per_task",
             s["packs_by_tag"].get("task", 0) / n_tasks,
             f"n={n_tasks} (invariant: exactly 1.0)")
        emit("fig3/latency/payload_unpacks_per_task",
             s["unpacks_by_tag"].get("task", 0) / n_tasks,
             f"n={n_tasks} (invariant: exactly 1.0)")
        # result-plane gauge (DESIGN.md §6): sequential lone tasks flush
        # immediately — one result envelope each, no coalescer batching
        # and no linger on an idle line.
        emit("fig3/latency/result_envelopes_per_task",
             (agent.coalescer.result_envelopes - env0) / n_tasks,
             f"n={n_tasks} (idle line: exactly 1.0, immediate flush)")
        # zero-copy gauge (DESIGN.md §7): payloads at/above SEGMENT_MIN
        # ride the wire as borrowed frame segments — the fraction of
        # payload bytes memcpy'd into an envelope must be 0.0 here.
        from repro.core import WIRE_STATS
        big = {"blob": b"\x00" * (1 << 20)}
        client.get_result(client.run(fid, eid, data=big), timeout=30)
        WIRE_STATS.reset()
        n_big = 5
        for _ in range(n_big):
            client.get_result(client.run(fid, eid, data=big), timeout=30)
        emb = WIRE_STATS.embedded_payload_bytes
        seg = WIRE_STATS.segment_payload_bytes
        emit("fig3/latency/copies_per_payload_byte", emb / max(emb + seg, 1),
             f"1MiB payloads n={n_big}: embedded={emb}B segment={seg}B "
             f"(segmented-path invariant: 0.0)")
        agent.stop()
    finally:
        svc.shutdown()
