"""Fig. 4 + §7.2.3 — strong/weak scaling, peak agent throughput, and the
many-endpoint federation scenario.

Four modes:
  - REAL: threaded workers through the full service→forwarder-pool→
    endpoint→manager→worker path (up to ~128 workers on this CPU).
  - FEDERATION: a 64+ endpoint fleet through one ForwarderPool — service
    thread count stays O(1) (the seed spent 3 threads/endpoint), and
    federation-level warming-aware routing beats random endpoint pick.
  - MULTIPROCESS: the same fleet as N actual OS processes dialing the
    service's TCP listener (``python -m repro.core.endpoint --connect``)
    vs N same-process thread endpoints — tasks/s and p50/p99 task latency
    for both deployment modes (DESIGN.md §2).
The old SIM mode (``fig4sim``: a discrete-event model extrapolated to
131 072 workers) is retired — the paper-scale queueing claims are now
*measured* on a real relay tree by the ``sec5_interchange`` suite
(``benchmarks/interchange_bench.py``, DESIGN.md §11).
"""
from __future__ import annotations

import subprocess
import threading
import time
from typing import List

from .common import emit, make_bench_service


# --------------------------------------------------------------------- real

def _run_batch(client, svc, fid, eid, n_tasks: int, timeout=300) -> float:
    t0 = time.perf_counter()
    ids = client.batch_run([(fid, eid, {}) for _ in range(n_tasks)])
    client.get_batch_results(ids, timeout=timeout)
    return time.perf_counter() - t0


def real_mode(workers_list=(4, 16, 64), n_strong=512,
              sleep_s=0.05) -> float:
    """Returns the measured per-task dispatch overhead (for sim calibration)."""
    dispatch_overhead = 1e-4
    for workers in workers_list:
        svc, client = make_bench_service()
        try:
            noop = client.register_function(lambda d: None, name="noop")
            sleeper = client.register_function(
                lambda d: time.sleep(sleep_s), name="sleep")
            n_managers = max(workers // 16, 1)
            eid, agent = svc.make_endpoint(
                client.token, "ep", n_managers=n_managers,
                workers_per_manager=workers // n_managers)
            _run_batch(client, svc, noop, eid, 32)       # warm
            # strong scaling: fixed task count
            t = _run_batch(client, svc, noop, eid, n_strong)
            emit(f"fig4/strong/noop/workers={workers}", t * 1e6,
                 f"tasks={n_strong} rate={n_strong/t:.0f}/s")
            dispatch_overhead = t / n_strong
            # weak scaling: 10 tasks per worker
            n_weak = 10 * workers
            t = _run_batch(client, svc, noop, eid, n_weak)
            emit(f"fig4/weak/noop/workers={workers}", t * 1e6,
                 f"tasks={n_weak} rate={n_weak/t:.0f}/s")
            t = _run_batch(client, svc, sleeper, eid, n_weak)
            emit(f"fig4/weak/sleep{int(sleep_s*1e3)}ms/workers={workers}",
                 t * 1e6, f"tasks={n_weak} ideal={10*sleep_s:.2f}s")
            agent.stop()
        finally:
            svc.shutdown()
    return dispatch_overhead


def throughput(n_tasks=3000, workers=64, repeats=3) -> None:
    """§7.2.3: peak tasks/s through one agent (paper: 1694/s on Theta).
    Repeats and records the best — it is a *peak* metric, and shared-host
    interference only ever produces slow outliers. Also emits the result
    plane's envelopes-per-task (DESIGN.md §6): the batched return path
    must stay well under one wire frame per completed task."""
    svc, client = make_bench_service()
    try:
        fid = client.register_function(lambda d: None, name="noop")
        eid, agent = svc.make_endpoint(client.token, "ep", n_managers=4,
                                       workers_per_manager=workers // 4)
        _run_batch(client, svc, fid, eid, 64)
        co = agent.coalescer
        e0 = co.envelopes_sent
        rates = [n_tasks / _run_batch(client, svc, fid, eid, n_tasks)
                 for _ in range(repeats)]
        emit("sec7.2.3/throughput_tasks_per_s", max(rates),
             f"(paper: 1694/s Theta, 1466/s Cori) n={n_tasks} "
             f"best of {repeats}")
        emit("sec7.2.3/envelopes_per_task",
             (co.envelopes_sent - e0) / (repeats * n_tasks),
             f"all return-path frames incl. acks (DESIGN.md §6); "
             f"pre-batch >= 1.0")
        agent.stop()
    finally:
        svc.shutdown()


# --------------------------------------------------------------- federation

def federation_threads(n_endpoints: int = 64) -> None:
    """Service-tier thread cost of N endpoints: the multiplexed pool adds
    zero threads per registration (the seed's per-endpoint Forwarder spent
    three)."""
    svc, client = make_bench_service()
    try:
        before = threading.active_count()
        for i in range(n_endpoints):
            svc.register_endpoint(client.token, f"ep{i}")
        grown = threading.active_count() - before
        emit(f"federation/service_threads_added/endpoints={n_endpoints}",
             grown, f"seed cost 3/endpoint = {3 * n_endpoints}")
    finally:
        svc.shutdown()


def federation_throughput(n_endpoints: int = 64,
                          tasks_per_endpoint: int = 10) -> None:
    """Fleet-wide throughput: every task submitted WITHOUT an endpoint and
    placed by the federation router over N live endpoint agents."""
    from repro.core import FuncXClient, FuncXService
    svc = FuncXService(heartbeat_timeout=1.0,
                       endpoint_router="least_loaded")
    try:
        tok = svc.register_user("bench")
        client = FuncXClient(svc, tok)
        fid = client.register_function(lambda d: None, name="noop")
        agents = []
        for i in range(n_endpoints):
            _, agent = svc.make_endpoint(tok, f"ep{i}", n_managers=1,
                                         workers_per_manager=1)
            agents.append(agent)
        n = n_endpoints * tasks_per_endpoint
        t0 = time.perf_counter()
        ids = client.batch_run([(fid, None, {}) for _ in range(n)])
        client.get_batch_results(ids, timeout=600)
        t = time.perf_counter() - t0
        used = {ln.dispatched > 0 for ln in svc.pool.lines()}
        emit(f"federation/routed_throughput/endpoints={n_endpoints}",
             n / t, f"tasks/s n={n} all_endpoints_used={used == {True}}")
        for a in agents:
            a.stop()
    finally:
        svc.shutdown()


def federation_routing_win(n_endpoints: int = 8, burst: int = 16,
                           build_s: float = 0.25) -> None:
    """§6.2 lifted to the federation: pre-warm half the fleet, then fire a
    routed burst. Warming-aware endpoint selection avoids every cold
    container build; random pays one per cold endpoint it scatters onto."""
    from repro.core import ContainerSpec, FuncXClient, FuncXService

    def run_policy(policy: str) -> float:
        svc = FuncXService(heartbeat_timeout=0.5, endpoint_router=policy)
        try:
            tok = svc.register_user("bench")
            client = FuncXClient(svc, tok)
            svc.register_container(ContainerSpec(
                "fed/heavy", build=lambda: time.sleep(build_s) or {}))
            fid = client.register_function(lambda d, env: None,
                                           name="heavy",
                                           container_type="fed/heavy")
            eids, agents = [], []
            for i in range(n_endpoints):
                eid, agent = svc.make_endpoint(tok, f"ep{i}", n_managers=1,
                                               workers_per_manager=1)
                eids.append(eid)
                agents.append(agent)
            warm = eids[: n_endpoints // 2]
            client.get_batch_results(
                client.batch_run([(fid, e, {}) for e in warm]), timeout=120)
            # let heartbeats advertise the warm containers
            deadline = time.time() + 5
            while time.time() < deadline and not all(
                    svc.pool.line(e).advertised.warm_total.get("fed/heavy")
                    for e in warm):
                time.sleep(0.02)
            t0 = time.perf_counter()
            ids = client.batch_run([(fid, None, {}) for _ in range(burst)])
            client.get_batch_results(ids, timeout=120)
            t = time.perf_counter() - t0
            for a in agents:
                a.stop()
            return t
        finally:
            svc.shutdown()

    t_random = run_policy("random")
    t_warm = run_policy("warming_aware")
    emit(f"federation/burst_makespan/random/endpoints={n_endpoints}",
         t_random * 1e6, f"burst={burst} build={build_s}s")
    emit(f"federation/burst_makespan/warming_aware/endpoints={n_endpoints}",
         t_warm * 1e6, f"speedup_vs_random={t_random / t_warm:.2f}x")


# ------------------------------------------------------------- multiprocess

def _percentile(xs: List[float], q: float) -> float:
    if not xs:
        return 0.0
    xs = sorted(xs)
    return xs[min(len(xs) - 1, int(round(q * (len(xs) - 1))))]


def _measured_batch(svc, client, fid, eids, n_tasks, timeout=300):
    """Round-robin a batch over ``eids``; returns (tasks/s, p50 s, p99 s)
    with per-task latency read from the submit→result_stored stamps
    (requires ``purge_on_get=False``)."""
    reqs = [(fid, eids[i % len(eids)], {}) for i in range(n_tasks)]
    t0 = time.perf_counter()
    ids = client.batch_run(reqs)
    client.get_batch_results(ids, timeout=timeout)
    elapsed = time.perf_counter() - t0
    lats = []
    for tid in ids:
        t = svc.tasks.get(tid).t
        if "submit" in t and "result_stored" in t:
            lats.append(t["result_stored"] - t["submit"])
        svc.tasks.purge(tid)
    return n_tasks / elapsed, _percentile(lats, 0.50), _percentile(lats, 0.99)


def multiprocess_mode(n_endpoints: int = 4, tasks_per_endpoint: int = 50,
                      workers: int = 4) -> None:
    """DESIGN.md §2 deployment modes, measured head-to-head: N endpoint
    agents as OS subprocesses over TcpTransport vs the same N as threads
    over LocalTransport, same service, same task mix."""
    from repro.core import FuncXClient, FuncXService
    from repro.core.endpoint import demo_noop

    n_tasks = n_endpoints * tasks_per_endpoint

    # -- threads / LocalTransport ------------------------------------------
    svc = FuncXService(heartbeat_timeout=1.0, purge_on_get=False)
    try:
        tok = svc.register_user("bench")
        client = FuncXClient(svc, tok)
        fid = client.register_function(demo_noop)
        eids, agents = [], []
        for i in range(n_endpoints):
            eid, agent = svc.make_endpoint(tok, f"thr{i}", n_managers=1,
                                           workers_per_manager=workers)
            eids.append(eid)
            agents.append(agent)
        _measured_batch(svc, client, fid, eids, min(n_tasks, 32))   # warm
        rate, p50, p99 = _measured_batch(svc, client, fid, eids, n_tasks)
        emit(f"federation/multiproc/threads/tasks_per_s/"
             f"endpoints={n_endpoints}", rate, f"n={n_tasks}")
        emit(f"federation/multiproc/threads/latency_p50_us", p50 * 1e6,
             f"p99_us={p99 * 1e6:.0f}")
        for a in agents:
            a.stop()
    finally:
        svc.shutdown()

    # -- subprocesses: socket-only, then with the same-host shm fast path --
    subprocess_lane("subprocess_tcp", False, n_endpoints,
                    tasks_per_endpoint, workers, repeats=3)
    subprocess_lane("subprocess", True, n_endpoints,
                    tasks_per_endpoint, workers, repeats=3)


def subprocess_lane(label: str, shm: bool, n_endpoints: int,
                    tasks_per_endpoint: int, workers: int = 4,
                    prefix: str = "federation/multiproc",
                    repeats: int = 1):
    """One fleet of N endpoint agents as OS subprocesses dialing the TCP
    listener, with the shared-memory same-host fast path on or off
    (DESIGN.md §7). Best-of-``repeats`` batches (throughput is a peak
    metric; shared-host interference only produces slow outliers).
    Returns (tasks/s, p50 s, shm channels installed)."""
    from repro.core import FuncXClient, FuncXService, ShmTransport
    from repro.core.endpoint import demo_noop, spawn_endpoint_process

    n_tasks = n_endpoints * tasks_per_endpoint
    svc = FuncXService(heartbeat_timeout=1.0, purge_on_get=False)
    procs = []
    try:
        tok = svc.register_user("bench")
        client = FuncXClient(svc, tok)
        fid = client.register_function(demo_noop)
        address = svc.listen()
        token = client.endpoint_credentials()
        eids = []
        for i in range(n_endpoints):
            p, eid = spawn_endpoint_process(address, token,
                                            name=f"{label}{i}",
                                            workers=workers, shm=shm)
            procs.append(p)
            eids.append(eid)
        _measured_batch(svc, client, fid, eids, min(n_tasks, 32))   # warm
        rate, p50, p99 = max(
            (_measured_batch(svc, client, fid, eids, n_tasks)
             for _ in range(repeats)), key=lambda r: r[0])
        n_shm = sum(isinstance(svc.endpoints[e].channel.transport,
                               ShmTransport) for e in eids)
        emit(f"{prefix}/{label}/tasks_per_s/"
             f"endpoints={n_endpoints}", rate,
             f"n={n_tasks} shm_channels={n_shm}/{n_endpoints}")
        emit(f"{prefix}/{label}/latency_p50_us", p50 * 1e6,
             f"p99_us={p99 * 1e6:.0f}")
        return rate, p50, n_shm
    finally:
        for p in procs:
            p.terminate()
        for p in procs:
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.kill()
        svc.shutdown()


def run(full: bool = False, tiny: bool = False) -> None:
    if tiny:                     # `make bench-smoke`: seconds, not minutes
        real_mode(workers_list=(4,), n_strong=64)
        throughput(n_tasks=300, workers=16)
        federation_threads(n_endpoints=16)
        federation_throughput(n_endpoints=8, tasks_per_endpoint=5)
        federation_routing_win(n_endpoints=4, burst=8, build_s=0.1)
        multiprocess_mode(n_endpoints=2, tasks_per_endpoint=25)
        return
    workers = (4, 16, 64) if not full else (4, 16, 64, 128)
    real_mode(workers_list=workers,
              n_strong=512 if not full else 2048)
    throughput(n_tasks=2000 if not full else 10000)
    federation_threads(n_endpoints=64 if not full else 256)
    federation_throughput(n_endpoints=64, tasks_per_endpoint=10)
    federation_routing_win(n_endpoints=8 if not full else 16)
    multiprocess_mode(n_endpoints=4 if not full else 8,
                      tasks_per_endpoint=50 if not full else 100)
