"""Fig. 4 + §7.2.3 — strong/weak scaling and peak agent throughput.

Two modes (DESIGN.md §2 "Scale"):
  - REAL: threaded workers through the full service→forwarder→endpoint→
    manager→worker path (up to ~128 workers on this CPU).
  - SIM: discrete-event simulation of the same dispatch pipeline,
    calibrated with the real mode's measured per-task dispatch overhead,
    scaled to 131 072 workers (the paper's Cori point).
"""
from __future__ import annotations

import heapq
import time
from typing import List

from .common import emit, make_bench_service


# --------------------------------------------------------------------- real

def _run_batch(client, svc, fid, eid, n_tasks: int, timeout=300) -> float:
    t0 = time.perf_counter()
    ids = client.batch_run([(fid, eid, {}) for _ in range(n_tasks)])
    client.get_batch_results(ids, timeout=timeout)
    return time.perf_counter() - t0


def real_mode(workers_list=(4, 16, 64), n_strong=512,
              sleep_s=0.05) -> float:
    """Returns the measured per-task dispatch overhead (for sim calibration)."""
    dispatch_overhead = 1e-4
    for workers in workers_list:
        svc, client = make_bench_service()
        try:
            noop = client.register_function(lambda d: None, name="noop")
            sleeper = client.register_function(
                lambda d: time.sleep(sleep_s), name="sleep")
            n_managers = max(workers // 16, 1)
            eid, agent = svc.make_endpoint(
                client.token, "ep", n_managers=n_managers,
                workers_per_manager=workers // n_managers)
            _run_batch(client, svc, noop, eid, 32)       # warm
            # strong scaling: fixed task count
            t = _run_batch(client, svc, noop, eid, n_strong)
            emit(f"fig4/strong/noop/workers={workers}", t * 1e6,
                 f"tasks={n_strong} rate={n_strong/t:.0f}/s")
            dispatch_overhead = t / n_strong
            # weak scaling: 10 tasks per worker
            n_weak = 10 * workers
            t = _run_batch(client, svc, noop, eid, n_weak)
            emit(f"fig4/weak/noop/workers={workers}", t * 1e6,
                 f"tasks={n_weak} rate={n_weak/t:.0f}/s")
            t = _run_batch(client, svc, sleeper, eid, n_weak)
            emit(f"fig4/weak/sleep{int(sleep_s*1e3)}ms/workers={workers}",
                 t * 1e6, f"tasks={n_weak} ideal={10*sleep_s:.2f}s")
            agent.stop()
        finally:
            svc.shutdown()
    return dispatch_overhead


def throughput(n_tasks=3000, workers=64) -> None:
    """§7.2.3: peak tasks/s through one agent (paper: 1694/s on Theta)."""
    svc, client = make_bench_service()
    try:
        fid = client.register_function(lambda d: None, name="noop")
        eid, agent = svc.make_endpoint(client.token, "ep", n_managers=4,
                                       workers_per_manager=workers // 4)
        _run_batch(client, svc, fid, eid, 64)
        t = _run_batch(client, svc, fid, eid, n_tasks)
        emit("sec7.2.3/throughput_tasks_per_s", n_tasks / t,
             f"(paper: 1694/s Theta, 1466/s Cori) n={n_tasks}")
        agent.stop()
    finally:
        svc.shutdown()


# ---------------------------------------------------------------------- sim

def simulate(n_workers: int, n_tasks: int, duration_s: float,
             dispatch_s: float) -> float:
    """Discrete-event model of the agent pipeline: a serial dispatcher
    assigns task i at time i·dispatch_s to the earliest-free worker."""
    free = [0.0] * min(n_workers, n_tasks)
    heapq.heapify(free)
    finish_last = 0.0
    for i in range(n_tasks):
        t_disp = i * dispatch_s
        w_free = heapq.heappop(free)
        start = max(t_disp, w_free)
        end = start + duration_s
        heapq.heappush(free, end)
        finish_last = max(finish_last, end)
    return finish_last


def sim_mode(dispatch_s: float) -> None:
    # weak scaling to the paper's 131 072 workers, 10 tasks/worker
    for workers in (256, 2048, 16384, 131072):
        n = 10 * workers
        for name, dur in (("noop", 0.0), ("sleep1s", 1.0), ("stress60s", 60.0)):
            t = simulate(workers, n, dur, dispatch_s)
            emit(f"fig4sim/weak/{name}/workers={workers}", t * 1e6,
                 f"tasks={n} dispatch={dispatch_s*1e6:.0f}us/task")
    # strong scaling, 100k tasks (paper Fig. 4a)
    for workers in (256, 2048, 16384):
        for name, dur in (("noop", 0.0), ("sleep1s", 1.0)):
            t = simulate(workers, 100_000, dur, dispatch_s)
            emit(f"fig4sim/strong/{name}/workers={workers}", t * 1e6,
                 f"tasks=100000")


def run(full: bool = False) -> None:
    workers = (4, 16, 64) if not full else (4, 16, 64, 128)
    dispatch = real_mode(workers_list=workers,
                         n_strong=512 if not full else 2048)
    throughput(n_tasks=2000 if not full else 10000)
    sim_mode(dispatch)
