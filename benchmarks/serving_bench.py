"""DESIGN.md §10 — serving fabric: sustained mixed-model load over real
socket endpoints, jit-cache-aware routing vs random.

Two identical subprocess fleets serve the same interleaved two-model
request stream through ``FuncXExecutor``. Each endpoint runs ONE worker
with ONE warm slot, so the fleet can hold each model's jit-compiled
executable warm exactly once — the *aware* lane (service endpoint_router
``warming_aware``) reads the jit warmth keys off heartbeats and keeps
each model pinned to its warm endpoint, while the *random* lane scatters
requests and pays the ``jax.jit`` recompile every time a model lands on
the endpoint that last served the other one. Emits per-lane p50/p99
latency and the warm-hit rate (from the env-held uses counter each
serving call reports), which ``tools/bench_gate.py --serving`` gates on:
warmth-aware routing must beat (or tie) random on warm-hit rate.
"""
from __future__ import annotations

import itertools
import subprocess
import threading
import time

import numpy as np

from .common import emit

ARCHS = ("qwen1.5-0.5b", "mamba2-370m")


def _pct(sorted_vals, q: float) -> float:
    if not sorted_vals:
        return float("nan")
    i = min(int(q * len(sorted_vals)), len(sorted_vals) - 1)
    return sorted_vals[i]


def serving_lane(router: str, requests: int, *, n_endpoints: int = 2,
                 concurrency: int = 2, timeout: float = 300.0):
    """One fleet under one endpoint-router policy. Closed-loop clients:
    ``concurrency`` threads each submit-and-wait through the executor
    (executor.submit → submit_packed_batch → select_many is the routed
    path under test). Returns (sorted latencies, warm-hit rate, req/s)."""
    from repro.core import FuncXClient, FuncXService
    from repro.core.endpoint import spawn_endpoint_process
    from repro.serve import fabric

    svc = FuncXService(heartbeat_timeout=2.0, shm=False,
                       endpoint_router=router)
    procs = []
    try:
        tok = svc.register_user("bench")
        client = FuncXClient(svc, tok)
        zoo = fabric.register_zoo(client, ARCHS)
        address = svc.listen()
        cred = client.endpoint_credentials()
        eids = []
        for i in range(n_endpoints):
            p, eid = spawn_endpoint_process(
                address, cred, name=f"serve-{router}-{i}", workers=1,
                shm=False, peer=False,
                containers="repro.serve.fabric:install")
            procs.append(p)
            eids.append(eid)

        rng = np.random.default_rng(0)
        prompts = [rng.integers(1, 100, (1, 9)).astype(np.int32)
                   for _ in range(requests)]
        ex = client.executor(batch_size=8)

        # Prewarm: seed exactly one warm jit cache per model, pinned
        # round-robin over the fleet — the deployment's prewarm step, and
        # identical in both lanes. The measured stream then gauges steady
        # -state routing quality, not the unavoidable first compiles.
        for i, arch in enumerate(ARCHS):
            fid, ct = zoo[arch]
            ex.submit(fid, {"tokens": prompts[0], "n_tokens": 2, "seed": 0},
                      endpoint_id=eids[i % n_endpoints],
                      container_type=ct).result(timeout=timeout)
        lock = threading.Lock()
        lats, warm_hits = [], [0]
        counter = itertools.count()

        def closed_loop():
            while True:
                i = next(counter)
                if i >= requests:
                    return
                fid, ct = zoo[ARCHS[i % len(ARCHS)]]
                t0 = time.perf_counter()
                fut = ex.submit(fid, {"tokens": prompts[i], "n_tokens": 2,
                                      "seed": i}, container_type=ct)
                out = fut.result(timeout=timeout)
                dt = time.perf_counter() - t0
                with lock:
                    lats.append(dt)
                    warm_hits[0] += bool(out["warm"])

        t0 = time.perf_counter()
        threads = [threading.Thread(target=closed_loop, daemon=True)
                   for _ in range(concurrency)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        ex.shutdown()
        return sorted(lats), warm_hits[0] / max(requests, 1), requests / wall
    finally:
        for p in procs:
            p.terminate()
        for p in procs:
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.kill()
        svc.shutdown()


def run(full: bool = False, tiny: bool = False) -> None:
    if tiny:
        requests = 10
    elif full:
        requests = 48
    else:
        requests = 24

    aware_lats, aware_rate, aware_rps = serving_lane("warming_aware",
                                                     requests)
    rand_lats, rand_rate, rand_rps = serving_lane("random", requests)

    for label, lats, rate, rps in [
            ("aware", aware_lats, aware_rate, aware_rps),
            ("random", rand_lats, rand_rate, rand_rps)]:
        emit(f"serving/{label}/p50_ms", _pct(lats, 0.50) * 1e3,
             f"requests={requests} archs={len(ARCHS)}")
        emit(f"serving/{label}/p99_ms", _pct(lats, 0.99) * 1e3, "")
        emit(f"serving/{label}/warm_hit_rate", rate,
             f"req_per_s={rps:.2f}")
    # the gated invariant: jit-cache-aware routing keeps the executables
    # pinned — it must never lose to scattering on warm-hit rate
    emit("serving/warm_hit_advantage", aware_rate - rand_rate,
         f"aware={aware_rate:.2f} random={rand_rate:.2f}")
