"""Table 3 — cold container instantiation time per container technology.

TPU adaptation (DESIGN.md §2): the container cold start is the XLA JIT
compile of the function's executable. We measure REAL jit compiles of
reduced model steps (the "Singularity/Shifter" row analogue — heavyweight,
shared-environment builds) and a lightweight python env (the "Docker on
EC2" analogue), plus warm-cache hits.
"""
from __future__ import annotations

import time
from typing import List

import numpy as np

from .common import emit


def _measure_arch(arch: str, trials: int = 3) -> List[float]:
    import jax
    from repro.configs import get_reduced_config
    from repro.models import get_model
    from repro.models.knobs import RunKnobs
    from repro.serve import make_prefill

    cfg = get_reduced_config(arch)
    model = get_model(cfg)
    times = []
    for t in range(trials):
        # vary a static attribute so each trial truly recompiles
        knobs = RunKnobs(q_block=16 + 16 * t, kv_block=16 + 16 * t)
        params = model.init(jax.random.PRNGKey(t))
        toks = np.zeros((1, 64), np.int32)
        batch = {"tokens": toks}
        if cfg.family == "audio":
            batch["frames"] = np.zeros((1, 16, cfg.d_model), np.float32)
        if cfg.family == "vlm":
            batch["patches"] = np.zeros(
                (1, cfg.vlm.vision_prefix_len, cfg.d_model), np.float32)
        fn = jax.jit(make_prefill(model, knobs=knobs))
        t0 = time.perf_counter()
        fn(params, batch)[0].block_until_ready()
        times.append(time.perf_counter() - t0)
        # warm call for contrast (only once)
        if t == 0:
            t0 = time.perf_counter()
            fn(params, batch)[0].block_until_ready()
            emit(f"table3/warm_hit/{arch}",
                 (time.perf_counter() - t0) * 1e6, "executable cache hit")
    return times


def run(full: bool = False) -> None:
    archs = ["qwen1.5-0.5b", "mamba2-370m", "granite-moe-1b-a400m"]
    if full:
        archs += ["recurrentgemma-9b", "minicpm3-4b"]
    for arch in archs:
        times = _measure_arch(arch, trials=3)
        emit(f"table3/cold_jit/{arch}/mean", float(np.mean(times)) * 1e6,
             f"min={min(times):.2f}s max={max(times):.2f}s "
             f"(paper: Theta Singularity 10.4s mean)")
    # lightweight env (the EC2/Docker row): simulated container spawn
    from repro.core import ContainerRegistry, ContainerSpec, WarmCache
    reg = ContainerRegistry()
    reg.register(ContainerSpec("light", simulated_cold_start=0.02))
    cache = WarmCache(reg, slots=1)
    t0 = time.perf_counter()
    cache.get_or_build("light")
    emit("table3/cold_sim/light_env", (time.perf_counter() - t0) * 1e6,
         "(paper: EC2 Docker 1.79s mean)")
