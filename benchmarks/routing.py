"""Figs. 6–7 — warming-aware vs randomized function routing: completion
time and cold-start counts, across batch sizes and function durations.

Setup mirrors §7.4 at CPU scale: M managers × W workers, K function types
each requiring its own container, cold start cost C, batches of uniformly
random function types. Paper result: up to 61% lower completion time and
22 vs thousands of cold starts for 3000 functions.
"""
from __future__ import annotations

import time
from typing import Dict, Tuple

from .common import emit


def _run_once(router: str, n_batch: int, duration_s: float,
              n_types: int = 10, n_managers: int = 4,
              workers_per_manager: int = 10,
              cold_start_s: float = 0.15) -> Tuple[float, int]:
    from repro.core import ContainerSpec, FuncXClient, FuncXService

    svc = FuncXService(heartbeat_timeout=1.0)
    try:
        tok = svc.register_user("bench")
        client = FuncXClient(svc, tok)
        def make_fn(dur):
            if dur <= 0:
                return lambda d: None
            def fn(d):
                time.sleep(dur)
            return fn

        fids = []
        for k in range(n_types):
            svc.register_container(ContainerSpec(
                f"ctr{k}", simulated_cold_start=cold_start_s))
            fids.append(client.register_function(
                make_fn(duration_s), name=f"f{k}", container_type=f"ctr{k}"))
        eid, agent = svc.make_endpoint(
            tok, "ep", n_managers=n_managers,
            workers_per_manager=workers_per_manager, router=router)
        import random
        rng = random.Random(0)
        reqs = [(fids[rng.randrange(n_types)], eid, {})
                for _ in range(n_batch)]
        t0 = time.perf_counter()
        ids = client.batch_run(reqs)
        client.get_batch_results(ids, timeout=600)
        took = time.perf_counter() - t0
        cold = sum(w.cache.stats.cold_starts
                   for m in agent.managers.values() for w in m.workers)
        agent.stop()
        return took, cold
    finally:
        svc.shutdown()


def run(full: bool = False) -> None:
    batches = (100, 300) if not full else (100, 300, 1000)
    durations = (0.0, 0.02) if not full else (0.0, 0.02, 0.1, 0.4)
    for n_batch in batches:
        for dur in durations:
            res: Dict[str, Tuple[float, int]] = {}
            for router in ("random", "warming_aware"):
                res[router] = _run_once(router, n_batch, dur)
            t_r, c_r = res["random"]
            t_w, c_w = res["warming_aware"]
            gain = (1 - t_w / t_r) * 100
            emit(f"fig6/completion/random/batch={n_batch}/dur={dur}",
                 t_r * 1e6, f"cold_starts={c_r}")
            emit(f"fig6/completion/warming/batch={n_batch}/dur={dur}",
                 t_w * 1e6, f"cold_starts={c_w} gain={gain:.0f}% "
                 f"(paper: up to 61%)")
            emit(f"fig7/cold_starts/random/batch={n_batch}/dur={dur}",
                 c_r, "")
            emit(f"fig7/cold_starts/warming/batch={n_batch}/dur={dur}",
                 c_w, "(paper: 22 for 3000 fns)")
    # beyond-paper routers at one representative point
    for router in ("warming_hash", "cost_aware", "locality_aware"):
        t, c = _run_once(router, 300, 0.02)
        emit(f"fig6x/completion/{router}/batch=300/dur=0.02", t * 1e6,
             f"cold_starts={c} (beyond-paper router)")
