"""§4.5 serialization — the pack-once data plane, measured.

Three scenarios:

  1. pack/unpack throughput per method × payload size (nd arrays, msgpack
     dicts, pickle objects) for the current facade;
  2. the current facade vs a faithful replica of the pre-PR facade
     (trial-by-exception dispatch, ``tobytes()`` array copies, fresh zstd
     context per buffer, ``header + payload`` concat) — the speedup column
     is the acceptance gauge for this PR (≥ 2x for ≥ 1 MiB arrays);
  3. the pack-once invariant on the *live* task path: a real
     service→endpoint→worker round trip, asserting exactly one
     ``task``-tagged serialization and one deserialization per submitted
     task (down from 2–3 pre-PR: limit-check pack, envelope re-pack, and
     per-hop decodes).
"""
from __future__ import annotations

import pickle
import struct
import time

import msgpack
import numpy as np

from .common import emit, timed

try:
    import zstandard
except ImportError:                                  # pragma: no cover
    zstandard = None


# ---------------------------------------------------------------------------
# pre-PR facade replica (kept verbatim-in-spirit so the comparison stays
# honest as the real facade evolves)
# ---------------------------------------------------------------------------

_MAGIC = b"RPX1"
_LEGACY_METHODS = ["nd", "msgpack", "json", "pickle"]
_LEGACY_COMPRESS_THRESHOLD = 1 << 20


def _legacy_encode_tree(obj):
    if isinstance(obj, np.ndarray):
        return {"__nd__": True, "d": str(obj.dtype), "s": list(obj.shape),
                "b": obj.tobytes()}                      # the copy
    if isinstance(obj, dict):
        return {"__map__": [[_legacy_encode_tree(k), _legacy_encode_tree(v)]
                            for k, v in obj.items()]}
    if isinstance(obj, tuple):
        return {"__tup__": [_legacy_encode_tree(v) for v in obj]}
    if isinstance(obj, list):
        return [_legacy_encode_tree(v) for v in obj]
    if isinstance(obj, (str, bytes, bool, int, float)) or obj is None:
        return obj
    raise ValueError(f"nd cannot encode {type(obj)}")


def _legacy_try(method, obj):
    try:
        if method == "nd":
            return msgpack.packb(_legacy_encode_tree(obj), use_bin_type=True)
        if method == "msgpack":
            return msgpack.packb(obj, use_bin_type=True)
        if method == "json":
            return None                                  # orjson-gated
        if method == "pickle":
            return pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    except Exception:
        return None
    return None


def legacy_pack(obj, tag: str = "") -> bytes:
    payload = method_id = None
    for i, m in enumerate(_LEGACY_METHODS):              # trial by exception
        payload = _legacy_try(m, obj)
        if payload is not None:
            method_id = i
            break
    if payload is None:
        raise ValueError("unserializable")
    flags = 0
    if len(payload) >= _LEGACY_COMPRESS_THRESHOLD and zstandard is not None:
        payload = zstandard.ZstdCompressor(level=1).compress(payload)  # fresh ctx
        flags |= 0x01
    tag_b = tag.encode()
    header = _MAGIC + struct.pack("<BBH", flags, method_id, len(tag_b)) + tag_b
    return header + payload                              # full concat copy


# ---------------------------------------------------------------------------


def _throughput(fn, *, seconds: float = 0.4, min_reps: int = 3) -> float:
    """Calls/sec of ``fn`` over a small timing window."""
    fn()                                                 # warm
    reps = 0
    t0 = time.perf_counter()
    while True:
        fn()
        reps += 1
        dt = time.perf_counter() - t0
        if dt >= seconds and reps >= min_reps:
            return reps / dt


def run(full: bool = False, tiny: bool = False) -> None:
    from repro.serialization import clear_method_cache, pack, stats, unpack

    seconds = 0.08 if tiny else (0.8 if full else 0.3)
    rng = np.random.default_rng(0)

    # -- 1. throughput by method × size ------------------------------------
    sizes = [1 << 16, 1 << 20, 1 << 23]
    if tiny:
        sizes = [1 << 16, 1 << 20]
    payloads = []
    for nbytes in sizes:
        arr = rng.integers(0, 255, nbytes, dtype=np.uint8)
        payloads.append((f"nd_{nbytes >> 10}KiB", arr, nbytes))
    payloads.append(("msgpack_dict",
                     {"k%d" % i: float(i) for i in range(256)}, 4096))
    payloads.append(("pickle_obj", complex(1, 2), 64))

    for name, obj, nbytes in payloads:
        pps = _throughput(lambda o=obj: pack(o), seconds=seconds)
        emit(f"sec45/pack/{name}_MBps", pps * nbytes / 1e6,
             f"{pps:.0f} packs/s")
        buf = pack(obj)
        ups = _throughput(lambda b=buf: unpack(b), seconds=seconds)
        emit(f"sec45/unpack/{name}_MBps", ups * nbytes / 1e6,
             f"{ups:.0f} unpacks/s")

    # -- 2. current facade vs pre-PR facade --------------------------------
    # Alternating fixed-rep rounds, best-of: allocator drift and scheduler
    # noise at MiB buffer sizes dwarf the effect under a single free-running
    # window, but hit interleaved rounds symmetrically.
    def _rate(fn, reps):
        t0 = time.perf_counter()
        for _ in range(reps):
            fn()
        return reps / (time.perf_counter() - t0)

    rounds = 2 if tiny else 5
    for nbytes in sizes:
        if nbytes < (1 << 20) and not full:
            continue
        arr = rng.integers(0, 255, nbytes, dtype=np.uint8)
        clear_method_cache()
        reps = max(int((1 << 26) / nbytes), 3)
        if tiny:
            reps = max(reps // 8, 3)
        new = old = 0.0
        pack(arr), legacy_pack(arr)                      # warm both
        for _ in range(rounds):
            new = max(new, _rate(lambda: pack(arr), reps))
            old = max(old, _rate(lambda: legacy_pack(arr), reps))
        emit(f"sec45/speedup/nd_{nbytes >> 20}MiB_x", new / old,
             f"new={new:.0f}/s old={old:.0f}/s (acceptance: >=2x at >=1MiB)")

    # -- 3. pack-once invariant on the live task path ----------------------
    from repro.core import FuncXClient, FuncXService

    n_tasks = 10 if tiny else 50
    svc = FuncXService(heartbeat_timeout=0.5)
    try:
        tok = svc.register_user("bench")
        client = FuncXClient(svc, tok)
        fid = client.register_function(
            lambda d: float(np.sum(d["x"])), name="sum")
        eid, agent = svc.make_endpoint(tok, "ep", n_managers=1,
                                       workers_per_manager=4)
        payload = {"x": np.arange(1 << 14, dtype=np.float32)}
        for _ in range(5):                               # warm path
            client.get_result(client.run(fid, eid, data=payload), timeout=10)
        stats.reset()
        with timed() as box:
            tids = [client.run(fid, eid, data=payload) for _ in range(n_tasks)]
            for tid in tids:
                client.get_result(tid, timeout=30)
        s = stats.snapshot()
        packs = s["packs_by_tag"].get("task", 0)
        unpacks = s["unpacks_by_tag"].get("task", 0)
        assert packs == n_tasks, (
            f"pack-once violated: {packs} payload packs for {n_tasks} tasks")
        assert unpacks == n_tasks, (
            f"decode-once violated: {unpacks} payload decodes for "
            f"{n_tasks} tasks")
        emit("sec45/pipeline/payload_packs_per_task", packs / n_tasks,
             f"n={n_tasks} (invariant: exactly 1.0)")
        emit("sec45/pipeline/payload_unpacks_per_task", unpacks / n_tasks,
             f"n={n_tasks} (invariant: exactly 1.0)")
        emit("sec45/pipeline/result_packs_per_task",
             s["packs_by_tag"].get("ret", 0) / n_tasks, f"n={n_tasks}")
        emit("sec45/pipeline/64KiB_roundtrip_us",
             box["s"] / n_tasks * 1e6, f"n={n_tasks}")
        agent.stop()
    finally:
        svc.shutdown()
