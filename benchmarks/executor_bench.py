"""§5 + DESIGN.md §8 — the futures-native executor's submit plane,
measured.

Three gauges the acceptance gate watches:

- ``submit_envelopes_per_task`` — per-endpoint submit groups landed on
  the service per task under a 16-thread submit storm. Per-call
  ``client.run`` pays exactly 1.0; the SubmitCoalescer amortizes toward
  1/batch_size. Acceptance: ≤ 1/8.
- ``speedup_vs_percall`` — storm throughput through the executor vs the
  same 16 threads using funcX per-call (Listing 1 usage: each thread
  blocks on ``get_result(run(...))`` one task at a time). Futures let a
  caller thread keep 100 tasks in flight while the coalescer amortizes
  their submission — the executor must win (committed target ≥ 1.2×).
- ``lone_overhead_ratio`` — a single ``executor.submit(...).result()``
  on an idle line vs a direct ``client.run``+``get_result``. The idle
  line flushes inline on the caller's thread, so a lone submit must not
  pay the linger — only the harvest-thread hop (< 2× bound; a linger
  regression shows up as 3×+).
"""
from __future__ import annotations

import threading
import time

from .common import emit, make_bench_service


def _noop(data):
    return None


def run(n_threads: int = 16, per_thread: int = 100, repeats: int = 5,
        workers: int = 64, full: bool = False, tiny: bool = False) -> None:
    if full:
        per_thread, repeats = 300, 7
    if tiny:
        n_threads, per_thread, repeats = 8, 30, 2
    svc, client = make_bench_service()
    try:
        fid = client.register_function(_noop, name="noop")
        eid, agent = svc.make_endpoint(client.token, "ep", n_managers=4,
                                       workers_per_manager=workers // 4)
        n_tasks = n_threads * per_thread

        def storm(worker):
            """n_threads threads × per_thread tasks each; wall clock
            until every result is back on its submitting thread."""
            threads = [threading.Thread(target=worker,
                                        args=(k * per_thread,))
                       for k in range(n_threads)]
            t0 = time.perf_counter()
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            return time.perf_counter() - t0

        # -- executor path: futures in flight, coalesced submit ----------
        ex = client.executor(endpoint_id=eid)

        def ex_worker(base):
            futs = [ex.submit(fid, {"x": base + i})
                    for i in range(per_thread)]
            for f in futs:
                f.result(timeout=60)

        storm(ex_worker)                                        # warm
        env0, sub0 = svc.submit_envelopes, svc.submitted
        ex_rates = [n_tasks / storm(ex_worker) for _ in range(repeats)]
        envelopes = svc.submit_envelopes - env0
        tasks = svc.submitted - sub0
        ex.shutdown(wait=True)

        # -- baseline: funcX per-call usage (Listing 1) — each thread
        # blocks on one run/get_result round trip per task ---------------
        def pc_worker(base):
            for i in range(per_thread):
                client.get_result(client.run(fid, eid,
                                             data={"x": base + i}),
                                  timeout=30)

        storm(pc_worker)                                        # warm
        pc_rates = [n_tasks / storm(pc_worker) for _ in range(repeats)]

        ex_tp, pc_tp = max(ex_rates), max(pc_rates)
        emit("sec5/executor/tasks_per_s", ex_tp,
             f"best of {repeats} storms of {n_threads}x{per_thread}; "
             f"median={sorted(ex_rates)[len(ex_rates) // 2]:.0f}")
        emit("sec5/executor/percall_tasks_per_s", pc_tp,
             "same storm, per-call run+get_result round trip per task")
        emit("sec5/executor/speedup_vs_percall", ex_tp / pc_tp,
             "futures pipeline + coalesced submit vs per-call round trips")
        emit("sec5/executor/submit_envelopes_per_task", envelopes / tasks,
             f"n={tasks} (per-call: 1.0; acceptance <= 1/8 = 0.125)")

        # -- lone submit: idle line must flush inline --------------------
        n_lone = 30 if not tiny else 10
        ex = client.executor(endpoint_id=eid)
        ex.submit(fid, {"x": 0}).result(timeout=10)             # warm
        t0 = time.perf_counter()
        for i in range(n_lone):
            ex.submit(fid, {"x": i}).result(timeout=10)
        lone_ex = (time.perf_counter() - t0) / n_lone
        ex.shutdown(wait=True)
        t0 = time.perf_counter()
        for i in range(n_lone):
            client.get_result(client.run(fid, eid, data={"x": i}),
                              timeout=10)
        lone_pc = (time.perf_counter() - t0) / n_lone
        emit("sec5/executor/lone_submit_roundtrip_us", lone_ex * 1e6,
             f"n={n_lone} (idle line -> inline flush, no linger)")
        emit("sec5/executor/lone_overhead_ratio", lone_ex / lone_pc,
             f"vs client.run roundtrip {lone_pc * 1e6:.0f}us "
             f"(harvest-thread hop only; linger would be 3x+)")
        agent.stop()
    finally:
        svc.shutdown()
