"""§5 hierarchical interchange at scale (DESIGN.md §11).

The paper's headline scaling claims (>100k queued tasks, 130k workers)
hang off the interchange tier: a relay that registers upstream as one
endpoint, absorbs deep bursts into a bounded backlog, and elastically
provisions leaf endpoints below itself. This suite retires the old
``fig4sim`` discrete-event rows with *measured* numbers from a real
relay tree of OS processes:

- **absorption**: a 100k-noop burst (default mode) lands entirely in the
  interchange backlog before a single leaf exists — observed upstream
  through the synthesized heartbeat's ``backlog`` gauge;
- **O(1) service**: the whole relay tree (interchange + elastic leaves)
  costs the service process zero additional threads;
- **elasticity**: the backlog provisions leaf endpoint processes
  (observable as the advertised capacity going 0 → leaves × workers);
- **steady-state throughput**: the same leaves behind the relay must
  stay within ~0.9× of the flat (interchange-less) fleet — the hop
  queues, it must not throttle.

Lanes are process-isolated: the interchange and every leaf are spawned
subprocesses, so the service-side thread count is a clean gauge.
"""
from __future__ import annotations

import subprocess
import threading
import time

from .common import emit, make_bench_service

CHUNK = 4000          # submit granularity (client.batch_run per call)


def _submit(client, fid, eid, n):
    ids = []
    for off in range(0, n, CHUNK):
        ids += client.batch_run([(fid, eid, {})
                                 for _ in range(min(CHUNK, n - off))])
    return ids


def _measured(client, fid, eid, n, timeout=900):
    t0 = time.perf_counter()
    ids = _submit(client, fid, eid, n)
    client.get_batch_results(ids, timeout=timeout)
    return n / (time.perf_counter() - t0)


def _flat_lane(n_leaves, workers, n_steady):
    """Baseline: the same leaves registered directly with the service."""
    from repro.core.endpoint import demo_noop, spawn_endpoint_process
    svc, client = make_bench_service()
    procs = []
    try:
        fid = client.register_function(demo_noop)
        address = svc.listen()
        token = client.endpoint_credentials()
        eids = []
        for i in range(n_leaves):
            p, eid = spawn_endpoint_process(address, token,
                                            name=f"flat{i}",
                                            workers=workers, shm=False,
                                            peer=False)
            procs.append(p)
            eids.append(eid)
        for eid in eids:                                   # warm
            _measured(client, fid, eid, 16)
        return _measured(client, fid, None, n_steady)
    finally:
        for p in procs:
            p.terminate()
        for p in procs:
            try:
                p.wait(timeout=5)
            except subprocess.TimeoutExpired:
                p.kill()
        svc.shutdown()


def _relay_lane(n_leaves, workers, n_burst, n_steady, acquire_delay):
    """The relay tree: one interchange subprocess, leaves provisioned
    elastically by its own strategy as the backlog grows."""
    from repro.core import spawn_interchange_process
    from repro.core.endpoint import demo_noop
    svc, client = make_bench_service()
    proc = None
    try:
        fid = client.register_function(demo_noop)
        host, port = svc.listen()
        time.sleep(0.5)           # let prior-lane threads finish dying
        threads_before = threading.active_count()
        proc, eid, _leaf_addr = spawn_interchange_process(
            f"{host}:{port}", client.endpoint_credentials(),
            name="relay", depth=max(150_000, 2 * n_burst),
            min_blocks=0, max_blocks=n_leaves,
            backlog_per_block=-(-n_burst // n_leaves),     # ceil
            idle_timeout=120.0, leaf_workers=workers,
            acquire_delay=acquire_delay)
        line = svc.pool.line(eid)
        deadline = time.time() + 30
        while line.advertised.credits < 0 and time.time() < deadline:
            time.sleep(0.01)
        assert line.advertised.credits >= 0, "no credit advertisement"

        # --- burst absorption: leaves are acquire_delay away, so the
        # whole burst must land in the relay's backlog
        t0 = time.perf_counter()
        ids = _submit(client, fid, eid, n_burst)
        depth_peak = 0
        absorb_s = None
        capacity_peak = 0
        deadline = time.time() + 900
        while time.time() < deadline:
            hb = line.advertised
            depth_peak = max(depth_peak, hb.backlog)
            capacity_peak = max(capacity_peak, hb.capacity)
            if absorb_s is None and hb.backlog >= n_burst:
                absorb_s = time.perf_counter() - t0
            if hb.backlog == 0 and hb.queued == 0 and capacity_peak > 0 \
                    and absorb_s is not None:
                break
            time.sleep(0.02)
        client.get_batch_results(ids, timeout=900)
        drain_s = time.perf_counter() - t0
        threads_during = threading.active_count()

        # --- steady state: leaves are up and warm; measure the relayed
        # throughput to compare against the flat fleet
        hb = line.advertised
        capacity_peak = max(capacity_peak, hb.capacity)
        relay_rate = _measured(client, fid, eid, n_steady)
        return {
            "depth_peak": depth_peak,
            "absorb_s": absorb_s if absorb_s is not None else drain_s,
            "drain_s": drain_s,
            "capacity_peak": capacity_peak,
            "threads_added": threads_during - threads_before,
            "relay_rate": relay_rate,
        }
    finally:
        if proc is not None:
            proc.terminate()
            try:
                proc.wait(timeout=10)
            except subprocess.TimeoutExpired:
                proc.kill()
        svc.shutdown()


def run(full: bool = False, tiny: bool = False) -> None:
    if tiny:              # CI smoke: same invariants, scaled-down burst
        n_burst, n_steady, n_leaves, workers = 2000, 1000, 2, 2
        acquire_delay = 1.0
    elif full:
        n_burst, n_steady, n_leaves, workers = 150_000, 20_000, 4, 4
        acquire_delay = 5.0
    else:
        n_burst, n_steady, n_leaves, workers = 100_000, 10_000, 4, 4
        acquire_delay = 5.0

    flat_rate = _flat_lane(n_leaves, workers, n_steady)
    emit("sec5_interchange/flat_tasks_per_s", flat_rate,
         f"n={n_steady} leaves={n_leaves}x{workers}w")

    r = _relay_lane(n_leaves, workers, n_burst, n_steady, acquire_delay)
    emit("sec5_interchange/burst_tasks", n_burst,
         f"leaves acquire in {acquire_delay}s")
    emit("sec5_interchange/queued_depth_peak", r["depth_peak"],
         f"backlog gauge via synthesized heartbeat; burst={n_burst}")
    emit("sec5_interchange/burst_absorb_s", r["absorb_s"],
         f"rate={n_burst / r['absorb_s']:.0f}/s into the backlog")
    emit("sec5_interchange/burst_drain_s", r["drain_s"],
         "submit -> all results (includes elastic scale-out)")
    emit("sec5_interchange/scale_out_capacity", r["capacity_peak"],
         f"advertised workers after elastic scale-out "
         f"(target {n_leaves * workers})")
    emit("sec5_interchange/service_threads_added", r["threads_added"],
         "service thread-count delta for the whole relay tree")
    emit("sec5_interchange/relay_tasks_per_s", r["relay_rate"],
         f"n={n_steady} via interchange")
    emit("sec5_interchange/relay_vs_flat_ratio",
         r["relay_rate"] / flat_rate if flat_rate else 0.0,
         "steady-state; gate floor 0.9")
