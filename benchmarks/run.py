"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3_latency,...] [--full]

Prints ``name,value,derived`` CSV (value µs unless the name states
otherwise). Roofline terms for §Roofline come from the compiled dry-run
(``python -m repro.launch.dryrun``), not from here — this harness measures
the FaaS system itself, which runs for real on CPU.
"""
from __future__ import annotations

import argparse
import inspect
import time

SUITES = {
    "fig3_latency": ("latency", "Fig 3 latency breakdown"),
    "fig4_scaling": ("scaling", "Fig 4 strong/weak scaling + throughput"),
    "fig5_t1_t2_data": ("data_mgmt", "Fig 5 + Tables 1-2 data management"),
    "table3_containers": ("container_cost", "Table 3 container cold starts"),
    "fig6_7_routing": ("routing", "Figs 6-7 warming-aware routing"),
    "sec7.5_batching": ("batching", "§7.5 batching"),
}


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--only", default="all",
                   help="comma list of suites: " + ",".join(SUITES))
    p.add_argument("--full", action="store_true",
                   help="paper-scale parameters (slower)")
    p.add_argument("--tiny", action="store_true",
                   help="smoke-test parameters (suites that support them)")
    args = p.parse_args()
    sel = list(SUITES) if args.only == "all" else args.only.split(",")

    print("name,value,derived")
    t0 = time.perf_counter()
    for key in sel:
        mod_name, desc = SUITES[key]
        print(f"# === {key}: {desc} ===", flush=True)
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        t1 = time.perf_counter()
        kw = {"full": args.full}
        if args.tiny and "tiny" in inspect.signature(mod.run).parameters:
            kw["tiny"] = True
        mod.run(**kw)
        print(f"# {key} done in {time.perf_counter()-t1:.1f}s", flush=True)
    print(f"# all suites done in {time.perf_counter()-t0:.1f}s")


if __name__ == "__main__":
    main()
