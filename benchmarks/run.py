"""Benchmark harness — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only fig3_latency,...] [--full]

Prints ``name,value,derived`` CSV (value µs unless the name states
otherwise). Roofline terms for §Roofline come from the compiled dry-run
(``python -m repro.launch.dryrun``), not from here — this harness measures
the FaaS system itself, which runs for real on CPU.
"""
from __future__ import annotations

import argparse
import inspect
import json
import time

from .common import ROWS

SUITES = {
    "fig3_latency": ("latency", "Fig 3 latency breakdown"),
    "fig4_scaling": ("scaling", "Fig 4 strong/weak scaling + throughput"),
    "fig5_t1_t2_data": ("data_mgmt", "Fig 5 + Tables 1-2 data management"),
    "table3_containers": ("container_cost", "Table 3 container cold starts"),
    "fig6_7_routing": ("routing", "Figs 6-7 warming-aware routing"),
    "sec7.5_batching": ("batching", "§7.5 batching"),
    "sec4.5_serialization": ("serialization",
                             "§4.5 pack-once data plane throughput"),
    "sec7.2.3_results": ("results_plane",
                         "§7.2.3 batched result plane (DESIGN.md §6)"),
    "sec7_shm": ("shm_bench",
                 "DESIGN.md §7 same-host shm vs tcp transport"),
    "sec5_executor": ("executor_bench",
                      "§5 futures-native executor submit coalescing "
                      "(DESIGN.md §8)"),
    "sec6_p2p": ("p2p_bench",
                 "§5/§6 peer data plane all-to-all shuffle "
                 "(DESIGN.md §9)"),
    "sec10_serving": ("serving_bench",
                      "DESIGN.md §10 serving fabric: jit-cache-aware "
                      "routing vs random over socket endpoints"),
    "sec5_interchange": ("interchange_bench",
                         "§5 hierarchical interchange: 100k-task burst "
                         "absorption + elastic leaves (DESIGN.md §11)"),
}

ARTIFACT = "BENCH_10.json"         # seeded from BENCH_9.json (PR 9 run)


def write_artifact(path: str, per_suite) -> None:
    """Scenario → metric map, so the perf trajectory is diffable across
    PRs (BENCH_<n>.json, n = PR number). Partial runs (``--only``,
    ``bench-smoke``) merge into an existing artifact instead of
    truncating it — only the suites that actually ran are refreshed."""
    doc = {}
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError):
        pass
    doc.update({
        suite: {name: value for name, value, _ in rows}
        for suite, rows in per_suite.items() if rows
    })
    with open(path, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    print(f"# artifact written: {path}")


def main() -> None:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--only", default="all",
                   help="comma list of suites: " + ",".join(SUITES))
    p.add_argument("--full", action="store_true",
                   help="paper-scale parameters (slower)")
    p.add_argument("--tiny", action="store_true",
                   help="smoke-test parameters (suites that support them)")
    p.add_argument("--artifact", default=ARTIFACT,
                   help="JSON artifact path ('' disables)")
    args = p.parse_args()
    sel = list(SUITES) if args.only == "all" else args.only.split(",")

    print("name,value,derived")
    t0 = time.perf_counter()
    per_suite = {}
    for key in sel:
        mod_name, desc = SUITES[key]
        print(f"# === {key}: {desc} ===", flush=True)
        mod = __import__(f"benchmarks.{mod_name}", fromlist=["run"])
        t1 = time.perf_counter()
        kw = {"full": args.full}
        if args.tiny and "tiny" in inspect.signature(mod.run).parameters:
            kw["tiny"] = True
        mark = len(ROWS)
        mod.run(**kw)
        per_suite[key] = [r.split(",", 2) for r in ROWS[mark:]]
        per_suite[key] = [(n, float(v), d) for n, v, d in per_suite[key]]
        print(f"# {key} done in {time.perf_counter()-t1:.1f}s", flush=True)
    print(f"# all suites done in {time.perf_counter()-t0:.1f}s")
    if args.artifact:
        write_artifact(args.artifact, per_suite)


if __name__ == "__main__":
    main()
