"""Shared benchmark helpers + CSV emission.

Every benchmark prints ``name,value,derived`` rows (value in µs unless the
name says otherwise) so ``python -m benchmarks.run`` output is one flat CSV.
"""
from __future__ import annotations

import time
from contextlib import contextmanager
from typing import List

ROWS: List[str] = []


def emit(name: str, value: float, derived: str = "") -> None:
    row = f"{name},{value:.3f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


@contextmanager
def timed():
    box = {}
    t0 = time.perf_counter()
    yield box
    box["s"] = time.perf_counter() - t0


def make_bench_service(*, heartbeat=0.5, forwarder_batch=32):
    from repro.core import FuncXClient, FuncXService
    svc = FuncXService(heartbeat_timeout=heartbeat,
                       forwarder_batch=forwarder_batch)
    tok = svc.register_user("bench")
    return svc, FuncXClient(svc, tok)
