"""§7.2.3 + DESIGN.md §6 — the batched result plane, measured.

Three gauges the acceptance gate watches:

- ``throughput_tasks_per_s`` — peak tasks/s through one agent with the
  coalesced return path. §7.2.3 measures *peak* throughput, so the run
  repeats and records the best (shared-host interference shows up as
  slow outliers, never fast ones).
- ``envelopes_per_task`` — return-path wire frames (results + acks +
  retransmissions) per completed task. The pre-batch plane paid ≥1
  result envelope per task; the coalescer amortizes toward
  1/batch_size. Must stay < 1 under load.
- ``lone_task_roundtrip_us`` — a single task on an idle line must not
  pay the linger: the coalescer's inline idle-path flush keeps this at
  the fig3 single-task total.
"""
from __future__ import annotations

import time

from .common import emit, make_bench_service


def run(n_tasks: int = 3000, repeats: int = 5, workers: int = 64,
        full: bool = False, tiny: bool = False) -> None:
    if full:
        n_tasks, repeats = 10000, 5
    if tiny:
        n_tasks, repeats = 600, 3
    svc, client = make_bench_service()
    try:
        fid = client.register_function(lambda d: None, name="noop")
        eid, agent = svc.make_endpoint(client.token, "ep", n_managers=4,
                                       workers_per_manager=workers // 4)
        co = agent.coalescer

        def run_batch(n):
            t0 = time.perf_counter()
            ids = client.batch_run([(fid, eid, {}) for _ in range(n)])
            client.get_batch_results(ids, timeout=300)
            return time.perf_counter() - t0

        run_batch(min(64, n_tasks))                      # warm
        rates = []
        e0, r0, re0 = co.envelopes_sent, co.results_sent, co.result_envelopes
        for _ in range(repeats):
            rates.append(n_tasks / run_batch(n_tasks))
        envelopes = co.envelopes_sent - e0
        results = co.results_sent - r0
        result_envs = co.result_envelopes - re0
        total = repeats * n_tasks
        emit("sec7.2.3/results_plane/throughput_tasks_per_s", max(rates),
             f"best of {repeats} runs of {n_tasks}; "
             f"median={sorted(rates)[len(rates) // 2]:.0f}")
        emit("sec7.2.3/results_plane/envelopes_per_task", envelopes / total,
             f"n={total} (pre-batch plane: >=1.0; target <1)")
        emit("sec7.2.3/results_plane/results_per_envelope",
             results / max(result_envs, 1),
             f"batch_size={co.batch_size} linger={co.linger * 1e3:.1f}ms")

        # lone-task latency: idle line, inline flush — mean wall clock of
        # sequential single-task round-trips
        n_lone = 30 if not tiny else 10
        t0 = time.perf_counter()
        for _ in range(n_lone):
            client.get_result(client.run(fid, eid, data={}), timeout=10)
        lone = (time.perf_counter() - t0) / n_lone
        emit("sec7.2.3/results_plane/lone_task_roundtrip_us", lone * 1e6,
             f"n={n_lone} (immediate flush when idle; no linger penalty)")
        agent.stop()
    finally:
        svc.shutdown()
