"""DESIGN.md §7 — same-host transport comparison: the identical subprocess
endpoint fleet over plain TcpTransport vs the auto-negotiated shared-memory
ring pair. Same service, same task mix, back to back; emits the speedup and
a binary shm-engaged gauge that ``tools/bench_gate.py --shm`` gates on.
"""
from __future__ import annotations

from .common import emit
from .scaling import subprocess_lane


def run(full: bool = False, tiny: bool = False) -> None:
    if tiny:
        n_endpoints, per_ep, repeats = 2, 50, 3
    elif full:
        n_endpoints, per_ep, repeats = 4, 200, 3
    else:
        n_endpoints, per_ep, repeats = 4, 100, 3

    tcp_rate, _, tcp_shm = subprocess_lane(
        "subprocess_tcp", False, n_endpoints, per_ep, prefix="shm",
        repeats=repeats)
    shm_rate, _, n_shm = subprocess_lane(
        "subprocess_shm", True, n_endpoints, per_ep, prefix="shm",
        repeats=repeats)
    emit("shm/speedup_vs_tcp", shm_rate / max(tcp_rate, 1e-9),
         f"shm={shm_rate:.0f}/s tcp={tcp_rate:.0f}/s "
         f"endpoints={n_endpoints}")
    # binary engagement gauge (noise-immune, like envelopes_per_task):
    # 1.0 = every shm-lane channel upgraded AND the tcp lane stayed tcp
    engaged = 1.0 if (n_shm == n_endpoints and tcp_shm == 0) else 0.0
    emit("shm/channels_upgraded", engaged,
         f"shm_lane={n_shm}/{n_endpoints} tcp_lane={tcp_shm}/0 expected")
