"""Fig. 5 + Tables 1–2 — intra-endpoint data management.

Fig. 5: point-to-point / broadcast / all-to-all transfer patterns across
store backends (in-memory KV ≙ Redis, shared FS, device store ≙ beyond-
paper zero-copy) over a range of sizes.

Table 1: MapReduce WordCount & Sort shuffle phases, Redis-analogue vs
sharedFS. Table 2: Colmena-style pipeline stage times.
"""
from __future__ import annotations

import os
import tempfile
import time
from typing import Dict, List

import numpy as np

from .common import emit


def _stores(tmp):
    from repro.data import DeviceStore, InMemoryKVStore, SharedFSStore
    return {
        "memory": InMemoryKVStore(),
        "sharedfs": SharedFSStore(os.path.join(tmp, "fs")),
        "device": DeviceStore(),
    }


# ------------------------------------------------------------------- Fig. 5

def patterns(sizes=(1 << 10, 1 << 16, 1 << 22), n_workers: int = 8,
             reps: int = 5) -> None:
    with tempfile.TemporaryDirectory() as tmp:
        for name, store in _stores(tmp).items():
            for size in sizes:
                data = np.random.default_rng(0).integers(
                    0, 255, size, dtype=np.uint8)
                # point-to-point: one writer, one reader
                t0 = time.perf_counter()
                for r in range(reps):
                    store.set(f"p2p/{r}", data)
                    store.get(f"p2p/{r}")
                t = (time.perf_counter() - t0) / reps
                emit(f"fig5/p2p/{name}/{size}B", t * 1e6,
                     f"{size/t/1e6:.1f}MB/s")
                # broadcast: one writer, n readers
                store.set("bcast", data)
                t0 = time.perf_counter()
                for r in range(reps):
                    for _ in range(n_workers):
                        store.get("bcast")
                t = (time.perf_counter() - t0) / reps
                emit(f"fig5/broadcast{n_workers}/{name}/{size}B", t * 1e6,
                     f"{size*n_workers/t/1e6:.1f}MB/s")
                # all-to-all: n writers × n readers (shuffle)
                t0 = time.perf_counter()
                for r in range(reps):
                    for i in range(n_workers):
                        store.set(f"a2a/{r}/{i}", data)
                    for i in range(n_workers):
                        for j in range(n_workers):
                            store.get(f"a2a/{r}/{i}")
                t = (time.perf_counter() - t0) / reps
                emit(f"fig5/alltoall{n_workers}/{name}/{size}B", t * 1e6,
                     f"{size*n_workers*n_workers/t/1e6:.1f}MB/s")


# ------------------------------------------------------------------ Table 1

def _wordcount_map(data):
    from collections import Counter
    return dict(Counter(data.split()))


def mapreduce(n_map: int = 16, n_reduce: int = 16,
              words_per_map: int = 20_000, sort_mode: bool = False) -> Dict:
    """Runs the shuffle through a store backend; returns phase timings."""
    rng = np.random.default_rng(0)
    vocab = [f"w{i:04d}" for i in range(2000)]
    texts = [" ".join(rng.choice(vocab, words_per_map)) for _ in range(n_map)]
    out = {}
    with tempfile.TemporaryDirectory() as tmp:
        for name, store in _stores(tmp).items():
            if name == "device":
                continue            # host-object workload
            t_write = t_read = 0.0
            t0 = time.perf_counter()
            # map + intermediate write (partitioned by hash → reducer)
            for m, text in enumerate(texts):
                if sort_mode:
                    keys = sorted(text.split())
                    parts: Dict[int, List] = {}
                    for w in keys:
                        parts.setdefault(hash(w) % n_reduce, []).append(w)
                else:
                    counts = _wordcount_map(text)
                    parts = {}
                    for w, c in counts.items():
                        parts.setdefault(hash(w) % n_reduce, {})[w] = c
                tw = time.perf_counter()
                for r, part in parts.items():
                    store.set(f"shuffle/{m}/{r}", part)
                t_write += time.perf_counter() - tw
            # reduce: intermediate read + merge
            for r in range(n_reduce):
                tr = time.perf_counter()
                parts = []
                for m in range(n_map):
                    try:
                        parts.append(store.get(f"shuffle/{m}/{r}"))
                    except KeyError:
                        pass
                t_read += time.perf_counter() - tr
                if sort_mode:
                    merged = sorted(x for p in parts for x in p)
                else:
                    merged = {}
                    for p in parts:
                        for w, c in p.items():
                            merged[w] = merged.get(w, 0) + c
            total = time.perf_counter() - t0
            app = "sort" if sort_mode else "wordcount"
            emit(f"table1/{app}/intermediate_write/{name}", t_write * 1e6,
                 f"maps={n_map} reducers={n_reduce}")
            emit(f"table1/{app}/intermediate_read/{name}", t_read * 1e6, "")
            emit(f"table1/{app}/total/{name}", total * 1e6, "")
            out[(app, name)] = (t_write, t_read, total)
    return out


# ------------------------------------------------------------------ Table 2

def colmena(n_tasks: int = 100, payload_bytes: int = 1 << 20) -> None:
    """Colmena-style stages: Thinker writes input → Worker reads input,
    writes result → Task server reads result. 1 MB in / 1 MB out."""
    data_in = np.random.default_rng(0).integers(0, 255, payload_bytes,
                                                dtype=np.uint8)
    with tempfile.TemporaryDirectory() as tmp:
        for name, store in _stores(tmp).items():
            if name == "device":
                continue
            stages = {"input_write": 0.0, "input_read": 0.0,
                      "result_write": 0.0, "result_read": 0.0}
            for i in range(n_tasks):
                t0 = time.perf_counter()
                store.set(f"in/{i}", data_in)
                stages["input_write"] += time.perf_counter() - t0
                t0 = time.perf_counter()
                x = store.get(f"in/{i}")
                stages["input_read"] += time.perf_counter() - t0
                t0 = time.perf_counter()
                store.set(f"out/{i}", x)
                stages["result_write"] += time.perf_counter() - t0
                t0 = time.perf_counter()
                store.get(f"out/{i}")
                stages["result_read"] += time.perf_counter() - t0
            for stage, tot in stages.items():
                emit(f"table2/colmena/{stage}/{name}",
                     tot / n_tasks * 1e6, f"n={n_tasks} 1MB payloads")


def run(full: bool = False) -> None:
    patterns(sizes=(1 << 10, 1 << 16, 1 << 22) if not full
             else (1 << 10, 1 << 14, 1 << 18, 1 << 22, 1 << 25))
    mapreduce(sort_mode=False)
    mapreduce(sort_mode=True)
    colmena(n_tasks=100 if not full else 1000)
