"""`make lint`: ruff when available, stdlib dead-import sweep otherwise.

CI installs ruff and gets the full `ruff check` rule set (pyproject.toml
``[tool.ruff]``). Containers without ruff — like the pinned benchmark
image — fall back to an AST-based unused-import check (the F401 subset
that matters most here: dead imports creeping into `src/repro/core/`), so
the lint gate never silently becomes a no-op.

    python -m tools.lint [paths...]
"""
from __future__ import annotations

import ast
import os
import shutil
import subprocess
import sys
from typing import List, Optional, Tuple

DEFAULT_PATHS = ["src", "tests", "benchmarks", "examples", "tools"]


def try_ruff(paths: List[str]) -> Optional[int]:
    """Run ruff if it exists; None means not installed."""
    exe = shutil.which("ruff")
    if exe is not None:
        return subprocess.call([exe, "check", *paths])
    try:
        import ruff  # noqa: F401  (probe only)
    except ImportError:
        return None
    return subprocess.call([sys.executable, "-m", "ruff", "check", *paths])


def iter_python_files(paths: List[str]):
    for path in paths:
        if os.path.isfile(path) and path.endswith(".py"):
            yield path
            continue
        for root, dirs, files in os.walk(path):
            dirs[:] = [d for d in dirs
                       if not d.startswith(".") and d != "__pycache__"]
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def unused_imports(tree: ast.AST) -> List[Tuple[int, str]]:
    """Conservative F401: flag an imported name only when it appears
    nowhere else — not as a Name load, not inside any string constant
    (covers ``__all__`` re-export lists and string annotations)."""
    imported: List[Tuple[int, str]] = []
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for a in node.names:
                imported.append((node.lineno,
                                 (a.asname or a.name).split(".")[0]))
        elif isinstance(node, ast.ImportFrom):
            if node.module == "__future__":
                continue
            for a in node.names:
                if a.name != "*":
                    imported.append((node.lineno, a.asname or a.name))
    used = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Name):
            used.add(node.id)
        elif isinstance(node, ast.Constant) and isinstance(node.value, str):
            used.update(node.value.replace(".", " ").split())
    return [(lineno, name) for lineno, name in imported if name not in used]


def fallback_check(paths: List[str]) -> int:
    findings = []
    for path in iter_python_files(paths):
        if os.path.basename(path) == "__init__.py":
            continue                       # re-export surface
        try:
            with open(path, "rb") as f:
                tree = ast.parse(f.read(), filename=path)
        except SyntaxError as e:
            findings.append((path, e.lineno or 0, f"syntax error: {e.msg}"))
            continue
        for lineno, name in unused_imports(tree):
            findings.append((path, lineno, f"unused import: {name}"))
    for path, lineno, msg in findings:
        print(f"{path}:{lineno}: {msg}")
    if findings:
        print(f"tools.lint: {len(findings)} finding(s)", file=sys.stderr)
        return 1
    print(f"tools.lint: clean (fallback checker; install ruff for the "
          f"full rule set)")
    return 0


def main(argv: Optional[List[str]] = None) -> int:
    paths = (argv if argv else DEFAULT_PATHS)
    rc = try_ruff(paths)
    if rc is not None:
        return rc
    return fallback_check(paths)


if __name__ == "__main__":
    raise SystemExit(main(sys.argv[1:]))
