"""Perf-regression smoke gate (CI: bench-results, bench-shm,
bench-executor).

Compares a freshly produced benchmark artifact against the committed
baseline (BENCH_10.json) with tolerance:

- ``sec7.2.3/results_plane/throughput_tasks_per_s`` must be at least
  ``--tolerance`` × baseline (throughput; higher is better). CI runners
  vary wildly, so the default tolerance is loose — the gate catches
  collapses (a reintroduced per-task lock convoy, a lost batching path),
  not single-digit drift.
- ``sec7.2.3/results_plane/envelopes_per_task`` must stay < 1.0 — the
  absolute invariant of the batched return path (the pre-batch plane
  paid ≥ 1 envelope per task). This bound is noise-immune: batching
  either happens or it doesn't.

With ``--shm`` it instead gates the same-host transport suite
(``sec7_shm``, DESIGN.md §7):

- ``shm/channels_upgraded`` must be exactly 1.0 — every endpoint in the
  shm lane negotiated the ring pair and the tcp lane stayed on the
  socket. Binary and noise-immune: negotiation works or it doesn't.
- ``shm/speedup_vs_tcp`` must be at least ``--shm-floor`` (default 0.4:
  a collapse detector for the ring path — a stall/retry storm, a lost
  doorbell — not a parity gate; on loaded single-core runners shm vs
  tcp jitters around 1× at smoke scale, and the real margin is recorded
  in the committed artifact).

With ``--executor`` it gates the futures-native submit plane
(``sec5_executor``, DESIGN.md §8):

- ``executor/submit_envelopes_per_task`` must be ≤ ``--envelope-cap``
  (default 0.125 = the ISSUE's 1/8 acceptance bound). Noise-immune:
  submit coalescing either amortizes the storm or it doesn't.
- ``executor/speedup_vs_percall`` must be at least ``--executor-floor``
  (default 0.9: a collapse detector — a lost coalescing path drops the
  executor to per-call throughput or below; the real ≥1.2× margin is
  recorded in the committed artifact, but smoke-scale storms on loaded
  runners jitter).
- ``executor/lone_overhead_ratio`` must stay < 2.0 — a lone submit that
  starts paying the linger (a broken idle-line inline flush) shows up
  as 3×+ against the direct ``client.run`` roundtrip.

With ``--p2p`` it gates the peer data plane (``sec6_p2p``, DESIGN.md
§9):

- ``p2p/peer/hub_relay_bytes`` must be exactly 0 — with every peer
  listener up, no intermediate byte may transit the hub. Binary and
  noise-immune: the fallback ladder either stops at the direct rung or
  it doesn't.
- ``p2p/speedup_vs_hub`` must be at least ``--p2p-floor`` (default 0.9:
  a collapse detector — a broken pipelined fetch or a lane silently
  relaying drops to ~1× or below; on loaded single-core runners the
  measured margin jitters between ~1.5× idle and >2× under CPU
  contention, and the real margin is recorded in the committed
  artifact).

With ``--serving`` it gates the serving fabric (``sec10_serving``,
DESIGN.md §10):

- ``serving/warm_hit_advantage`` (aware − random warm-hit rate) must be
  ≥ 0 — jit-cache-aware routing must never lose to random scattering on
  warm hits. Both lanes serve an identical request stream against an
  identical fleet, so the comparison is noise-resistant even at smoke
  scale.
- ``serving/aware/warm_hit_rate`` must be ≥ ``--serving-floor`` (default
  0.5: with one warm slot per model fleet-wide, warmth-aware routing
  keeps the majority of the stream on compiled executables).

With ``--interchange`` it gates the hierarchical relay tier
(``sec5_interchange``, DESIGN.md §11):

- ``sec5_interchange/service_threads_added`` must be ≤ 0 — registering
  a whole relay tree (interchange + elastic leaves) costs the service
  process no additional threads. Binary and noise-immune (negative
  deltas just mean unrelated threads died between the samples).
- ``sec5_interchange/queued_depth_peak`` must reach the full burst,
  floored at ``min(100_000, burst_tasks)`` — the backlog either absorbs
  the burst (acked upstream, nothing dropped) or it doesn't. Smoke runs
  submit a smaller burst, so the floor follows the recorded burst size;
  default runs gate the paper-scale 100k depth.
- ``sec5_interchange/relay_vs_flat_ratio`` must be ≥ ``--ix-floor``
  (default 0.9): steady-state throughput through the relay vs the same
  leaves registered flat — the hop queues, it must not throttle.
- ``sec5_interchange/scale_out_capacity`` must be > 0 — elastic leaf
  provisioning observably kicked in (capacity went 0 → leaves×workers).

Exit code 0 = pass, 1 = regression, 2 = malformed/missing artifacts.

    python -m tools.bench_gate --baseline BENCH_10.json \
        --fresh bench_fresh.json [--tolerance 0.4]
    python -m tools.bench_gate --shm --fresh bench_fresh.json
    python -m tools.bench_gate --executor --fresh bench_fresh.json
    python -m tools.bench_gate --p2p --fresh bench_fresh.json
    python -m tools.bench_gate --interchange --fresh bench_fresh.json
"""
from __future__ import annotations

import argparse
import json
import sys

SUITE = "sec7.2.3_results"
THROUGHPUT = "sec7.2.3/results_plane/throughput_tasks_per_s"
ENVELOPES = "sec7.2.3/results_plane/envelopes_per_task"

SHM_SUITE = "sec7_shm"
SHM_SPEEDUP = "shm/speedup_vs_tcp"
SHM_UPGRADED = "shm/channels_upgraded"

EXEC_SUITE = "sec5_executor"
EXEC_ENVELOPES = "sec5/executor/submit_envelopes_per_task"
EXEC_SPEEDUP = "sec5/executor/speedup_vs_percall"
EXEC_LONE = "sec5/executor/lone_overhead_ratio"

P2P_SUITE = "sec6_p2p"
P2P_RELAY = "p2p/peer/hub_relay_bytes"
P2P_SPEEDUP = "p2p/speedup_vs_hub"

SERVING_SUITE = "sec10_serving"
SERVING_ADVANTAGE = "serving/warm_hit_advantage"
SERVING_AWARE_RATE = "serving/aware/warm_hit_rate"

IX_SUITE = "sec5_interchange"
IX_THREADS = "sec5_interchange/service_threads_added"
IX_DEPTH = "sec5_interchange/queued_depth_peak"
IX_BURST = "sec5_interchange/burst_tasks"
IX_RATIO = "sec5_interchange/relay_vs_flat_ratio"
IX_CAPACITY = "sec5_interchange/scale_out_capacity"


def load_suite(path: str, suite_key: str = SUITE) -> dict:
    try:
        with open(path) as f:
            doc = json.load(f)
    except (OSError, ValueError) as e:
        print(f"bench-gate: cannot read {path}: {e}")
        sys.exit(2)
    suite = doc.get(suite_key)
    if not isinstance(suite, dict):
        print(f"bench-gate: {path} has no {suite_key!r} suite")
        sys.exit(2)
    return suite


def gate_shm(args) -> int:
    fresh = load_suite(args.fresh, SHM_SUITE)
    failures = []

    upgraded = fresh.get(SHM_UPGRADED)
    speedup = fresh.get(SHM_SPEEDUP)
    if upgraded is None or speedup is None:
        print(f"bench-gate: {SHM_UPGRADED} / {SHM_SPEEDUP} missing "
              f"(got {upgraded}, {speedup})")
        return 2
    status = "ok" if upgraded == 1.0 else "REGRESSION"
    print(f"bench-gate: shm channels upgraded={upgraded} "
          f"(invariant: 1.0) -> {status}")
    if upgraded != 1.0:
        failures.append(SHM_UPGRADED)
    status = "ok" if speedup >= args.shm_floor else "REGRESSION"
    print(f"bench-gate: shm speedup vs tcp={speedup:.2f}x "
          f"floor={args.shm_floor:.2f}x -> {status}")
    if speedup < args.shm_floor:
        failures.append(SHM_SPEEDUP)

    if failures:
        print(f"bench-gate: FAILED on {', '.join(failures)}")
        return 1
    print("bench-gate: PASS")
    return 0


def gate_executor(args) -> int:
    fresh = load_suite(args.fresh, EXEC_SUITE)
    failures = []

    envelopes = fresh.get(EXEC_ENVELOPES)
    speedup = fresh.get(EXEC_SPEEDUP)
    lone = fresh.get(EXEC_LONE)
    if envelopes is None or speedup is None or lone is None:
        print(f"bench-gate: {EXEC_ENVELOPES} / {EXEC_SPEEDUP} / "
              f"{EXEC_LONE} missing "
              f"(got {envelopes}, {speedup}, {lone})")
        return 2
    status = "ok" if envelopes <= args.envelope_cap else "REGRESSION"
    print(f"bench-gate: executor submit envelopes/task={envelopes:.3f} "
          f"cap={args.envelope_cap:.3f} -> {status}")
    if envelopes > args.envelope_cap:
        failures.append(EXEC_ENVELOPES)
    status = "ok" if speedup >= args.executor_floor else "REGRESSION"
    print(f"bench-gate: executor speedup vs percall={speedup:.2f}x "
          f"floor={args.executor_floor:.2f}x -> {status}")
    if speedup < args.executor_floor:
        failures.append(EXEC_SPEEDUP)
    status = "ok" if lone < args.lone_cap else "REGRESSION"
    print(f"bench-gate: lone submit overhead={lone:.2f}x "
          f"cap={args.lone_cap:.2f}x -> {status}")
    if lone >= args.lone_cap:
        failures.append(EXEC_LONE)

    if failures:
        print(f"bench-gate: FAILED on {', '.join(failures)}")
        return 1
    print("bench-gate: PASS")
    return 0


def gate_p2p(args) -> int:
    fresh = load_suite(args.fresh, P2P_SUITE)
    failures = []

    relay = fresh.get(P2P_RELAY)
    speedup = fresh.get(P2P_SPEEDUP)
    if relay is None or speedup is None:
        print(f"bench-gate: {P2P_RELAY} / {P2P_SPEEDUP} missing "
              f"(got {relay}, {speedup})")
        return 2
    status = "ok" if relay == 0.0 else "REGRESSION"
    print(f"bench-gate: p2p peer-lane relay bytes={relay:.0f} "
          f"(invariant: 0) -> {status}")
    if relay != 0.0:
        failures.append(P2P_RELAY)
    status = "ok" if speedup >= args.p2p_floor else "REGRESSION"
    print(f"bench-gate: p2p speedup vs hub relay={speedup:.2f}x "
          f"floor={args.p2p_floor:.2f}x -> {status}")
    if speedup < args.p2p_floor:
        failures.append(P2P_SPEEDUP)

    if failures:
        print(f"bench-gate: FAILED on {', '.join(failures)}")
        return 1
    print("bench-gate: PASS")
    return 0


def gate_serving(args) -> int:
    fresh = load_suite(args.fresh, SERVING_SUITE)
    failures = []

    advantage = fresh.get(SERVING_ADVANTAGE)
    aware = fresh.get(SERVING_AWARE_RATE)
    if advantage is None or aware is None:
        print(f"bench-gate: {SERVING_ADVANTAGE} / {SERVING_AWARE_RATE} "
              f"missing (got {advantage}, {aware})")
        return 2
    status = "ok" if advantage >= 0.0 else "REGRESSION"
    print(f"bench-gate: serving warm-hit advantage (aware - random)="
          f"{advantage:+.3f} (invariant: >= 0) -> {status}")
    if advantage < 0.0:
        failures.append(SERVING_ADVANTAGE)
    status = "ok" if aware >= args.serving_floor else "REGRESSION"
    print(f"bench-gate: serving aware warm-hit rate={aware:.3f} "
          f"floor={args.serving_floor:.2f} -> {status}")
    if aware < args.serving_floor:
        failures.append(SERVING_AWARE_RATE)

    if failures:
        print(f"bench-gate: FAILED on {', '.join(failures)}")
        return 1
    print("bench-gate: PASS")
    return 0


def gate_interchange(args) -> int:
    fresh = load_suite(args.fresh, IX_SUITE)
    failures = []

    threads = fresh.get(IX_THREADS)
    depth = fresh.get(IX_DEPTH)
    burst = fresh.get(IX_BURST)
    ratio = fresh.get(IX_RATIO)
    capacity = fresh.get(IX_CAPACITY)
    if None in (threads, depth, burst, ratio, capacity):
        print(f"bench-gate: {IX_THREADS} / {IX_DEPTH} / {IX_BURST} / "
              f"{IX_RATIO} / {IX_CAPACITY} missing (got {threads}, "
              f"{depth}, {burst}, {ratio}, {capacity})")
        return 2
    status = "ok" if threads <= 0 else "REGRESSION"
    print(f"bench-gate: interchange service threads added={threads:.0f} "
          f"(invariant: <= 0) -> {status}")
    if threads > 0:
        failures.append(IX_THREADS)
    depth_floor = min(100_000.0, burst)
    status = "ok" if depth >= depth_floor else "REGRESSION"
    print(f"bench-gate: interchange queued depth peak={depth:.0f} "
          f"floor={depth_floor:.0f} (burst={burst:.0f}) -> {status}")
    if depth < depth_floor:
        failures.append(IX_DEPTH)
    status = "ok" if ratio >= args.ix_floor else "REGRESSION"
    print(f"bench-gate: interchange relay vs flat={ratio:.2f}x "
          f"floor={args.ix_floor:.2f}x -> {status}")
    if ratio < args.ix_floor:
        failures.append(IX_RATIO)
    status = "ok" if capacity > 0 else "REGRESSION"
    print(f"bench-gate: interchange elastic scale-out capacity="
          f"{capacity:.0f} (invariant: > 0) -> {status}")
    if capacity <= 0:
        failures.append(IX_CAPACITY)

    if failures:
        print(f"bench-gate: FAILED on {', '.join(failures)}")
        return 1
    print("bench-gate: PASS")
    return 0


def main() -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--baseline", default="BENCH_10.json",
                   help="committed artifact to compare against")
    p.add_argument("--fresh", required=True,
                   help="artifact produced by this run")
    p.add_argument("--tolerance", type=float, default=0.4,
                   help="fresh throughput must be >= tolerance * baseline "
                        "(default 0.4: catches collapses, tolerates "
                        "shared-runner noise)")
    p.add_argument("--shm", action="store_true",
                   help="gate the sec7_shm same-host transport suite "
                        "instead of the result plane")
    p.add_argument("--shm-floor", type=float, default=0.4,
                   help="fresh shm/speedup_vs_tcp must be >= this "
                        "(default 0.4: catches a collapsed ring path, "
                        "tolerates smoke-scale jitter around parity)")
    p.add_argument("--executor", action="store_true",
                   help="gate the sec5_executor submit-coalescing suite "
                        "instead of the result plane")
    p.add_argument("--envelope-cap", type=float, default=0.125,
                   help="executor submit envelopes/task under storm must "
                        "be <= this (default 1/8, the ISSUE acceptance)")
    p.add_argument("--executor-floor", type=float, default=0.9,
                   help="executor storm speedup vs per-call must be >= "
                        "this (default 0.9: collapse detector; committed "
                        "artifact records the real >=1.2x margin)")
    p.add_argument("--lone-cap", type=float, default=2.0,
                   help="lone executor.submit roundtrip vs client.run "
                        "must stay < this (a linger-on-idle regression "
                        "is 3x+)")
    p.add_argument("--p2p", action="store_true",
                   help="gate the sec6_p2p peer data plane suite instead "
                        "of the result plane")
    p.add_argument("--p2p-floor", type=float, default=0.9,
                   help="fresh p2p/speedup_vs_hub must be >= this "
                        "(default 0.9: collapse detector; the committed "
                        "artifact records the real margin)")
    p.add_argument("--serving", action="store_true",
                   help="gate the sec10_serving fabric suite instead of "
                        "the result plane")
    p.add_argument("--serving-floor", type=float, default=0.5,
                   help="aware-lane warm-hit rate must be >= this "
                        "(default 0.5: even smoke-scale streams keep the "
                        "majority of requests on a warm jit cache when "
                        "routing reads the warmth keys)")
    p.add_argument("--interchange", action="store_true",
                   help="gate the sec5_interchange hierarchical relay "
                        "suite instead of the result plane")
    p.add_argument("--ix-floor", type=float, default=0.9,
                   help="steady-state relay throughput vs the flat fleet "
                        "must be >= this (default 0.9: the relay hop "
                        "queues, it must not throttle)")
    args = p.parse_args()

    if args.shm:
        return gate_shm(args)
    if args.executor:
        return gate_executor(args)
    if args.p2p:
        return gate_p2p(args)
    if args.serving:
        return gate_serving(args)
    if args.interchange:
        return gate_interchange(args)

    base = load_suite(args.baseline)
    fresh = load_suite(args.fresh)
    failures = []

    base_tp, fresh_tp = base.get(THROUGHPUT), fresh.get(THROUGHPUT)
    if base_tp is None or fresh_tp is None:
        print(f"bench-gate: {THROUGHPUT} missing "
              f"(baseline={base_tp}, fresh={fresh_tp})")
        return 2
    floor = args.tolerance * base_tp
    status = "ok" if fresh_tp >= floor else "REGRESSION"
    print(f"bench-gate: throughput fresh={fresh_tp:.0f}/s "
          f"baseline={base_tp:.0f}/s floor={floor:.0f}/s -> {status}")
    if fresh_tp < floor:
        failures.append(THROUGHPUT)

    fresh_env = fresh.get(ENVELOPES)
    if fresh_env is None:
        print(f"bench-gate: {ENVELOPES} missing from fresh artifact")
        return 2
    status = "ok" if fresh_env < 1.0 else "REGRESSION"
    print(f"bench-gate: envelopes/task fresh={fresh_env:.3f} "
          f"(invariant: < 1.0) -> {status}")
    if fresh_env >= 1.0:
        failures.append(ENVELOPES)

    if failures:
        print(f"bench-gate: FAILED on {', '.join(failures)}")
        return 1
    print("bench-gate: PASS")
    return 0


if __name__ == "__main__":
    sys.exit(main())
