"""Intra-endpoint data stores (paper §5.2).

The paper adopts (a) an in-memory KV store (Redis) and (b) the shared
filesystem, after comparing against MPI and raw sockets. We implement both
for real, plus the TPU-native *device store* (arrays stay in HBM and are
handed between functions by reference — zero host round-trip, beyond-paper).

All stores share one interface and account bytes/ops for the benchmarks.
"""
from __future__ import annotations

import hashlib
import os
import shutil
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional

from ..serialization import pack, unpack


@dataclass
class StoreStats:
    sets: int = 0
    gets: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    set_time: float = 0.0
    get_time: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        return dict(sets=self.sets, gets=self.gets, bytes_in=self.bytes_in,
                    bytes_out=self.bytes_out, set_time=self.set_time,
                    get_time=self.get_time)


class KVStore:
    """Interface. Values are arbitrary objects (serialization facade) or raw
    bytes via the *_raw variants (used by the transfer service)."""

    name = "abstract"

    def set(self, key: str, value: Any) -> None:
        self.set_raw(key, pack(value, tag=key))

    def get(self, key: str) -> Any:
        return unpack(self.get_raw(key))[0]

    def set_raw(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get_raw(self, key: str) -> bytes:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def keys(self) -> List[str]:
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        return key in self.keys()

    def mset(self, items: Dict[str, Any]) -> None:
        for k, v in items.items():
            self.set(k, v)

    def mget(self, keys: Iterable[str]) -> List[Any]:
        return [self.get(k) for k in keys]


class InMemoryKVStore(KVStore):
    """Redis analogue: lock-protected in-memory hash with optional capacity
    (LRU eviction) and TTL — the funcX endpoint's co-deployed Redis cluster."""

    name = "memory"

    def __init__(self, max_bytes: Optional[int] = None,
                 default_ttl: Optional[float] = None):
        self._data: "OrderedDict[str, Tuple[bytes, float]]" = OrderedDict()
        self._lock = threading.RLock()
        self._bytes = 0
        self.max_bytes = max_bytes
        self.default_ttl = default_ttl
        self.stats = StoreStats()

    def set_raw(self, key: str, data: bytes) -> None:
        t0 = time.perf_counter()
        with self._lock:
            if key in self._data:
                self._bytes -= len(self._data[key][0])
            expiry = (time.time() + self.default_ttl
                      if self.default_ttl else float("inf"))
            self._data[key] = (data, expiry)
            self._data.move_to_end(key)
            self._bytes += len(data)
            while self.max_bytes and self._bytes > self.max_bytes and self._data:
                _, (old, _e) = self._data.popitem(last=False)
                self._bytes -= len(old)
            # stats mutate under the same lock — concurrent setters would
            # otherwise lose read-modify-write increments
            self.stats.sets += 1
            self.stats.bytes_in += len(data)
            self.stats.set_time += time.perf_counter() - t0

    def get_raw(self, key: str) -> bytes:
        t0 = time.perf_counter()
        with self._lock:
            data, expiry = self._data[key]
            if expiry < time.time():
                del self._data[key]
                self._bytes -= len(data)
                raise KeyError(key)
            self._data.move_to_end(key)
            self.stats.gets += 1
            self.stats.bytes_out += len(data)
            self.stats.get_time += time.perf_counter() - t0
        return data

    def delete(self, key: str) -> None:
        with self._lock:
            if key in self._data:
                self._bytes -= len(self._data[key][0])
                del self._data[key]

    def keys(self) -> List[str]:
        with self._lock:
            return list(self._data.keys())

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    @property
    def nbytes(self) -> int:
        return self._bytes


class SharedFSStore(KVStore):
    """Shared-filesystem store: one file per object, atomic rename writes,
    optional fsync (shared FS semantics make durability explicit)."""

    name = "sharedfs"

    def __init__(self, root: str, fsync: bool = True):
        self.root = root
        self.fsync = fsync
        os.makedirs(root, exist_ok=True)
        self.stats = StoreStats()
        self._stats_lock = threading.Lock()

    def _path(self, key: str) -> str:
        safe = hashlib.sha1(key.encode()).hexdigest()
        return os.path.join(self.root, safe)

    def set_raw(self, key: str, data: bytes) -> None:
        t0 = time.perf_counter()
        path = self._path(key)
        tmp = path + f".tmp{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(data)
            if self.fsync:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, path)
        with self._stats_lock:
            self.stats.sets += 1
            self.stats.bytes_in += len(data)
            self.stats.set_time += time.perf_counter() - t0

    def get_raw(self, key: str) -> bytes:
        t0 = time.perf_counter()
        with open(self._path(key), "rb") as f:
            data = f.read()
        with self._stats_lock:
            self.stats.gets += 1
            self.stats.bytes_out += len(data)
            self.stats.get_time += time.perf_counter() - t0
        return data

    def delete(self, key: str) -> None:
        try:
            os.remove(self._path(key))
        except FileNotFoundError:
            pass

    def keys(self) -> List[str]:
        return os.listdir(self.root)          # hashed names

    def exists(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def clear(self) -> None:
        shutil.rmtree(self.root, ignore_errors=True)
        os.makedirs(self.root, exist_ok=True)


class DeviceStore(KVStore):
    """TPU-native object store (beyond paper): values stay as live
    ``jax.Array``s in device memory; intra-endpoint consumers receive them
    by reference — no serialize/host-copy. Falls back to object semantics
    for non-array values."""

    name = "device"

    def __init__(self):
        self._data: Dict[str, Any] = {}
        self._lock = threading.RLock()
        self.stats = StoreStats()

    def set(self, key: str, value: Any) -> None:
        with self._lock:
            self._data[key] = value
            self.stats.sets += 1

    def get(self, key: str) -> Any:
        with self._lock:
            val = self._data[key]
            self.stats.gets += 1
        return val

    def set_raw(self, key: str, data: bytes) -> None:
        self.set(key, data)

    def get_raw(self, key: str) -> bytes:
        val = self.get(key)
        if isinstance(val, bytes):
            return val
        return pack(val, tag=key)

    def delete(self, key: str) -> None:
        with self._lock:
            self._data.pop(key, None)

    def keys(self) -> List[str]:
        with self._lock:
            return list(self._data.keys())

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data


def make_store(kind: str, **kw) -> KVStore:
    if kind == "memory":
        return InMemoryKVStore(**kw)
    if kind == "sharedfs":
        return SharedFSStore(**kw)
    if kind == "device":
        return DeviceStore(**kw)
    raise ValueError(f"unknown store kind {kind!r}")
