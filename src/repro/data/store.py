"""Intra-endpoint data stores (paper §5.2).

The paper adopts (a) an in-memory KV store (Redis) and (b) the shared
filesystem, after comparing against MPI and raw sockets. We implement both
for real, plus the TPU-native *device store* (arrays stay in HBM and are
handed between functions by reference — zero host round-trip, beyond-paper).

All stores share one interface and account bytes/ops for the benchmarks.
"""
from __future__ import annotations

import hashlib
import os
import shutil
import threading
import time
from collections import OrderedDict
from dataclasses import dataclass
from typing import Any, Dict, Iterable, List, Optional

from ..serialization import pack, unpack


@dataclass
class StoreStats:
    """Op/byte counters. StoreStats itself is lock-free — every mutation
    must happen under the owning store's lock (InMemoryKVStore/DeviceStore
    reuse their data lock, SharedFSStore has a dedicated ``_stats_lock``
    because its data plane is the filesystem). Readers wanting a coherent
    view use the store's ``stats_snapshot()``, which takes the same lock;
    ``as_dict()`` alone may tear between fields mid-increment."""
    sets: int = 0
    gets: int = 0
    bytes_in: int = 0
    bytes_out: int = 0
    set_time: float = 0.0
    get_time: float = 0.0

    def as_dict(self) -> Dict[str, float]:
        return dict(sets=self.sets, gets=self.gets, bytes_in=self.bytes_in,
                    bytes_out=self.bytes_out, set_time=self.set_time,
                    get_time=self.get_time)


@dataclass(frozen=True)
class StoreInventory:
    """Cheap store summary for the heartbeat advertisement (peer data
    plane): ``version`` bumps on every mutation, so a consumer of the
    advertisement can cache derived state (the service's peer grants)
    keyed on it — warm-dict style version stamping."""
    version: int
    keys: int
    nbytes: int


class KVStore:
    """Interface. Values are arbitrary objects (serialization facade) or raw
    bytes via the *_raw variants (used by the transfer service)."""

    name = "abstract"

    def set(self, key: str, value: Any) -> None:
        self.set_raw(key, pack(value, tag=key))

    def get(self, key: str) -> Any:
        return unpack(self.get_raw(key))[0]

    def set_raw(self, key: str, data: bytes) -> None:
        raise NotImplementedError

    def get_raw(self, key: str) -> bytes:
        raise NotImplementedError

    def delete(self, key: str) -> None:
        raise NotImplementedError

    def keys(self) -> List[str]:
        raise NotImplementedError

    def exists(self, key: str) -> bool:
        return key in self.keys()

    def mset(self, items: Dict[str, Any]) -> None:
        for k, v in items.items():
            self.set(k, v)

    def mget(self, keys: Iterable[str]) -> List[Any]:
        return [self.get(k) for k in keys]

    def inventory(self) -> StoreInventory:
        """Version-stamped size summary; concrete stores override with an
        O(1) counter-based answer."""
        return StoreInventory(0, len(self.keys()), 0)

    def stats_snapshot(self) -> Dict[str, float]:
        """Coherent stats read (overridden to take the store's lock)."""
        return self.stats.as_dict()


class InMemoryKVStore(KVStore):
    """Redis analogue: lock-protected in-memory hash with optional capacity
    (LRU eviction) and TTL — the funcX endpoint's co-deployed Redis cluster."""

    name = "memory"

    def __init__(self, max_bytes: Optional[int] = None,
                 default_ttl: Optional[float] = None):
        self._data: "OrderedDict[str, Tuple[bytes, float]]" = OrderedDict()
        self._lock = threading.RLock()
        self._bytes = 0
        self._version = 0
        self.max_bytes = max_bytes
        self.default_ttl = default_ttl
        self.stats = StoreStats()

    def set_raw(self, key: str, data: bytes) -> None:
        t0 = time.perf_counter()
        with self._lock:
            if key in self._data:
                self._bytes -= len(self._data[key][0])
            expiry = (time.time() + self.default_ttl
                      if self.default_ttl else float("inf"))
            self._data[key] = (data, expiry)
            self._data.move_to_end(key)
            self._bytes += len(data)
            self._version += 1
            while self.max_bytes and self._bytes > self.max_bytes and self._data:
                _, (old, _e) = self._data.popitem(last=False)
                self._bytes -= len(old)
                self._version += 1
            # stats mutate under the same lock — concurrent setters would
            # otherwise lose read-modify-write increments
            self.stats.sets += 1
            self.stats.bytes_in += len(data)
            self.stats.set_time += time.perf_counter() - t0

    def get_raw(self, key: str) -> bytes:
        t0 = time.perf_counter()
        with self._lock:
            data, expiry = self._data[key]
            if expiry < time.time():
                del self._data[key]
                self._bytes -= len(data)
                self._version += 1
                raise KeyError(key)
            self._data.move_to_end(key)
            self.stats.gets += 1
            self.stats.bytes_out += len(data)
            self.stats.get_time += time.perf_counter() - t0
        return data

    def delete(self, key: str) -> None:
        with self._lock:
            if key in self._data:
                self._bytes -= len(self._data[key][0])
                del self._data[key]
                self._version += 1

    def keys(self) -> List[str]:
        with self._lock:
            return list(self._data.keys())

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def inventory(self) -> StoreInventory:
        with self._lock:
            return StoreInventory(self._version, len(self._data), self._bytes)

    def stats_snapshot(self) -> Dict[str, float]:
        with self._lock:
            return self.stats.as_dict()

    @property
    def nbytes(self) -> int:
        return self._bytes


class SharedFSStore(KVStore):
    """Shared-filesystem store: one file per object, atomic rename writes,
    optional fsync (shared FS semantics make durability explicit)."""

    name = "sharedfs"

    def __init__(self, root: str, fsync: bool = True):
        self.root = root
        self.fsync = fsync
        os.makedirs(root, exist_ok=True)
        self.stats = StoreStats()
        self._stats_lock = threading.Lock()
        # inventory counters: per-process approximation of the FS state
        # (other writers sharing the root aren't visible — the heartbeat
        # advertisement only needs this process's view)
        self._version = 0
        self._live_keys = 0
        self._live_bytes = 0

    def _path(self, key: str) -> str:
        safe = hashlib.sha1(key.encode()).hexdigest()
        return os.path.join(self.root, safe)

    def set_raw(self, key: str, data: bytes) -> None:
        t0 = time.perf_counter()
        path = self._path(key)
        try:
            old_size = os.path.getsize(path)
            existed = True
        except OSError:
            old_size, existed = 0, False
        tmp = path + f".tmp{threading.get_ident()}"
        with open(tmp, "wb") as f:
            f.write(data)
            if self.fsync:
                f.flush()
                os.fsync(f.fileno())
        os.replace(tmp, path)
        with self._stats_lock:
            self.stats.sets += 1
            self.stats.bytes_in += len(data)
            self.stats.set_time += time.perf_counter() - t0
            self._version += 1
            if not existed:
                self._live_keys += 1
            self._live_bytes += len(data) - old_size

    def get_raw(self, key: str) -> bytes:
        t0 = time.perf_counter()
        with open(self._path(key), "rb") as f:
            data = f.read()
        with self._stats_lock:
            self.stats.gets += 1
            self.stats.bytes_out += len(data)
            self.stats.get_time += time.perf_counter() - t0
        return data

    def delete(self, key: str) -> None:
        path = self._path(key)
        try:
            size = os.path.getsize(path)
            os.remove(path)
        except FileNotFoundError:
            return
        except OSError:
            size = 0
            try:
                os.remove(path)
            except OSError:
                return
        with self._stats_lock:
            self._version += 1
            self._live_keys = max(0, self._live_keys - 1)
            self._live_bytes = max(0, self._live_bytes - size)

    def keys(self) -> List[str]:
        return os.listdir(self.root)          # hashed names

    def exists(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def inventory(self) -> StoreInventory:
        with self._stats_lock:
            return StoreInventory(self._version, self._live_keys,
                                  self._live_bytes)

    def stats_snapshot(self) -> Dict[str, float]:
        with self._stats_lock:
            return self.stats.as_dict()

    def clear(self) -> None:
        shutil.rmtree(self.root, ignore_errors=True)
        os.makedirs(self.root, exist_ok=True)
        with self._stats_lock:
            self._version += 1
            self._live_keys = 0
            self._live_bytes = 0


class DeviceStore(KVStore):
    """TPU-native object store (beyond paper): values stay as live
    ``jax.Array``s in device memory; intra-endpoint consumers receive them
    by reference — no serialize/host-copy. Falls back to object semantics
    for non-array values."""

    name = "device"

    def __init__(self):
        self._data: Dict[str, Any] = {}
        self._lock = threading.RLock()
        self._version = 0
        self._nbytes = 0               # running estimate (heartbeats poll)
        self.stats = StoreStats()

    @staticmethod
    def _value_bytes(value: Any) -> int:
        # live arrays report device bytes; host bytes report their length;
        # anything else counts 0 rather than paying a serialization
        if isinstance(value, (bytes, bytearray, memoryview)):
            return len(value)
        return int(getattr(value, "nbytes", 0) or 0)

    def set(self, key: str, value: Any) -> None:
        t0 = time.perf_counter()
        nb = self._value_bytes(value)
        with self._lock:
            old = self._data.get(key)
            if old is not None:
                self._nbytes -= self._value_bytes(old)
            self._data[key] = value
            self._nbytes += nb
            self._version += 1
            self.stats.sets += 1
            self.stats.bytes_in += nb
            self.stats.set_time += time.perf_counter() - t0

    def get(self, key: str) -> Any:
        t0 = time.perf_counter()
        with self._lock:
            val = self._data[key]
            self.stats.gets += 1
            self.stats.bytes_out += self._value_bytes(val)
            self.stats.get_time += time.perf_counter() - t0
        return val

    # The raw variants are the wire plane (transfer service, peer data
    # plane). They used to delegate to set()/get(), which (a) double-dipped
    # the object-layer op counters with zero bytes attached, and (b) on the
    # inbound side parked the *wire frame* as the live value — a later
    # get() handed headered bytes to the consumer. Now each raw op accounts
    # exactly once with real byte totals, and set_raw decodes the frame
    # back into a live object (falling back to the raw bytes for payloads
    # that aren't pack() products).

    def set_raw(self, key: str, data: bytes) -> None:
        t0 = time.perf_counter()
        try:
            value = unpack(bytes(data))[0]
        except Exception:
            value = data
        with self._lock:
            old = self._data.get(key)
            if old is not None:
                self._nbytes -= self._value_bytes(old)
            self._data[key] = value
            self._nbytes += self._value_bytes(value)
            self._version += 1
            self.stats.sets += 1
            self.stats.bytes_in += len(data)
            self.stats.set_time += time.perf_counter() - t0

    def get_raw(self, key: str) -> bytes:
        t0 = time.perf_counter()
        with self._lock:
            val = self._data[key]
        data = val if isinstance(val, bytes) else pack(val, tag=key)
        with self._lock:
            self.stats.gets += 1
            self.stats.bytes_out += len(data)
            self.stats.get_time += time.perf_counter() - t0
        return data

    def delete(self, key: str) -> None:
        with self._lock:
            if key in self._data:
                self._nbytes -= self._value_bytes(self._data[key])
                del self._data[key]
                self._version += 1

    def keys(self) -> List[str]:
        with self._lock:
            return list(self._data.keys())

    def exists(self, key: str) -> bool:
        with self._lock:
            return key in self._data

    def inventory(self) -> StoreInventory:
        with self._lock:
            return StoreInventory(self._version, len(self._data),
                                  max(0, self._nbytes))

    def stats_snapshot(self) -> Dict[str, float]:
        with self._lock:
            return self.stats.as_dict()


def make_store(kind: str, **kw) -> KVStore:
    if kind == "memory":
        return InMemoryKVStore(**kw)
    if kind == "sharedfs":
        return SharedFSStore(**kw)
    if kind == "device":
        return DeviceStore(**kw)
    raise ValueError(f"unknown store kind {kind!r}")
