"""Inter-endpoint data transfer (paper §5.1 — the Globus tier).

funcX limits payloads through the service to 10 MB and moves anything larger
out-of-band via Globus between *storage endpoints*. Here each funcX endpoint
owns a store; the TransferService moves objects between stores in chunks on
background threads, with CRC integrity, retry, optional simulated WAN
bandwidth (for benchmarks), and async status polling — the GridFTP shape
without the wire. On a real TPU fleet the equivalent fabric is DCN
``jax.device_put`` between pod meshes; the control plane here is identical.
"""
from __future__ import annotations

import threading
import time
import uuid
import zlib
from dataclasses import dataclass, field
from enum import Enum
from typing import Dict, Optional

from .store import KVStore


class TransferStatus(Enum):
    ACTIVE = "ACTIVE"
    SUCCEEDED = "SUCCEEDED"
    FAILED = "FAILED"


@dataclass
class TransferRecord:
    transfer_id: str
    src_endpoint: str
    src_key: str
    dst_endpoint: str
    dst_key: str
    status: TransferStatus = TransferStatus.ACTIVE
    bytes_total: int = 0
    bytes_done: int = 0
    checksum_ok: Optional[bool] = None
    error: Optional[str] = None
    t_start: float = field(default_factory=time.perf_counter)
    t_end: Optional[float] = None

    @property
    def duration(self) -> float:
        end = self.t_end if self.t_end is not None else time.perf_counter()
        return end - self.t_start


@dataclass(frozen=True)
class DataRef:
    """Reference passed in place of large values (like a Globus path).

    scheme "kv"     — intra-endpoint store key
    scheme "globus" — (endpoint_id, key) pair resolvable via TransferService
                      or, since the peer data plane (DESIGN.md §9), by
                      dialing the producing endpoint's PeerServer directly

    ``location`` is the producer's peer listen address at staging time —
    a *hint* only (the service's ResolvePeer answer is authoritative and
    survives the producer re-registering on a new port); empty on refs
    minted before the peer plane, which keeps old pickles decodable.
    """
    scheme: str
    endpoint: str
    key: str
    location: str = ""

    def uri(self) -> str:
        return f"{self.scheme}://{self.endpoint}/{self.key}"

    @staticmethod
    def parse(uri: str) -> "DataRef":
        scheme, rest = uri.split("://", 1)
        endpoint, key = rest.split("/", 1)
        return DataRef(scheme, endpoint, key)


class TransferService:
    """Registry of endpoint stores + chunked async transfers."""

    def __init__(self, chunk_bytes: int = 4 << 20,
                 bandwidth_bps: Optional[float] = None,
                 max_retries: int = 2):
        self._stores: Dict[str, KVStore] = {}
        self._records: Dict[str, TransferRecord] = {}
        self._lock = threading.RLock()
        self.chunk_bytes = chunk_bytes
        self.bandwidth_bps = bandwidth_bps    # simulated WAN cap (None = off)
        self.max_retries = max_retries

    # -- endpoint registration (Globus Connect analogue) --------------------
    def register_endpoint(self, endpoint_id: str, store: KVStore) -> None:
        with self._lock:
            self._stores[endpoint_id] = store

    def store_for(self, endpoint_id: str) -> KVStore:
        return self._stores[endpoint_id]

    # -- transfers -----------------------------------------------------------
    def submit(self, src_endpoint: str, src_key: str, dst_endpoint: str,
               dst_key: Optional[str] = None, sync: bool = False) -> str:
        dst_key = dst_key or src_key
        rec = TransferRecord(str(uuid.uuid4()), src_endpoint, src_key,
                             dst_endpoint, dst_key)
        with self._lock:
            self._records[rec.transfer_id] = rec
        if sync:
            self._run(rec)
        else:
            t = threading.Thread(target=self._run, args=(rec,), daemon=True)
            t.start()
        return rec.transfer_id

    def _run(self, rec: TransferRecord) -> None:
        for attempt in range(self.max_retries + 1):
            try:
                src = self._stores[rec.src_endpoint]
                dst = self._stores[rec.dst_endpoint]
                data = src.get_raw(rec.src_key)
                rec.bytes_total = len(data)
                crc = zlib.crc32(data)
                # chunked move (GridFTP-style striping degenerates to
                # sequential chunks on one host; bandwidth cap emulates WAN)
                out = bytearray()
                for off in range(0, len(data), self.chunk_bytes):
                    chunk = data[off:off + self.chunk_bytes]
                    if self.bandwidth_bps:
                        time.sleep(len(chunk) / self.bandwidth_bps)
                    out.extend(chunk)
                    rec.bytes_done = off + len(chunk)
                ok = zlib.crc32(bytes(out)) == crc
                rec.checksum_ok = ok
                if not ok:
                    raise IOError("checksum mismatch")
                dst.set_raw(rec.dst_key, bytes(out))
                rec.status = TransferStatus.SUCCEEDED
                rec.t_end = time.perf_counter()
                return
            except Exception as e:      # noqa: BLE001 — record & retry
                rec.error = f"{type(e).__name__}: {e}"
        rec.status = TransferStatus.FAILED
        rec.t_end = time.perf_counter()

    def status(self, transfer_id: str) -> TransferRecord:
        with self._lock:
            return self._records[transfer_id]

    def wait(self, transfer_id: str, timeout: float = 30.0) -> TransferRecord:
        deadline = time.time() + timeout
        while time.time() < deadline:
            rec = self.status(transfer_id)
            if rec.status != TransferStatus.ACTIVE:
                return rec
            time.sleep(0.001)
        raise TimeoutError(f"transfer {transfer_id} still active")
