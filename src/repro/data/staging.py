"""Automatic data staging (paper §5): inputs that are DataRefs are resolved
before function execution (intra-endpoint: local store; inter-endpoint:
TransferService pull), and outputs larger than the service payload limit
(10 MB in the paper) are written to the endpoint store and replaced by refs.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from typing import Any, Optional

from ..serialization import PackedBuffer, pack_buffer
from .store import KVStore
from .transfer import DataRef, TransferService, TransferStatus

SERVICE_PAYLOAD_LIMIT = 10 * 1024 * 1024      # paper §5.1


def _map_structure(obj: Any, fn) -> Any:
    if isinstance(obj, DataRef):
        return fn(obj)
    if isinstance(obj, dict):
        return {k: _map_structure(v, fn) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        t = type(obj)
        return t(_map_structure(v, fn) for v in obj)
    return obj


def resolve_inputs(obj: Any, endpoint_id: str, store: KVStore,
                   transfer: Optional[TransferService] = None,
                   peer: Optional[Any] = None) -> Any:
    """Replace every DataRef in ``obj`` with its value (stage-in).

    Cross-endpoint refs walk the fallback ladder (DESIGN.md §9): local
    store hit (a previous fetch cached it, or the producer is this
    endpoint) → same-process store registry via ``transfer`` (the
    shm-adjacent rung: zero wire) → the peer data plane (``peer``, a
    :class:`~repro.core.peer.PeerClient`), which itself tries direct TCP
    to the producer and falls back to a hub relay through the service.
    Fetched bytes are cached into the local store under the ref's key, so
    N tasks consuming one ref pay one wire crossing.

    Batched stage-in: a task consuming many cross-endpoint refs (a
    shuffle's gather) groups them by producer — each producer's batch
    rides one pipelined request train on its cached connection, and
    distinct producers drain concurrently, so the task pays one
    round-trip's latency per producer instead of one per ref.
    """
    _MISS = object()

    def upper(ref: DataRef):
        """Rungs 0+1 (local store, same-process registry); _MISS means
        the peer plane is the next move."""
        if store.exists(ref.key):
            return store.get(ref.key)
        if ref.endpoint == endpoint_id:
            raise KeyError(
                f"{ref.uri()} names this endpoint but the key is gone "
                f"(evicted?)")
        if transfer is not None:
            try:
                tid = transfer.submit(ref.endpoint, ref.key, endpoint_id,
                                      sync=True)
                rec = transfer.status(tid)
            except KeyError:
                rec = None                  # producer store not registered
            if rec is not None and rec.status == TransferStatus.SUCCEEDED:
                return store.get(ref.key)
            if rec is not None and peer is None:
                raise IOError(
                    f"stage-in failed for {ref.uri()}: {rec.error}")
        return _MISS

    def cache(ref: DataRef, raw: bytes):
        # cache-then-read: set_raw/get round-trips on every store
        # (DeviceStore decodes the frame back to a live object)
        store.set_raw(ref.key, raw)
        return store.get(ref.key)

    def fetch(ref: DataRef):
        val = upper(ref)
        if val is not _MISS:
            return val
        # rungs 2+3: peer data plane (direct TCP, then hub relay)
        if peer is not None:
            return cache(ref, peer.fetch_raw(ref))
        raise KeyError(
            f"cannot resolve {ref.uri()}: no transfer service or peer "
            f"client on endpoint {endpoint_id}")

    refs: list = []
    seen = set()

    def collect(ref: DataRef):
        if (ref.endpoint, ref.key) not in seen:
            seen.add((ref.endpoint, ref.key))
            refs.append(ref)
        return ref

    _map_structure(obj, collect)
    remote = [r for r in refs
              if r.endpoint != endpoint_id and not store.exists(r.key)]
    if len(remote) > 1 and peer is not None \
            and hasattr(peer, "fetch_raw_many"):
        def drain(batch):
            out = {}
            misses = []
            for r in batch:
                val = upper(r)
                if val is _MISS:
                    misses.append(r)
                else:
                    out[(r.endpoint, r.key)] = val
            if misses:
                for r, raw in zip(misses, peer.fetch_raw_many(misses)):
                    out[(r.endpoint, r.key)] = cache(r, raw)
            return out

        by_prod: dict = {}
        for r in remote:
            by_prod.setdefault(r.endpoint, []).append(r)
        fetched: dict = {}
        if len(by_prod) == 1:
            fetched = drain(remote)
        else:
            with ThreadPoolExecutor(max_workers=len(by_prod)) as pool:
                for part in pool.map(drain, by_prod.values()):
                    fetched.update(part)
        return _map_structure(
            obj, lambda r: fetched[(r.endpoint, r.key)]
            if (r.endpoint, r.key) in fetched else fetch(r))
    return _map_structure(obj, fetch)


def stage_outputs(result: Any, endpoint_id: str, store: KVStore,
                  key_prefix: str,
                  limit: int = SERVICE_PAYLOAD_LIMIT,
                  packed: Optional[PackedBuffer] = None,
                  location: str = "") -> Any:
    """If the serialized result exceeds the service limit, park it in the
    endpoint store and return a DataRef instead (stage-out).

    ``packed`` is the pack-once fast path: when the caller already holds
    the result's wire buffer (the endpoint packs every result exactly once
    before shipping it), its length decides the threshold and its *bytes*
    are what lands in the store — no second serialization either way."""
    if packed is None:
        try:
            packed = pack_buffer(result, tag=f"{key_prefix}/result")
        except Exception:
            packed = None
    if packed is not None and len(packed) <= limit:
        return result
    key = f"{key_prefix}/result"
    # The raw-bytes write is only valid for stores whose ``get`` decodes
    # what ``set_raw`` wrote (the KVStore base behaviour). DeviceStore
    # overrides ``get`` with live-object semantics — handing it wire bytes
    # would surface headered bytes to the consumer AND forfeit its
    # keep-arrays-on-device purpose, so it takes the object path.
    if packed is not None and type(store).get is KVStore.get:
        store.set_raw(key, packed.data)      # same bytes, no re-pack
    else:
        store.set(key, result)
    return DataRef("globus", endpoint_id, key, location)
