"""Automatic data staging (paper §5): inputs that are DataRefs are resolved
before function execution (intra-endpoint: local store; inter-endpoint:
TransferService pull), and outputs larger than the service payload limit
(10 MB in the paper) are written to the endpoint store and replaced by refs.
"""
from __future__ import annotations

from typing import Any, Optional

from ..serialization import PackedBuffer, pack_buffer
from .store import KVStore
from .transfer import DataRef, TransferService, TransferStatus

SERVICE_PAYLOAD_LIMIT = 10 * 1024 * 1024      # paper §5.1


def _map_structure(obj: Any, fn) -> Any:
    if isinstance(obj, DataRef):
        return fn(obj)
    if isinstance(obj, dict):
        return {k: _map_structure(v, fn) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        t = type(obj)
        return t(_map_structure(v, fn) for v in obj)
    return obj


def resolve_inputs(obj: Any, endpoint_id: str, store: KVStore,
                   transfer: Optional[TransferService] = None) -> Any:
    """Replace every DataRef in ``obj`` with its value (stage-in)."""

    def fetch(ref: DataRef):
        # intra-endpoint: straight from the local store
        if ref.endpoint == endpoint_id and store.exists(ref.key):
            return store.get(ref.key)
        # inter-endpoint: Globus-style pull, then read locally
        if transfer is None:
            raise KeyError(f"cannot resolve {ref.uri()} without transfer service")
        tid = transfer.submit(ref.endpoint, ref.key, endpoint_id, sync=True)
        rec = transfer.status(tid)
        if rec.status != TransferStatus.SUCCEEDED:
            raise IOError(f"stage-in failed for {ref.uri()}: {rec.error}")
        return store.get(ref.key)

    return _map_structure(obj, fetch)


def stage_outputs(result: Any, endpoint_id: str, store: KVStore,
                  key_prefix: str,
                  limit: int = SERVICE_PAYLOAD_LIMIT,
                  packed: Optional[PackedBuffer] = None) -> Any:
    """If the serialized result exceeds the service limit, park it in the
    endpoint store and return a DataRef instead (stage-out).

    ``packed`` is the pack-once fast path: when the caller already holds
    the result's wire buffer (the endpoint packs every result exactly once
    before shipping it), its length decides the threshold and its *bytes*
    are what lands in the store — no second serialization either way."""
    if packed is None:
        try:
            packed = pack_buffer(result, tag=f"{key_prefix}/result")
        except Exception:
            packed = None
    if packed is not None and len(packed) <= limit:
        return result
    key = f"{key_prefix}/result"
    # The raw-bytes write is only valid for stores whose ``get`` decodes
    # what ``set_raw`` wrote (the KVStore base behaviour). DeviceStore
    # overrides ``get`` with live-object semantics — handing it wire bytes
    # would surface headered bytes to the consumer AND forfeit its
    # keep-arrays-on-device purpose, so it takes the object path.
    if packed is not None and type(store).get is KVStore.get:
        store.set_raw(key, packed.data)      # same bytes, no re-pack
    else:
        store.set(key, result)
    return DataRef("globus", endpoint_id, key)
