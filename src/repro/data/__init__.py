from .staging import SERVICE_PAYLOAD_LIMIT, resolve_inputs, stage_outputs
from .store import (
    DeviceStore,
    InMemoryKVStore,
    KVStore,
    SharedFSStore,
    StoreInventory,
    StoreStats,
    make_store,
)
from .transfer import DataRef, TransferRecord, TransferService, TransferStatus

__all__ = [
    "DataRef", "DeviceStore", "InMemoryKVStore", "KVStore",
    "SERVICE_PAYLOAD_LIMIT", "SharedFSStore", "StoreInventory", "StoreStats",
    "TransferRecord", "TransferService", "TransferStatus", "make_store",
    "resolve_inputs", "stage_outputs",
]
