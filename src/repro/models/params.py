"""Parameter-spec system.

Every model declares its parameters once, as a nested dict of :class:`ParamSpec`
(shape + logical axes + initializer). From that single declaration we derive:

- ``init_params``     — materialized arrays (seeded per path)
- ``logical_axes``    — same-structure pytree of logical-axis tuples, consumed
                        by ``repro.sharding.rules`` to build NamedShardings
- ``abstract_params`` — ShapeDtypeStructs for dry-run lowering (no allocation)
- ``count_params``    — exact parameter counts (used for roofline 6·N·D)

Stacked (scanned) layers are expressed by :func:`stack` which prepends a
``"layers"`` axis (never sharded).
"""
from __future__ import annotations

import math
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


class ParamSpec(NamedTuple):
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    init: str = "normal"        # "normal" | "zeros" | "ones" | "scaled_normal"
    scale: float = 0.02

    def stacked(self, n: int) -> "ParamSpec":
        return ParamSpec((n,) + self.shape, ("layers",) + self.axes, self.init, self.scale)


def stack(spec_tree: Any, n: int) -> Any:
    """Prepend a scan ('layers') dimension to every spec in the tree."""
    return jax.tree.map(lambda s: s.stacked(n), spec_tree,
                        is_leaf=lambda x: isinstance(x, ParamSpec))


def _is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def _init_leaf(spec: ParamSpec, key: jax.Array, dtype) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, dtype)
    if spec.init == "const":
        return jnp.full(spec.shape, spec.scale, dtype)
    if spec.init == "normal":
        return (jax.random.normal(key, spec.shape, jnp.float32) * spec.scale).astype(dtype)
    if spec.init == "scaled_normal":
        # fan-in scaled (truncated-normal-free variant; keeps init fast)
        fan_in = spec.shape[-2] if len(spec.shape) >= 2 else spec.shape[-1]
        std = 1.0 / math.sqrt(max(fan_in, 1))
        return (jax.random.normal(key, spec.shape, jnp.float32) * std).astype(dtype)
    raise ValueError(f"unknown init {spec.init!r}")


def init_params(spec_tree: Any, key: jax.Array, dtype=jnp.float32) -> Any:
    """Materialize parameters; each leaf is seeded by folding in its path."""
    leaves_with_paths = jax.tree_util.tree_flatten_with_path(
        spec_tree, is_leaf=_is_spec)[0]
    treedef = jax.tree_util.tree_structure(spec_tree, is_leaf=_is_spec)
    arrays = []
    for path, spec in leaves_with_paths:
        path_str = jax.tree_util.keystr(path)
        leaf_key = jax.random.fold_in(key, hash(path_str) % (2**31 - 1))
        arrays.append(_init_leaf(spec, leaf_key, dtype))
    return jax.tree_util.tree_unflatten(treedef, arrays)


def logical_axes(spec_tree: Any) -> Any:
    return jax.tree.map(lambda s: s.axes, spec_tree, is_leaf=_is_spec)


def abstract_params(spec_tree: Any, dtype=jnp.float32) -> Any:
    return jax.tree.map(lambda s: jax.ShapeDtypeStruct(s.shape, dtype),
                        spec_tree, is_leaf=_is_spec)


def count_params(spec_tree: Any) -> int:
    return sum(int(np.prod(s.shape))
               for s in jax.tree.leaves(spec_tree, is_leaf=_is_spec))


def scan_or_loop(body: Callable, carry: Any, xs: Any, *, scan: bool,
                 length: int):
    """``lax.scan(body, carry, xs)`` or an unrolled python loop with
    identical semantics (used by the roofline analysis lowerings — XLA's
    cost_analysis counts while bodies once, so unrolled variants give exact
    per-layer costs)."""
    if scan:
        return jax.lax.scan(body, carry, xs)
    ys = []
    for i in range(length):
        xi = jax.tree.map(lambda a: a[i], xs)
        carry, y = body(carry, xi)
        ys.append(y)
    if ys:
        stacked = jax.tree.map(lambda *z: jnp.stack(z), *ys)
    else:
        stacked = None
    return carry, stacked


def cast_floats(tree: Any, dtype) -> Any:
    """Cast float leaves (mixed precision: bf16 compute / f32 master)."""
    def c(a):
        if hasattr(a, "dtype") and jnp.issubdtype(a.dtype, jnp.floating):
            return a.astype(dtype)
        return a
    return jax.tree.map(c, tree)


def tree_paths(spec_tree: Any) -> Dict[str, ParamSpec]:
    out = {}
    for path, spec in jax.tree_util.tree_flatten_with_path(spec_tree, is_leaf=_is_spec)[0]:
        out[jax.tree_util.keystr(path)] = spec
    return out
