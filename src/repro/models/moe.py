"""Mixture-of-experts layer.

Two execution paths sharing one core algorithm (scatter/gather token
dispatch with per-rank capacity — no giant one-hot dispatch einsums):

- **EP path** (production): wrapped in ``shard_map``; experts are sharded
  over the ``model`` mesh axis (expert parallelism), tokens are replicated
  over ``model`` and sharded over batch axes. Each rank dispatches only to
  its local experts and the partial outputs are ``psum``-combined — the
  TPU-idiomatic equivalent of the all-to-all in GPU MoE systems.
- **Local path** (single device / smoke tests): identical math with
  ``E_local == E`` and no collectives.

Returns the layer output plus the Switch-style load-balancing auxiliary loss.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P
try:                                     # jax >= 0.6 public API
    from jax import shard_map
except ImportError:                      # older jax: experimental module,
    from jax.experimental.shard_map import (  # check_vma spelled check_rep
        shard_map as _exp_shard_map,
    )

    def shard_map(f, *, mesh, in_specs, out_specs, check_vma=True, **kw):
        return _exp_shard_map(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_rep=check_vma, **kw)

from ..configs import ModelConfig
from ..sharding.rules import ShardCtx, spec_for
from .params import ParamSpec


def moe_spec(cfg: ModelConfig) -> dict:
    m = cfg.moe
    d, e, f = cfg.d_model, m.n_experts, m.d_ff_expert
    return {
        "router": ParamSpec((d, e), ("embed", None), "scaled_normal"),
        "w_gate": ParamSpec((e, d, f), ("experts", "embed", "ffn"), "scaled_normal"),
        "w_up": ParamSpec((e, d, f), ("experts", "embed", "ffn"), "scaled_normal"),
        "w_down": ParamSpec((e, f, d), ("experts", "ffn", "embed"), "scaled_normal"),
    }


def _capacity(n_tokens: int, top_k: int, n_experts: int, factor: float) -> int:
    c = int(factor * n_tokens * top_k / n_experts) + 1
    return max(c, top_k)


def _moe_core(
    xf: jax.Array,               # (T, d) local tokens
    router_w: jax.Array,         # (d, E)
    w_gate: jax.Array,           # (E_loc, d, f)
    w_up: jax.Array,
    w_down: jax.Array,           # (E_loc, f, d)
    *,
    cfg: ModelConfig,
    e_first: jax.Array,          # scalar: first local expert id
    psum: Optional[Callable],    # combine fn over the expert axis, or None
    pmean_tokens: Optional[Callable],  # mean over batch shards for aux loss
) -> Tuple[jax.Array, jax.Array]:
    m = cfg.moe
    T, d = xf.shape
    E, k = m.n_experts, m.top_k
    E_loc = w_gate.shape[0]
    C = _capacity(T, k, E, m.capacity_factor)

    gates = jax.nn.softmax(
        jnp.einsum("td,de->te", xf, router_w,
                   preferred_element_type=jnp.float32), axis=-1)  # (T, E)
    top_w, top_i = lax.top_k(gates, k)                             # (T, k)
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- dispatch to local experts (scatter into capacity buffer) --------
    flat_i = top_i.reshape(-1)                                     # (T*k,)
    local_e = flat_i - e_first
    valid = (local_e >= 0) & (local_e < E_loc)
    safe_e = jnp.where(valid, local_e, 0)
    one_hot = jax.nn.one_hot(jnp.where(valid, local_e, E_loc),
                             E_loc + 1, dtype=jnp.int32)           # (T*k, E_loc+1)
    slot = (jnp.cumsum(one_hot, axis=0) - 1)[jnp.arange(T * k), safe_e]
    keep = valid & (slot < C)
    tok = jnp.arange(T * k) // k
    scat_e = jnp.where(keep, safe_e, E_loc)                        # OOB -> drop
    scat_s = jnp.where(keep, slot, 0)
    buf = jnp.zeros((E_loc, C, d), xf.dtype)
    buf = buf.at[scat_e, scat_s].add(xf[tok], mode="drop")

    # ---- expert FFN (SwiGLU) ---------------------------------------------
    g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
    u = jnp.einsum("ecd,edf->ecf", buf, w_up)
    out_buf = jnp.einsum("ecf,efd->ecd", jax.nn.silu(g) * u, w_down)

    # ---- combine (gather + weighted sum over the k copies) ---------------
    y_copies = out_buf[scat_e.clip(0, E_loc - 1), scat_s]          # (T*k, d)
    w_copies = jnp.where(keep, top_w.reshape(-1), 0.0)
    y = (y_copies * w_copies[:, None].astype(y_copies.dtype)
         ).reshape(T, k, d).sum(axis=1)
    y = y.astype(xf.dtype)      # combine on the wire in bf16, not f32
    if psum is not None:
        y = psum(y)

    # ---- load-balance aux loss (Switch): E * sum_e f_e * p_e --------------
    assign = jax.nn.one_hot(top_i[:, 0], E, dtype=jnp.float32)     # top-1 assign
    f_e = assign.mean(axis=0)
    p_e = gates.mean(axis=0)
    aux = E * jnp.sum(f_e * p_e)
    if pmean_tokens is not None:
        aux = pmean_tokens(aux)
    return y.astype(xf.dtype), aux


def moe_block(
    x: jax.Array,                # (B, S, d)
    p: dict,                     # moe params
    cfg: ModelConfig,
    ctx: ShardCtx,
) -> Tuple[jax.Array, jax.Array]:
    """Apply the MoE FFN. Chooses EP (shard_map) vs local path from ctx."""
    B, S, d = x.shape
    m = cfg.moe
    E = m.n_experts

    if not ctx.active or "model" not in ctx.mesh.axis_names:
        xf = x.reshape(B * S, d)
        y, aux = _moe_core(
            xf, p["router"], p["w_gate"], p["w_up"], p["w_down"],
            cfg=cfg, e_first=jnp.int32(0), psum=None, pmean_tokens=None)
        return y.reshape(B, S, d), aux

    mesh = ctx.mesh
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    n_model = sizes["model"]
    if E % n_model != 0:
        # experts don't divide the model axis: fall back to replicated experts
        xf = x.reshape(B * S, d)
        y, aux = _moe_core(
            xf, p["router"], p["w_gate"], p["w_up"], p["w_down"],
            cfg=cfg, e_first=jnp.int32(0), psum=None, pmean_tokens=None)
        return y.reshape(B, S, d), aux

    E_loc = E // n_model
    batch_axes = tuple(a for a in ("pod", "data") if a in sizes)
    x_spec = spec_for(("act_batch", None, None), x.shape, mesh, ctx.rules)

    def inner(x_l, router_w, w_gate, w_up, w_down):
        Bl, Sl, _ = x_l.shape
        xf = x_l.reshape(Bl * Sl, d)
        e_first = lax.axis_index("model") * E_loc
        psum = lambda y: lax.psum(y, "model")
        pmean = (lambda a: lax.pmean(a, batch_axes)) if batch_axes else None
        y, aux = _moe_core(xf, router_w, w_gate, w_up, w_down,
                           cfg=cfg, e_first=e_first, psum=psum,
                           pmean_tokens=pmean)
        return y.reshape(Bl, Sl, d), aux

    y, aux = shard_map(
        inner, mesh=mesh,
        in_specs=(x_spec, P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(x_spec, P()),
        check_vma=False,
    )(x, p["router"], p["w_gate"], p["w_up"], p["w_down"])
    return y, aux
