"""Decoder-only LM stack covering the dense, MoE, MLA, and VLM assigned
architectures. One scan-over-layers implementation; per-arch behaviour is
driven entirely by ``ModelConfig``.

Shapes legend: B batch, S sequence, d d_model, H heads, KVH kv heads,
hd head dim, V (padded) vocab, L layers, P vision-prefix length.
"""
from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs import ModelConfig
from ..sharding.rules import ShardCtx
from . import attention as attn
from .common import (
    chunked_cross_entropy,
    cross_entropy,
    embed_tokens,
    lm_logits,
    rms_norm,
    swiglu,
)
from .knobs import DEFAULT_KNOBS, RunKnobs
from .moe import moe_block, moe_spec
from .params import ParamSpec, scan_or_loop, stack

VISION_GRID_W = 32


# ---------------------------------------------------------------------------
# Spec
# ---------------------------------------------------------------------------

def ffn_spec(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("embed", "ffn"), "scaled_normal"),
        "w_up": ParamSpec((d, f), ("embed", "ffn"), "scaled_normal"),
        "w_down": ParamSpec((f, d), ("ffn", "embed"), "scaled_normal"),
    }


def block_spec(cfg: ModelConfig) -> dict:
    spec = {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
        "attn": attn.attn_spec(cfg),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
    }
    if cfg.moe is not None:
        spec["moe"] = moe_spec(cfg)
    else:
        spec["ffn"] = ffn_spec(cfg)
    return spec


def model_spec(cfg: ModelConfig) -> dict:
    v = cfg.padded_vocab()
    spec = {
        "embed": {"tok": ParamSpec((v, cfg.d_model), ("vocab", "embed"),
                                   "normal", 0.02)},
        "blocks": stack(block_spec(cfg), cfg.n_layers),
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
    }
    if not cfg.tie_embeddings:
        spec["lm_head"] = ParamSpec((cfg.d_model, v), ("embed", "vocab"),
                                    "scaled_normal")
    return spec


def _head(cfg: ModelConfig, params: dict) -> jax.Array:
    if cfg.tie_embeddings:
        return params["embed"]["tok"].T
    return params["lm_head"]


# ---------------------------------------------------------------------------
# Positions
# ---------------------------------------------------------------------------

def build_positions(cfg: ModelConfig, B: int, S: int,
                    prefix: int = 0) -> jax.Array:
    """(B, S) standard positions, or (3, B, S) M-RoPE positions where the
    first ``prefix`` slots are vision patches laid out on a 2-D grid."""
    s = jnp.arange(S, dtype=jnp.int32)
    if cfg.vlm is None:
        return jnp.broadcast_to(s[None], (B, S))
    is_vis = s < prefix
    t = jnp.where(is_vis, 0, s)
    h = jnp.where(is_vis, s // VISION_GRID_W, s)
    w = jnp.where(is_vis, s % VISION_GRID_W, s)
    pos = jnp.stack([t, h, w])                           # (3, S)
    return jnp.broadcast_to(pos[:, None], (3, B, S))


# ---------------------------------------------------------------------------
# Forward (train / prefill)
# ---------------------------------------------------------------------------

def _remat(f, mode: str):
    if mode == "none":
        return f
    if mode == "dots":
        return jax.checkpoint(
            f, policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
            prevent_cse=False)
    return jax.checkpoint(f, prevent_cse=False)


def _embed_inputs(cfg: ModelConfig, params: dict, batch: Dict, dtype):
    """Token (+ optional stub-frontend) embeddings. Returns (x, prefix_len)."""
    x = embed_tokens(params["embed"]["tok"], batch["tokens"], dtype)
    prefix = 0
    if cfg.vlm is not None and "patches" in batch:
        patches = batch["patches"].astype(dtype)        # (B, P, d) stub
        x = jnp.concatenate([patches, x], axis=1)
        prefix = patches.shape[1]
    return x, prefix


def forward_hidden(
    cfg: ModelConfig,
    params: dict,
    x: jax.Array,                  # (B, S, d) embedded inputs
    positions: jax.Array,
    ctx: ShardCtx,
    knobs: RunKnobs,
    *,
    collect_kv: bool = False,
    remat: Optional[str] = None,
) -> Tuple[jax.Array, jax.Array, Optional[Tuple]]:
    """Run the block stack. Returns (hidden, moe_aux_mean, kv_per_layer)."""
    remat = knobs.remat if remat is None else remat

    def body(x, lp):
        x = ctx.constrain(x, ("act_batch", "act_seq", "act_embed"))
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if collect_kv:
            a, kv = attn.attn_full(cfg, lp["attn"], h, positions, ctx, knobs,
                                   return_kv=True)
        else:
            a = attn.attn_full(cfg, lp["attn"], h, positions, ctx, knobs)
            kv = None
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            f, aux = moe_block(h, lp["moe"], cfg, ctx)
        else:
            f = swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                       lp["ffn"]["w_down"])
            aux = jnp.float32(0.0)
        x = x + f
        ys = (aux, kv) if collect_kv else (aux, None)
        return x, ys

    scan_body = _remat(body, remat) if not collect_kv else body
    x, (aux, kv) = scan_or_loop(scan_body, x, params["blocks"],
                                scan=knobs.scan_layers, length=cfg.n_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x, aux.mean(), kv


def loss_fn(
    cfg: ModelConfig,
    params: dict,
    batch: Dict,
    ctx: ShardCtx = ShardCtx(),
    knobs: RunKnobs = DEFAULT_KNOBS,
    z_loss: float = 0.0,
) -> Tuple[jax.Array, Dict]:
    dtype = jnp.dtype(cfg.dtype)
    x, prefix = _embed_inputs(cfg, params, batch, dtype)
    B, S = x.shape[:2]
    positions = build_positions(cfg, B, S, prefix)
    hidden, aux, _ = forward_hidden(cfg, params, x, positions, ctx, knobs)
    if prefix:
        hidden = hidden[:, prefix:]
    head = _head(cfg, params)
    labels = batch["labels"]
    mask = batch.get("mask")
    if knobs.chunked_loss:
        ce = chunked_cross_entropy(hidden, head, labels, cfg.vocab_size,
                                   mask, z_loss, knobs.loss_chunk,
                                   unroll=not knobs.scan_layers)
    else:
        logits = lm_logits(hidden, head, cfg.vocab_size)
        ce = cross_entropy(logits, labels, mask, z_loss)
    aux_w = cfg.moe.aux_loss_weight if cfg.moe is not None else 0.0
    loss = ce + aux_w * aux
    return loss, {"ce": ce, "moe_aux": aux}


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    per_layer = attn.attn_cache_init(cfg, batch, max_seq, dtype)
    stacked = jax.tree.map(
        lambda x: jnp.zeros((cfg.n_layers,) + x.shape, x.dtype), per_layer)
    return {"layers": stacked,
            "pos": jnp.zeros((), jnp.int32),
            "lengths": jnp.zeros((batch,), jnp.int32)}


def cache_axes(cfg: ModelConfig) -> dict:
    layer = attn.attn_cache_axes(cfg)
    return {"layers": jax.tree.map(lambda a: ("layers",) + a, layer,
                                   is_leaf=lambda x: isinstance(x, tuple)),
            "pos": (),
            "lengths": ("cache_batch",)}


def prefill(
    cfg: ModelConfig,
    params: dict,
    batch: Dict,
    ctx: ShardCtx = ShardCtx(),
    knobs: RunKnobs = DEFAULT_KNOBS,
    cache_len: Optional[int] = None,
) -> Tuple[jax.Array, dict]:
    """Full-sequence forward; returns (last-token logits, populated cache)."""
    dtype = jnp.dtype(cfg.dtype)
    x, prefix = _embed_inputs(cfg, params, batch, dtype)
    B, S = x.shape[:2]
    positions = build_positions(cfg, B, S, prefix)
    hidden, _, kv = forward_hidden(cfg, params, x, positions, ctx, knobs,
                                   collect_kv=True, remat="none")
    logits = lm_logits(hidden[:, -1:], _head(cfg, params), cfg.vocab_size)
    max_seq = cache_len or S
    layers = jax.vmap(lambda kv_l: attn.attn_cache_from_prefill(
        cfg, kv_l, max_seq))(kv)
    cache = {"layers": layers,
             "pos": jnp.int32(S),
             "lengths": jnp.full((B,), S, jnp.int32)}
    return logits[:, 0], cache


def decode_step(
    cfg: ModelConfig,
    params: dict,
    cache: dict,
    batch: Dict,
    ctx: ShardCtx = ShardCtx(),
    knobs: RunKnobs = DEFAULT_KNOBS,
) -> Tuple[jax.Array, dict]:
    """One token for every sequence. batch = {"tokens": (B, 1)}."""
    dtype = jnp.dtype(cfg.dtype)
    x = embed_tokens(params["embed"]["tok"], batch["tokens"], dtype)  # (B,1,d)
    pos, lengths = cache["pos"], cache["lengths"] + 1
    window = (cfg.recurrent.attention_window
              if (cfg.attention_kind == "local" and cfg.recurrent) else None)

    def body(x, xs):
        lp, cache_l = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, new_cache_l = attn.attn_decode(cfg, lp["attn"], h, cache_l, pos,
                                          lengths, ctx, window=window)
        x = x + a
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        if cfg.moe is not None:
            f, _ = moe_block(h, lp["moe"], cfg, ctx)
        else:
            f = swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                       lp["ffn"]["w_down"])
        x = x + f
        return x, new_cache_l

    x, new_layers = scan_or_loop(body, x, (params["blocks"], cache["layers"]),
                                 scan=knobs.scan_layers, length=cfg.n_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = lm_logits(x, _head(cfg, params), cfg.vocab_size)
    new_cache = {"layers": new_layers, "pos": pos + 1, "lengths": lengths}
    return logits[:, 0], new_cache
