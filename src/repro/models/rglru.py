"""RecurrentGemma / Griffin hybrid: RG-LRU recurrent blocks + local
(sliding-window) attention blocks in a 2:1 pattern.

Layer layout for L layers: ``head = L % 3`` leading recurrent blocks, then
``L // 3`` scanned super-blocks of (attention, recurrent, recurrent) — this
cyclic rotation reproduces the paper's r,r,a,r,r,a,... sequence exactly.

Training/prefill runs the RG-LRU with ``lax.associative_scan`` (log-depth);
the Pallas kernel (``repro.kernels.rglru_scan``) is the TPU sequential-scan
target. Decode is the O(1) recurrence plus a rolling window KV cache.
"""
from __future__ import annotations

from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs import ModelConfig
from ..sharding.rules import ShardCtx
from . import attention as attn
from .common import (
    NEG_INF,
    apply_rope,
    chunked_cross_entropy,
    cross_entropy,
    embed_tokens,
    lm_logits,
    rms_norm,
)
from .knobs import DEFAULT_KNOBS
from .params import ParamSpec, scan_or_loop, stack
from .ssm import causal_conv, conv_step

RG_C = 8.0          # RG-LRU decay sharpness constant (Griffin §2.4)
LAMBDA_INIT = -4.6  # softplus(Λ)≈0.01 → per-step decay a ≈ exp(-0.08·r)


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def _gelu_ffn_spec(cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    return {
        "w_gate": ParamSpec((d, f), ("embed", "ffn"), "scaled_normal"),
        "w_up": ParamSpec((d, f), ("embed", "ffn"), "scaled_normal"),
        "w_down": ParamSpec((f, d), ("ffn", "embed"), "scaled_normal"),
    }


def rec_block_spec(cfg: ModelConfig) -> dict:
    r = cfg.recurrent
    d, lru = cfg.d_model, r.lru_width
    nb = cfg.n_heads                      # block-diagonal gate blocks
    bs = lru // nb
    return {
        "ln1": ParamSpec((d,), ("embed",), "zeros"),
        "w_x": ParamSpec((d, lru), ("embed", "lru_width"), "scaled_normal"),
        "w_gate": ParamSpec((d, lru), ("embed", "lru_width"), "scaled_normal"),
        "conv": ParamSpec((r.conv1d_width, lru), (None, "lru_width"),
                          "scaled_normal"),
        "rg_a_w": ParamSpec((nb, bs, bs), ("act_heads", None, None),
                            "scaled_normal"),
        "rg_a_b": ParamSpec((lru,), ("lru_width",), "zeros"),
        "rg_x_w": ParamSpec((nb, bs, bs), ("act_heads", None, None),
                            "scaled_normal"),
        "rg_x_b": ParamSpec((lru,), ("lru_width",), "zeros"),
        "lam": ParamSpec((lru,), ("lru_width",), "const", LAMBDA_INIT),
        "w_out": ParamSpec((lru, d), ("lru_width", "embed"), "scaled_normal"),
        "ln2": ParamSpec((d,), ("embed",), "zeros"),
        "ffn": _gelu_ffn_spec(cfg),
    }


def attn_block_spec(cfg: ModelConfig) -> dict:
    return {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
        "attn": attn.attn_spec(cfg),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
        "ffn": _gelu_ffn_spec(cfg),
    }


def _layout(cfg: ModelConfig) -> Tuple[int, int]:
    head = cfg.n_layers % 3
    n_sb = cfg.n_layers // 3
    return head, n_sb


def model_spec(cfg: ModelConfig) -> dict:
    head, n_sb = _layout(cfg)
    v = cfg.padded_vocab()
    spec = {
        "embed": {"tok": ParamSpec((v, cfg.d_model), ("vocab", "embed"),
                                   "normal", 0.02)},
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
    }
    if head:
        spec["head_rec"] = stack(rec_block_spec(cfg), head)
    if n_sb:
        spec["sb"] = stack({"attn": attn_block_spec(cfg),
                            "rec": stack(rec_block_spec(cfg), 2)}, n_sb)
    if not cfg.tie_embeddings:
        spec["lm_head"] = ParamSpec((cfg.d_model, v), ("embed", "vocab"),
                                    "scaled_normal")
    return spec


# ---------------------------------------------------------------------------
# RG-LRU core
# ---------------------------------------------------------------------------

def _blockdiag(x: jax.Array, w: jax.Array, b: jax.Array) -> jax.Array:
    """x: (B, S, lru); w: (nb, bs, bs); b: (lru,)."""
    B, S, lru = x.shape
    nb, bs, _ = w.shape
    xb = x.reshape(B, S, nb, bs)
    y = jnp.einsum("bshi,hij->bshj", xb, w).reshape(B, S, lru)
    return y + b


def rglru_gates(p: dict, x: jax.Array):
    """x: (B, S, lru) post-conv. Returns (log_a f32, beta·x f32)."""
    r = jax.nn.sigmoid(_blockdiag(x, p["rg_a_w"], p["rg_a_b"]).astype(jnp.float32))
    i = jax.nn.sigmoid(_blockdiag(x, p["rg_x_w"], p["rg_x_b"]).astype(jnp.float32))
    log_a = -RG_C * jax.nn.softplus(p["lam"].astype(jnp.float32)) * r
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    bx = beta * i * x.astype(jnp.float32)
    return log_a, bx


def rglru_full(p: dict, x: jax.Array, use_kernel: bool = False):
    """Linear recurrence over the sequence. Returns (h (B,S,lru), h_last)."""
    log_a, bx = rglru_gates(p, x)
    if use_kernel:
        from ..kernels import ops as kops
        h = kops.rglru(jnp.exp(log_a), bx)
    else:
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2
        _, h = lax.associative_scan(combine, (jnp.exp(log_a), bx), axis=1)
    return h.astype(x.dtype), h[:, -1]


def rglru_step(p: dict, x: jax.Array, h_prev: jax.Array):
    """x: (B, 1, lru); h_prev: (B, lru) f32."""
    log_a, bx = rglru_gates(p, x)
    h = jnp.exp(log_a[:, 0]) * h_prev + bx[:, 0]
    return h.astype(x.dtype)[:, None], h


# ---------------------------------------------------------------------------
# Blocks
# ---------------------------------------------------------------------------

def _gelu_ffn(p: dict, x: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    return jnp.einsum("...f,fd->...d", jax.nn.gelu(g) * u, p["w_down"])


def rec_block_full(cfg, p, x_res, ctx, knobs, collect=False):
    r = cfg.recurrent
    h = rms_norm(x_res, p["ln1"], cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("bsd,dl->bsl", h, p["w_gate"]))
    xr = jnp.einsum("bsd,dl->bsl", h, p["w_x"])
    conv_in = xr
    xr = causal_conv(xr, p["conv"])
    hr, h_last = rglru_full(p, xr, use_kernel=knobs.use_kernels)
    y = jnp.einsum("bsl,ld->bsd", hr * gate, p["w_out"])
    x_res = x_res + y
    h2 = rms_norm(x_res, p["ln2"], cfg.norm_eps)
    x_res = x_res + _gelu_ffn(p["ffn"], h2)
    state = None
    if collect:
        state = {"h": h_last,
                 "conv": conv_in[:, -(r.conv1d_width - 1):]}
    return x_res, state


def rec_block_step(cfg, p, x_res, cache, ctx):
    h = rms_norm(x_res, p["ln1"], cfg.norm_eps)
    gate = jax.nn.gelu(jnp.einsum("bsd,dl->bsl", h, p["w_gate"]))
    xr = jnp.einsum("bsd,dl->bsl", h, p["w_x"])
    y_conv, new_window = conv_step(cache["conv"], p["conv"], xr)
    hr, h_new = rglru_step(p, y_conv, cache["h"])
    y = jnp.einsum("bsl,ld->bsd", hr * gate, p["w_out"])
    x_res = x_res + y
    h2 = rms_norm(x_res, p["ln2"], cfg.norm_eps)
    x_res = x_res + _gelu_ffn(p["ffn"], h2)
    return x_res, {"h": h_new, "conv": new_window}


def attn_block_full(cfg, p, x_res, positions, ctx, knobs, collect=False):
    W = cfg.recurrent.attention_window
    h = rms_norm(x_res, p["ln1"], cfg.norm_eps)
    if collect:
        a, (k, v) = attn.attn_full(cfg, p["attn"], h, positions, ctx, knobs,
                                   window=W, return_kv=True)
        B, S = h.shape[:2]
        if S >= W:
            kw, vw = k[:, -W:], v[:, -W:]
        else:
            pad = [(0, 0)] * k.ndim
            pad[1] = (W - S, 0)
            kw, vw = jnp.pad(k, pad), jnp.pad(v, pad)
        state = {"k": kw, "v": vw}
    else:
        a = attn.attn_full(cfg, p["attn"], h, positions, ctx, knobs, window=W)
        state = None
    x_res = x_res + a
    h2 = rms_norm(x_res, p["ln2"], cfg.norm_eps)
    x_res = x_res + _gelu_ffn(p["ffn"], h2)
    return x_res, state


def attn_block_step(cfg, p, x_res, cache, pos, ctx):
    """Rolling (end-aligned) window cache: shift left, append at the end."""
    W = cfg.recurrent.attention_window
    B = x_res.shape[0]
    h = rms_norm(x_res, p["ln1"], cfg.norm_eps)
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    q, k, v = attn._qkv(cfg, p["attn"], h)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    k_cache = jnp.concatenate([cache["k"][:, 1:], k.astype(cache["k"].dtype)], axis=1)
    v_cache = jnp.concatenate([cache["v"][:, 1:], v.astype(cache["v"].dtype)], axis=1)
    filled = jnp.minimum(pos + 1, W)
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    G = H // KVH
    qh = (q * hd ** -0.5).reshape(B, KVH, G, hd)
    s = jnp.einsum("bhgd,bkhd->bhgk", qh, k_cache,
                   preferred_element_type=jnp.float32)
    valid = jnp.arange(W)[None] >= (W - filled)             # (1, W)
    s = jnp.where(valid[:, None, None], s, NEG_INF)
    pr = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", pr, v_cache,
                     preferred_element_type=jnp.float32)
    a = jnp.einsum("bsk,kd->bsd",
                   out.reshape(B, 1, H * hd).astype(h.dtype), p["attn"]["wo"])
    x_res = x_res + a
    h2 = rms_norm(x_res, p["ln2"], cfg.norm_eps)
    x_res = x_res + _gelu_ffn(p["ffn"], h2)
    return x_res, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# Stack plumbing
# ---------------------------------------------------------------------------

def _tree_idx(tree, i):
    return jax.tree.map(lambda a: a[i], tree)


def _stack_forward(cfg, params, x, positions, ctx, knobs, collect=False):
    head, n_sb = _layout(cfg)
    from .transformer import _remat
    head_states = []
    if head:
        for i in range(head):
            x, st = rec_block_full(cfg, _tree_idx(params["head_rec"], i),
                                   x, ctx, knobs, collect)
            head_states.append(st)

    sb_states = None
    if n_sb:
        def body(x, lp):
            x = ctx.constrain(x, ("act_batch", "act_seq", "act_embed"))
            x, a_st = attn_block_full(cfg, lp["attn_blk"], x, positions, ctx,
                                      knobs, collect)
            r_sts = []
            for i in range(2):
                x, r_st = rec_block_full(cfg, _tree_idx(lp["rec"], i), x,
                                         ctx, knobs, collect)
                r_sts.append(r_st)
            if collect:
                r_stack = jax.tree.map(lambda *z: jnp.stack(z), *r_sts)
                return x, (a_st, r_stack)
            return x, None

        sb_params = {"attn_blk": params["sb"]["attn"], "rec": params["sb"]["rec"]}
        body_fn = body if collect else _remat(body, knobs.remat)
        x, sb_states = scan_or_loop(body_fn, x, sb_params,
                                    scan=knobs.scan_layers, length=n_sb)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    return x, head_states, sb_states


def _head_w(cfg, params):
    if cfg.tie_embeddings:
        return params["embed"]["tok"].T
    return params["lm_head"]


def loss_fn(cfg, params, batch, ctx=ShardCtx(), knobs=DEFAULT_KNOBS,
            z_loss: float = 0.0):
    dtype = jnp.dtype(cfg.dtype)
    x = embed_tokens(params["embed"]["tok"], batch["tokens"], dtype)
    x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)          # gemma scaling
    B, S = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, _, _ = _stack_forward(cfg, params, x, positions, ctx, knobs)
    head = _head_w(cfg, params)
    if knobs.chunked_loss:
        ce = chunked_cross_entropy(x, head, batch["labels"], cfg.vocab_size,
                                   batch.get("mask"), z_loss, knobs.loss_chunk,
                                   unroll=not knobs.scan_layers)
    else:
        logits = lm_logits(x, head, cfg.vocab_size)
        ce = cross_entropy(logits, batch["labels"], batch.get("mask"), z_loss)
    return ce, {"ce": ce, "moe_aux": jnp.float32(0.0)}


# ---------------------------------------------------------------------------
# Serving
# ---------------------------------------------------------------------------

def _rec_cache_zero(cfg, batch, dtype):
    r = cfg.recurrent
    return {"h": jnp.zeros((batch, r.lru_width), jnp.float32),
            "conv": jnp.zeros((batch, r.conv1d_width - 1, r.lru_width), dtype)}


def _attn_cache_zero(cfg, batch, dtype):
    W = cfg.recurrent.attention_window
    return {"k": jnp.zeros((batch, W, cfg.n_kv_heads, cfg.head_dim_), dtype),
            "v": jnp.zeros((batch, W, cfg.n_kv_heads, cfg.head_dim_), dtype)}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    head, n_sb = _layout(cfg)
    z = lambda t, n: jax.tree.map(
        lambda a: jnp.zeros((n,) + a.shape, a.dtype), t)
    cache = {"pos": jnp.zeros((), jnp.int32),
             "lengths": jnp.zeros((batch,), jnp.int32)}
    if head:
        cache["head_rec"] = z(_rec_cache_zero(cfg, batch, dtype), head)
    if n_sb:
        cache["sb"] = {
            "attn": z(_attn_cache_zero(cfg, batch, dtype), n_sb),
            "rec": jax.tree.map(
                lambda a: jnp.zeros((n_sb, 2) + a.shape, a.dtype),
                _rec_cache_zero(cfg, batch, dtype)),
        }
    return cache


def cache_axes(cfg: ModelConfig) -> dict:
    head, n_sb = _layout(cfg)
    rec = {"h": ("layers", "cache_batch", "lru_width"),
           "conv": ("layers", "cache_batch", None, "lru_width")}
    axes = {"pos": (), "lengths": ("cache_batch",)}
    if head:
        axes["head_rec"] = rec
    if n_sb:
        axes["sb"] = {
            "attn": {"k": ("layers", "cache_batch", "cache_seq",
                           "cache_heads", None),
                     "v": ("layers", "cache_batch", "cache_seq",
                           "cache_heads", None)},
            "rec": {"h": ("layers", None, "cache_batch", "lru_width"),
                    "conv": ("layers", None, "cache_batch", None,
                             "lru_width")},
        }
    return axes


def prefill(cfg, params, batch, ctx=ShardCtx(), knobs=DEFAULT_KNOBS,
            cache_len=None):
    dtype = jnp.dtype(cfg.dtype)
    head, n_sb = _layout(cfg)
    x = embed_tokens(params["embed"]["tok"], batch["tokens"], dtype)
    x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    B, S = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))
    x, head_states, sb_states = _stack_forward(cfg, params, x, positions,
                                               ctx, knobs, collect=True)
    logits = lm_logits(x[:, -1:], _head_w(cfg, params), cfg.vocab_size)
    cache = {"pos": jnp.int32(S), "lengths": jnp.full((B,), S, jnp.int32)}
    if head:
        cache["head_rec"] = jax.tree.map(lambda *z: jnp.stack(z), *head_states)
    if n_sb:
        a_st, r_st = sb_states
        cache["sb"] = {"attn": a_st, "rec": r_st}
    return logits[:, 0], cache


def decode_step(cfg, params, cache, batch, ctx=ShardCtx(),
                knobs=DEFAULT_KNOBS):
    dtype = jnp.dtype(cfg.dtype)
    head, n_sb = _layout(cfg)
    x = embed_tokens(params["embed"]["tok"], batch["tokens"], dtype)
    x = x * jnp.asarray(cfg.d_model ** 0.5, dtype)
    pos = cache["pos"]
    new_cache = {"pos": pos + 1, "lengths": cache["lengths"] + 1}

    if head:
        new_heads = []
        for i in range(head):
            x, st = rec_block_step(cfg, _tree_idx(params["head_rec"], i), x,
                                   _tree_idx(cache["head_rec"], i), ctx)
            new_heads.append(st)
        new_cache["head_rec"] = jax.tree.map(lambda *z: jnp.stack(z),
                                             *new_heads)
    if n_sb:
        def body(x, xs):
            lp, c_attn, c_rec = xs
            x, a_st = attn_block_step(cfg, lp["attn_blk"], x, c_attn, pos, ctx)
            r_sts = []
            for i in range(2):
                x, r_st = rec_block_step(cfg, _tree_idx(lp["rec"], i), x,
                                         _tree_idx(c_rec, i), ctx)
                r_sts.append(r_st)
            r_stack = jax.tree.map(lambda *z: jnp.stack(z), *r_sts)
            return x, (a_st, r_stack)

        sb_params = {"attn_blk": params["sb"]["attn"], "rec": params["sb"]["rec"]}
        x, (a_st, r_st) = scan_or_loop(
            body, x, (sb_params, cache["sb"]["attn"], cache["sb"]["rec"]),
            scan=knobs.scan_layers, length=n_sb)
        new_cache["sb"] = {"attn": a_st, "rec": r_st}
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = lm_logits(x, _head_w(cfg, params), cfg.vocab_size)
    return logits[:, 0], new_cache
