from .api import (
    Model,
    concrete_batch,
    decode_cache_kwargs,
    get_model,
    input_specs,
)
from .knobs import DEFAULT_KNOBS, RunKnobs

__all__ = ["Model", "RunKnobs", "DEFAULT_KNOBS", "concrete_batch",
           "decode_cache_kwargs", "get_model", "input_specs"]
