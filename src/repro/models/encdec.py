"""Encoder–decoder backbone (seamless-m4t-large-v2).

The audio frontend is a STUB per the assignment: ``input_specs()`` provides
precomputed frame embeddings (B, S_src, d_model); a learned adaptor
projection stands in for the real feature pipeline. The text decoder is a
standard causal stack with cross-attention; decode caches both the decoder
self-attention KV and the (computed-once) cross-attention KV.

The assigned ``seq_len`` is interpreted as the *total* token budget:
S_src = S_tgt = seq_len // 2 (recorded in DESIGN.md).
"""
from __future__ import annotations

from typing import Optional

import jax.numpy as jnp

from ..configs import ModelConfig
from ..sharding.rules import ShardCtx
from . import attention as attn
from .common import (
    chunked_attention,
    chunked_cross_entropy,
    cross_entropy,
    decode_attention,
    embed_tokens,
    lm_logits,
    rms_norm,
    swiglu,
)
from .knobs import DEFAULT_KNOBS
from .params import ParamSpec, scan_or_loop, stack
from .transformer import _remat, ffn_spec


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def _cross_spec(cfg: ModelConfig) -> dict:
    d, H, KVH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    return {
        "wq": ParamSpec((d, H * hd), ("embed", "heads_dim"), "scaled_normal"),
        "wk": ParamSpec((d, KVH * hd), ("embed", "heads_dim"), "scaled_normal"),
        "wv": ParamSpec((d, KVH * hd), ("embed", "heads_dim"), "scaled_normal"),
        "wo": ParamSpec((H * hd, d), ("heads_dim", "embed"), "scaled_normal"),
    }


def enc_block_spec(cfg: ModelConfig) -> dict:
    return {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
        "attn": attn.attn_spec(cfg),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
        "ffn": ffn_spec(cfg),
    }


def dec_block_spec(cfg: ModelConfig) -> dict:
    return {
        "ln1": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
        "attn": attn.attn_spec(cfg),
        "ln_x": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
        "cross": _cross_spec(cfg),
        "ln2": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
        "ffn": ffn_spec(cfg),
    }


def model_spec(cfg: ModelConfig) -> dict:
    v = cfg.padded_vocab()
    return {
        "embed": {"tok": ParamSpec((v, cfg.d_model), ("vocab", "embed"),
                                   "normal", 0.02)},
        "frame_proj": ParamSpec((cfg.d_model, cfg.d_model),
                                ("embed", "act_embed"), "scaled_normal"),
        "enc_blocks": stack(enc_block_spec(cfg), cfg.encdec.n_encoder_layers),
        "enc_ln_f": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
        "dec_blocks": stack(dec_block_spec(cfg), cfg.n_layers),
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
        "lm_head": ParamSpec((cfg.d_model, v), ("embed", "vocab"),
                             "scaled_normal"),
    }


# ---------------------------------------------------------------------------
# Cross attention
# ---------------------------------------------------------------------------

def _cross_kv(cfg, p, mem):
    B, Ss, _ = mem.shape
    KVH, hd = cfg.n_kv_heads, cfg.head_dim_
    k = jnp.einsum("bsd,dk->bsk", mem, p["wk"]).reshape(B, Ss, KVH, hd)
    v = jnp.einsum("bsd,dk->bsk", mem, p["wv"]).reshape(B, Ss, KVH, hd)
    return k, v


def _cross_full(cfg, p, h, mem, ctx, knobs, collect=False):
    B, S, _ = h.shape
    H, hd = cfg.n_heads, cfg.head_dim_
    q = jnp.einsum("bsd,dk->bsk", h, p["wq"]).reshape(B, S, H, hd)
    k, v = _cross_kv(cfg, p, mem)
    out = chunked_attention(q, k, v, causal=False,
                            q_block=knobs.q_block, kv_block=knobs.kv_block,
                            unroll=not knobs.scan_layers)
    y = jnp.einsum("bsk,kd->bsd", out.reshape(B, S, -1), p["wo"])
    if collect:
        return y, (k, v)
    return y


def _cross_decode(cfg, p, h, xk, xv):
    B = h.shape[0]
    H, hd = cfg.n_heads, cfg.head_dim_
    q = jnp.einsum("bsd,dk->bsk", h, p["wq"]).reshape(B, 1, H, hd)
    lengths = jnp.full((B,), xk.shape[1], jnp.int32)
    out = decode_attention(q, xk, xv, lengths)
    return jnp.einsum("bsk,kd->bsd", out.reshape(B, 1, -1), p["wo"])


# ---------------------------------------------------------------------------
# Encoder / decoder stacks
# ---------------------------------------------------------------------------

def encode(cfg, params, frames, ctx, knobs):
    dtype = jnp.dtype(cfg.dtype)
    x = jnp.einsum("bsd,de->bse", frames.astype(dtype), params["frame_proj"])
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32)[None], (B, S))

    def body(x, lp):
        x = ctx.constrain(x, ("act_batch", "act_seq", "act_embed"))
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + attn.attn_full(cfg, lp["attn"], h, positions, ctx, knobs,
                               causal=False)
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                       lp["ffn"]["w_down"])
        return x, None

    x, _ = scan_or_loop(_remat(body, knobs.remat), x, params["enc_blocks"],
                        scan=knobs.scan_layers,
                        length=cfg.encdec.n_encoder_layers)
    return rms_norm(x, params["enc_ln_f"], cfg.norm_eps)


def decode_stack(cfg, params, x, mem, positions, ctx, knobs,
                 collect: bool = False):
    def body(x, lp):
        x = ctx.constrain(x, ("act_batch", "act_seq", "act_embed"))
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        if collect:
            a, kv = attn.attn_full(cfg, lp["attn"], h, positions, ctx, knobs,
                                   return_kv=True)
        else:
            a = attn.attn_full(cfg, lp["attn"], h, positions, ctx, knobs)
            kv = None
        x = x + a
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        if collect:
            c, xkv = _cross_full(cfg, lp["cross"], h, mem, ctx, knobs,
                                 collect=True)
        else:
            c = _cross_full(cfg, lp["cross"], h, mem, ctx, knobs)
            xkv = None
        x = x + c
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                       lp["ffn"]["w_down"])
        return x, (kv, xkv)

    body_fn = body if collect else _remat(body, knobs.remat)
    x, states = scan_or_loop(body_fn, x, params["dec_blocks"],
                             scan=knobs.scan_layers, length=cfg.n_layers)
    return rms_norm(x, params["ln_f"], cfg.norm_eps), states


# ---------------------------------------------------------------------------
# Model API
# ---------------------------------------------------------------------------

def loss_fn(cfg, params, batch, ctx=ShardCtx(), knobs=DEFAULT_KNOBS,
            z_loss: float = 0.0):
    dtype = jnp.dtype(cfg.dtype)
    mem = encode(cfg, params, batch["frames"], ctx, knobs)
    x = embed_tokens(params["embed"]["tok"], batch["tokens"], dtype)
    B, St = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(St, dtype=jnp.int32)[None], (B, St))
    x, _ = decode_stack(cfg, params, x, mem, positions, ctx, knobs)
    if knobs.chunked_loss:
        ce = chunked_cross_entropy(x, params["lm_head"], batch["labels"],
                                   cfg.vocab_size, batch.get("mask"), z_loss,
                                   knobs.loss_chunk,
                                   unroll=not knobs.scan_layers)
    else:
        logits = lm_logits(x, params["lm_head"], cfg.vocab_size)
        ce = cross_entropy(logits, batch["labels"], batch.get("mask"), z_loss)
    return ce, {"ce": ce, "moe_aux": jnp.float32(0.0)}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype,
               src_len: Optional[int] = None) -> dict:
    KVH, hd, L = cfg.n_kv_heads, cfg.head_dim_, cfg.n_layers
    Ss = src_len if src_len is not None else max_seq
    return {
        "layers": {
            "k": jnp.zeros((L, batch, max_seq, KVH, hd), dtype),
            "v": jnp.zeros((L, batch, max_seq, KVH, hd), dtype),
            "xk": jnp.zeros((L, batch, Ss, KVH, hd), dtype),
            "xv": jnp.zeros((L, batch, Ss, KVH, hd), dtype),
        },
        "pos": jnp.zeros((), jnp.int32),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def cache_axes(cfg: ModelConfig) -> dict:
    kv = ("layers", "cache_batch", "cache_seq", "cache_heads", None)
    return {"layers": {"k": kv, "v": kv, "xk": kv, "xv": kv},
            "pos": (), "lengths": ("cache_batch",)}


def prefill(cfg, params, batch, ctx=ShardCtx(), knobs=DEFAULT_KNOBS,
            cache_len=None):
    """Encode frames + teacher-forced decoder prefix; build both caches."""
    dtype = jnp.dtype(cfg.dtype)
    mem = encode(cfg, params, batch["frames"], ctx, knobs)
    x = embed_tokens(params["embed"]["tok"], batch["tokens"], dtype)
    B, St = batch["tokens"].shape
    positions = jnp.broadcast_to(jnp.arange(St, dtype=jnp.int32)[None], (B, St))
    x, (kv, xkv) = decode_stack(cfg, params, x, mem, positions, ctx, knobs,
                                collect=True)
    logits = lm_logits(x[:, -1:], params["lm_head"], cfg.vocab_size)
    max_seq = cache_len or St

    def pad(t):
        cfgs = [(0, 0)] * t.ndim
        cfgs[2] = (0, max_seq - t.shape[2])
        return jnp.pad(t, cfgs)

    cache = {
        "layers": {"k": pad(kv[0]), "v": pad(kv[1]),
                   "xk": xkv[0], "xv": xkv[1]},
        "pos": jnp.int32(St),
        "lengths": jnp.full((B,), St, jnp.int32),
    }
    return logits[:, 0], cache


def decode_step(cfg, params, cache, batch, ctx=ShardCtx(),
                knobs=DEFAULT_KNOBS):
    dtype = jnp.dtype(cfg.dtype)
    x = embed_tokens(params["embed"]["tok"], batch["tokens"], dtype)
    pos, lengths = cache["pos"], cache["lengths"] + 1

    def body(x, xs):
        lp, cache_l = xs
        h = rms_norm(x, lp["ln1"], cfg.norm_eps)
        a, new_self = attn.attn_decode(
            cfg, lp["attn"], h, {"k": cache_l["k"], "v": cache_l["v"]},
            pos, lengths, ctx)
        x = x + a
        h = rms_norm(x, lp["ln_x"], cfg.norm_eps)
        x = x + _cross_decode(cfg, lp["cross"], h, cache_l["xk"],
                              cache_l["xv"])
        h = rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + swiglu(h, lp["ffn"]["w_gate"], lp["ffn"]["w_up"],
                       lp["ffn"]["w_down"])
        new_cache_l = {"k": new_self["k"], "v": new_self["v"],
                       "xk": cache_l["xk"], "xv": cache_l["xv"]}
        return x, new_cache_l

    x, new_layers = scan_or_loop(body, x,
                                 (params["dec_blocks"], cache["layers"]),
                                 scan=knobs.scan_layers, length=cfg.n_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = lm_logits(x, params["lm_head"], cfg.vocab_size)
    return logits[:, 0], {"layers": new_layers, "pos": pos + 1,
                          "lengths": lengths}
