"""Runtime knobs orthogonal to the architecture config — the execution-path
and performance surface (kernel selection, block sizes, remat, loss chunking).
Part of the *compile signature* (funcX container type) together with the
ModelConfig, ShapeConfig, and mesh."""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class RunKnobs:
    use_kernels: bool = False    # Pallas kernels (TPU target) vs chunked-jnp
    q_block: int = 1024
    kv_block: int = 1024
    remat: str = "full"          # "none" | "dots" | "full"
    chunked_loss: bool = False   # never materialize (B, S, V) logits
    loss_chunk: int = 512
    causal_skip: bool = False    # skip fully-masked kv blocks in causal attn
    # scan over layers (production) vs unrolled python loop. The unrolled
    # form exists because XLA cost_analysis counts while bodies ONCE —
    # roofline analysis lowers unrolled 1-/2-period variants and
    # extrapolates exact per-layer costs (see launch/dryrun.py).
    scan_layers: bool = True
    # ANALYSIS-ONLY: replace the attention core (scores/softmax/context)
    # with a shape-preserving stub so its exact byte/flop contribution can
    # be isolated by differencing two lowerings — the Pallas flash kernel's
    # cost model is then substituted (§Perf "kernel-adjusted" iterations).
    attn_stub: bool = False


DEFAULT_KNOBS = RunKnobs()
