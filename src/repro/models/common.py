"""Shared model components: norms, RoPE/M-RoPE, embeddings, GQA attention
(memory-bounded chunked implementation), SwiGLU, losses.

The chunked attention here is the *default execution path* of the framework
(pure JAX, flash-style online softmax, compiles to bounded-memory HLO on any
backend). The Pallas kernels in ``repro.kernels`` are the TPU-target
implementations of the same contract and are validated against
``repro.kernels.ref`` oracles; select them with ``use_kernels=True``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * lax.rsqrt(var + eps)
    return (x * (1.0 + scale.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------

def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    """(head_dim/2,) inverse frequencies."""
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (B, S, H, D); positions: (B, S) int32."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                   # (D/2,)
    ang = positions[..., None].astype(jnp.float32) * inv         # (B, S, D/2)
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jax.Array, positions: jax.Array, theta: float,
                sections: Tuple[int, int, int]) -> jax.Array:
    """Multimodal RoPE (qwen2-vl). positions: (3, B, S) for (t, h, w);
    ``sections`` split the D/2 frequency dims across the three position ids."""
    d = x.shape[-1]
    inv = rope_freqs(d, theta)                                   # (D/2,)
    assert sum(sections) == d // 2, (sections, d)
    # Select, per frequency index, which of the 3 position streams drives it.
    sec_ids = jnp.repeat(jnp.arange(3), jnp.array(sections),
                         total_repeat_length=d // 2)             # (D/2,)
    # (B, S, D/2): pick positions[sec_ids[i]] for dim i
    pos3 = jnp.moveaxis(positions.astype(jnp.float32), 0, -1)    # (B, S, 3)
    pos_per_dim = jnp.take(pos3, sec_ids, axis=-1)               # (B, S, D/2)
    ang = pos_per_dim * inv
    cos, sin = jnp.cos(ang)[:, :, None, :], jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention — memory-bounded chunked (flash-style) implementation
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def _gqa_scores(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B, Sq, KVH, G, D); k: (B, Sk, KVH, D) -> (B, KVH, G, Sq, Sk)."""
    return jnp.einsum("bqhgd,bkhd->bhgqk", q, k,
                      preferred_element_type=jnp.float32)


def _gqa_context(p: jax.Array, v: jax.Array) -> jax.Array:
    """p: (B, KVH, G, Sq, Sk); v: (B, Sk, KVH, D) -> (B, Sq, KVH, G, D)."""
    return jnp.einsum("bhgqk,bkhd->bqhgd", p, v,
                      preferred_element_type=jnp.float32)


def chunked_attention(
    q: jax.Array,            # (B, Sq, H, D)
    k: jax.Array,            # (B, Sk, KVH, D)
    v: jax.Array,            # (B, Sk, KVH, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,     # sliding-window (local) attention
    q_offset: int = 0,                # absolute position of q[0] (for caches)
    q_block: int = 1024,
    kv_block: int = 1024,
    softmax_scale: Optional[float] = None,
    unroll: bool = False,
) -> jax.Array:
    """Flash-style attention: outer scan over q blocks, inner scan over kv
    blocks with online softmax. Peak temp ~ (B, KVH, G, q_block, kv_block).

    ``unroll=True`` replaces the scans with python loops — used ONLY by the
    roofline analysis lowerings (XLA cost_analysis counts while bodies once,
    which would undercount attention by n_q·n_k)."""
    B, Sq, H, D = q.shape
    _, Sk, KVH, _ = k.shape
    Dv = v.shape[-1]
    G = H // KVH
    scale = softmax_scale if softmax_scale is not None else D ** -0.5

    q_block = min(q_block, Sq)
    kv_block = min(kv_block, Sk)
    # pad to block multiples (masked out below)
    pad_q = (-Sq) % q_block
    pad_k = (-Sk) % kv_block
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_k), (0, 0), (0, 0)))
    Sq_p, Sk_p = Sq + pad_q, Sk + pad_k
    n_q, n_k = Sq_p // q_block, Sk_p // kv_block

    qr = (q * scale).reshape(B, n_q, q_block, KVH, G, D)
    kr = k.reshape(B, n_k, kv_block, KVH, D)
    vr = v.reshape(B, n_k, kv_block, KVH, Dv)

    q_pos_base = jnp.arange(q_block) + q_offset
    k_pos_base = jnp.arange(kv_block)

    def q_step(_, qi):
        qb = qr[:, qi]                                       # (B,qb,KVH,G,D)
        q_pos = q_pos_base + qi * q_block                    # absolute positions

        def kv_step(carry, ki):
            m, l, acc = carry
            kb, vb = kr[:, ki], vr[:, ki]
            k_pos = k_pos_base + ki * kv_block
            s = _gqa_scores(qb, kb)                          # (B,KVH,G,qb,kb) f32
            mask = jnp.ones((q_block, kv_block), bool)
            if causal:
                mask &= q_pos[:, None] >= k_pos[None, :]
            if window is not None:
                mask &= q_pos[:, None] - k_pos[None, :] < window
            mask &= (k_pos < Sk)[None, :]                    # kv padding
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))           # (B,KVH,G,qb)
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(axis=-1)
            corr_q = jnp.moveaxis(corr, -1, 1)[..., None]    # (B,qb,KVH,G,1)
            acc_new = acc * corr_q + _gqa_context(p, vb)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((B, KVH, G, q_block), NEG_INF, jnp.float32)
        l0 = jnp.zeros((B, KVH, G, q_block), jnp.float32)
        a0 = jnp.zeros((B, q_block, KVH, G, Dv), jnp.float32)
        carry = (m0, l0, a0)
        if unroll:
            for ki in range(n_k):
                carry, _ = kv_step(carry, ki)
            m, l, acc = carry
        else:
            (m, l, acc), _ = lax.scan(kv_step, carry, jnp.arange(n_k))
        l = jnp.moveaxis(l, -1, 1)[..., None]                # (B,qb,KVH,G,1)
        out = acc / jnp.maximum(l, 1e-30)
        return None, out.astype(q.dtype)

    if unroll:
        blocks = jnp.stack([q_step(None, qi)[1] for qi in range(n_q)])
    else:
        _, blocks = lax.scan(q_step, None, jnp.arange(n_q))  # (n_q,B,qb,…)
    out = jnp.moveaxis(blocks, 0, 1).reshape(B, Sq_p, H, Dv)
    return out[:, :Sq]


def decode_attention(
    q: jax.Array,            # (B, 1, H, D)
    k_cache: jax.Array,      # (B, S, KVH, D)
    v_cache: jax.Array,      # (B, S, KVH, D)
    lengths: jax.Array,      # (B,) number of valid cache entries (incl. new)
    *,
    window: Optional[int] = None,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    """Single-token attention against a (possibly sequence-sharded) KV cache."""
    B, _, H, D = q.shape
    S, KVH = k_cache.shape[1], k_cache.shape[2]
    Dv = v_cache.shape[-1]
    G = H // KVH
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qh = (q * scale).reshape(B, KVH, G, D)
    s = jnp.einsum("bhgd,bkhd->bhgk", qh, k_cache,
                   preferred_element_type=jnp.float32)        # (B,KVH,G,S)
    pos = jnp.arange(S)[None]                                 # (1, S)
    mask = pos < lengths[:, None]
    if window is not None:
        mask &= pos >= (lengths[:, None] - window)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", p, v_cache,
                     preferred_element_type=jnp.float32)
    return out.reshape(B, 1, H, Dv).astype(q.dtype)


# ---------------------------------------------------------------------------
# FFN / embeddings / loss
# ---------------------------------------------------------------------------

def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array,
           w_down: jax.Array) -> jax.Array:
    g = jnp.einsum("...d,df->...f", x, w_gate)
    u = jnp.einsum("...d,df->...f", x, w_up)
    return jnp.einsum("...f,fd->...d", jax.nn.silu(g) * u, w_down)


def embed_tokens(table: jax.Array, tokens: jax.Array, dtype) -> jax.Array:
    return jnp.take(table, tokens, axis=0).astype(dtype)


def lm_logits(x: jax.Array, head: jax.Array, vocab_size: int) -> jax.Array:
    """x: (B, S, d); head: (d, V_pad). Padded vocab columns are masked."""
    logits = jnp.einsum("bsd,dv->bsv", x, head,
                        preferred_element_type=jnp.float32)
    v_pad = head.shape[-1]
    if v_pad > vocab_size:
        pad_mask = jnp.arange(v_pad) >= vocab_size
        logits = jnp.where(pad_mask[None, None], NEG_INF, logits)
    return logits


def cross_entropy(logits: jax.Array, labels: jax.Array,
                  mask: Optional[jax.Array] = None,
                  z_loss: float = 0.0) -> jax.Array:
    """Mean token cross-entropy. logits f32 (B,S,V_pad); labels (B,S) int."""
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if z_loss:
        nll = nll + z_loss * jnp.square(lse)
    if mask is None:
        return nll.mean()
    mask = mask.astype(nll.dtype)
    return (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)


def chunked_cross_entropy(x: jax.Array, head: jax.Array, labels: jax.Array,
                          vocab_size: int, mask: Optional[jax.Array] = None,
                          z_loss: float = 0.0, chunk: int = 512,
                          unroll: bool = False) -> jax.Array:
    """Beyond-paper memory optimization: compute logits + CE per sequence
    chunk inside a scan so the (B, S, V) logits tensor is never materialized.
    Used when the sharding config enables ``chunked_loss``."""
    B, S, d = x.shape
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0)))
        labels = jnp.pad(labels, ((0, 0), (0, pad)))
        m = jnp.pad(mask if mask is not None else jnp.ones((B, S), jnp.float32),
                    ((0, 0), (0, pad)))
    else:
        m = mask if mask is not None else jnp.ones((B, S), jnp.float32)
    n = (S + pad) // chunk
    xr = x.reshape(B, n, chunk, d)
    lr = labels.reshape(B, n, chunk)
    mr = m.reshape(B, n, chunk)

    def step(carry, i):
        tot, cnt = carry
        logits = lm_logits(xr[:, i], head, vocab_size)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lr[:, i][..., None], axis=-1)[..., 0]
        nll = lse - gold
        if z_loss:
            nll = nll + z_loss * jnp.square(lse)
        mi = mr[:, i].astype(nll.dtype)
        return (tot + (nll * mi).sum(), cnt + mi.sum()), None

    carry = (jnp.float32(0.0), jnp.float32(0.0))
    if unroll:
        for i in range(n):
            carry, _ = step(carry, i)
        tot, cnt = carry
    else:
        (tot, cnt), _ = lax.scan(step, carry, jnp.arange(n))
    return tot / jnp.maximum(cnt, 1.0)
