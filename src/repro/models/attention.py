"""Attention sub-blocks: standard GQA (optionally local/windowed) and MLA
(multi-head latent attention, MiniCPM3/DeepSeek-V2 style), each with a
full-sequence path (train/prefill) and a KV-cache decode path.

The decode path for MLA uses the *absorbed* formulation: scores and context
are computed directly against the latent cache (c_kv, k_pe) so the per-head
K/V are never reconstructed for the whole cache — this is the TPU-friendly
memory form (cache is rank·S instead of 2·H·hd·S).
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs import ModelConfig
from ..sharding.rules import ShardCtx
from .knobs import RunKnobs
from .common import (
    NEG_INF,
    apply_mrope,
    apply_rope,
    chunked_attention,
    decode_attention,
    rms_norm,
)
from .params import ParamSpec


# ---------------------------------------------------------------------------
# Specs
# ---------------------------------------------------------------------------

def attn_spec(cfg: ModelConfig) -> dict:
    if cfg.mla is not None:
        return _mla_spec(cfg)
    d, H, KVH, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    spec = {
        "wq": ParamSpec((d, H * hd), ("embed", "heads_dim"), "scaled_normal"),
        "wk": ParamSpec((d, KVH * hd), ("embed", "heads_dim"), "scaled_normal"),
        "wv": ParamSpec((d, KVH * hd), ("embed", "heads_dim"), "scaled_normal"),
        "wo": ParamSpec((H * hd, d), ("heads_dim", "embed"), "scaled_normal"),
    }
    if cfg.qkv_bias:
        spec["bq"] = ParamSpec((H * hd,), ("heads_dim",), "zeros")
        spec["bk"] = ParamSpec((KVH * hd,), ("heads_dim",), "zeros")
        spec["bv"] = ParamSpec((KVH * hd,), ("heads_dim",), "zeros")
    return spec


def _mla_spec(cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, H = cfg.d_model, cfg.n_heads
    qk = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "w_dq": ParamSpec((d, m.q_lora_rank), ("embed", "mla_rank"), "scaled_normal"),
        "q_norm": ParamSpec((m.q_lora_rank,), ("mla_rank",), "zeros"),
        "w_uq": ParamSpec((m.q_lora_rank, H * qk), ("mla_rank", "heads_dim"), "scaled_normal"),
        "w_dkv": ParamSpec((d, m.kv_lora_rank + m.qk_rope_head_dim),
                           ("embed", "mla_rank"), "scaled_normal"),
        "kv_norm": ParamSpec((m.kv_lora_rank,), ("mla_rank",), "zeros"),
        "w_uk": ParamSpec((m.kv_lora_rank, H * m.qk_nope_head_dim),
                          ("mla_rank", "heads_dim"), "scaled_normal"),
        "w_uv": ParamSpec((m.kv_lora_rank, H * m.v_head_dim),
                          ("mla_rank", "heads_dim"), "scaled_normal"),
        "wo": ParamSpec((H * m.v_head_dim, d), ("heads_dim", "embed"), "scaled_normal"),
    }


# ---------------------------------------------------------------------------
# Standard GQA
# ---------------------------------------------------------------------------

def _qkv(cfg: ModelConfig, p: dict, h: jax.Array):
    B, S, _ = h.shape
    H, KVH, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim_
    q = jnp.einsum("bsd,dk->bsk", h, p["wq"])
    k = jnp.einsum("bsd,dk->bsk", h, p["wk"])
    v = jnp.einsum("bsd,dk->bsk", h, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    return (q.reshape(B, S, H, hd), k.reshape(B, S, KVH, hd),
            v.reshape(B, S, KVH, hd))


def _rope(cfg: ModelConfig, x: jax.Array, positions: jax.Array) -> jax.Array:
    if cfg.vlm is not None:
        return apply_mrope(x, positions, cfg.rope_theta, cfg.vlm.mrope_sections)
    return apply_rope(x, positions, cfg.rope_theta)


def attn_full(
    cfg: ModelConfig,
    p: dict,
    h: jax.Array,                 # (B, S, d) — already normed
    positions: jax.Array,         # (B, S) or (3, B, S) for M-RoPE
    ctx: ShardCtx,
    knobs: RunKnobs,
    *,
    window: Optional[int] = None,
    causal: bool = True,
    return_kv: bool = False,
):
    if cfg.mla is not None:
        return mla_full(cfg, p, h, positions, ctx, knobs,
                        return_kv=return_kv)
    q, k, v = _qkv(cfg, p, h)
    q, k = _rope(cfg, q, positions), _rope(cfg, k, positions)
    q = ctx.constrain(q, ("act_batch", "act_seq", "act_heads", None))
    k = ctx.constrain(k, ("act_batch", "act_seq", "act_heads", None))
    v = ctx.constrain(v, ("act_batch", "act_seq", "act_heads", None))
    w = window if window is not None else (
        cfg.recurrent.attention_window
        if (cfg.attention_kind == "local" and cfg.recurrent) else None)
    if knobs.attn_stub:
        # analysis stub: keep qkv/out projections, skip the attention core
        G = cfg.n_heads // cfg.n_kv_heads
        out = jnp.repeat(v, G, axis=2)
    elif knobs.use_kernels:
        from ..kernels import ops as kops
        out = kops.flash_attention(q, k, v, causal=causal, window=w)
    else:
        out = chunked_attention(q, k, v, causal=causal, window=w,
                                q_block=knobs.q_block, kv_block=knobs.kv_block,
                                unroll=not knobs.scan_layers)
    B, S = h.shape[:2]
    y = jnp.einsum("bsk,kd->bsd", out.reshape(B, S, -1), p["wo"])
    if return_kv:
        return y, (k, v)
    return y


def attn_cache_init(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    KVH, hd = cfg.n_kv_heads, cfg.head_dim_
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "c_kv": jnp.zeros((batch, max_seq, m.kv_lora_rank), dtype),
            "k_pe": jnp.zeros((batch, max_seq, m.qk_rope_head_dim), dtype),
        }
    return {
        "k": jnp.zeros((batch, max_seq, KVH, hd), dtype),
        "v": jnp.zeros((batch, max_seq, KVH, hd), dtype),
    }


def attn_cache_axes(cfg: ModelConfig) -> dict:
    if cfg.mla is not None:
        return {
            "c_kv": ("cache_batch", "cache_seq", None),
            "k_pe": ("cache_batch", "cache_seq", None),
        }
    return {
        "k": ("cache_batch", "cache_seq", "cache_heads", None),
        "v": ("cache_batch", "cache_seq", "cache_heads", None),
    }


def attn_cache_from_prefill(cfg: ModelConfig, kv, max_seq: int) -> dict:
    """Pad prefill-computed K/V (or MLA latents) out to the cache buffer."""
    def pad(x):
        pad_len = max_seq - x.shape[1]
        cfgs = [(0, 0)] * x.ndim
        cfgs[1] = (0, pad_len)
        return jnp.pad(x, cfgs)
    if cfg.mla is not None:
        c_kv, k_pe = kv
        return {"c_kv": pad(c_kv), "k_pe": pad(k_pe)}
    k, v = kv
    return {"k": pad(k), "v": pad(v)}


def attn_decode(
    cfg: ModelConfig,
    p: dict,
    h: jax.Array,                 # (B, 1, d) — already normed
    cache: dict,                  # per-layer cache
    pos: jax.Array,               # () int32 — write index
    lengths: jax.Array,           # (B,) valid lengths incl. this token
    ctx: ShardCtx,
    *,
    window: Optional[int] = None,
) -> Tuple[jax.Array, dict]:
    if cfg.mla is not None:
        return mla_decode(cfg, p, h, cache, pos, lengths, ctx)
    B = h.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    if cfg.vlm is not None:
        positions = jnp.broadcast_to(pos, (3, B, 1)).astype(jnp.int32)
    q, k, v = _qkv(cfg, p, h)
    q, k = _rope(cfg, q, positions), _rope(cfg, k, positions)
    k_cache = lax.dynamic_update_slice(cache["k"], k.astype(cache["k"].dtype),
                                       (0, pos, 0, 0))
    v_cache = lax.dynamic_update_slice(cache["v"], v.astype(cache["v"].dtype),
                                       (0, pos, 0, 0))
    k_cache = ctx.constrain(k_cache, attn_cache_axes(cfg)["k"])
    v_cache = ctx.constrain(v_cache, attn_cache_axes(cfg)["v"])
    out = decode_attention(q, k_cache, v_cache, lengths, window=window)
    y = jnp.einsum("bsk,kd->bsd", out.reshape(B, 1, -1), p["wo"])
    return y, {"k": k_cache, "v": v_cache}


# ---------------------------------------------------------------------------
# MLA
# ---------------------------------------------------------------------------

def _mla_q(cfg: ModelConfig, p: dict, h: jax.Array, positions: jax.Array):
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = h.shape
    dq = rms_norm(jnp.einsum("bsd,dr->bsr", h, p["w_dq"]), p["q_norm"],
                  cfg.norm_eps)
    q = jnp.einsum("bsr,rk->bsk", dq, p["w_uq"]).reshape(
        B, S, H, m.qk_nope_head_dim + m.qk_rope_head_dim)
    q_nope = q[..., :m.qk_nope_head_dim]
    q_pe = apply_rope(q[..., m.qk_nope_head_dim:], positions, cfg.rope_theta)
    return q_nope, q_pe


def _mla_latents(cfg: ModelConfig, p: dict, h: jax.Array, positions: jax.Array):
    m = cfg.mla
    dkv = jnp.einsum("bsd,dr->bsr", h, p["w_dkv"])
    c_kv = rms_norm(dkv[..., :m.kv_lora_rank], p["kv_norm"], cfg.norm_eps)
    k_pe = apply_rope(dkv[..., None, m.kv_lora_rank:], positions,
                      cfg.rope_theta)[:, :, 0]            # (B, S, rope_dim)
    return c_kv, k_pe


def mla_full(cfg: ModelConfig, p: dict, h: jax.Array, positions: jax.Array,
             ctx: ShardCtx, knobs: RunKnobs, *, return_kv: bool = False):
    m, H = cfg.mla, cfg.n_heads
    B, S, _ = h.shape
    q_nope, q_pe = _mla_q(cfg, p, h, positions)
    c_kv, k_pe = _mla_latents(cfg, p, h, positions)
    # reconstruct per-head K/V for the full-sequence path
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    k_nope = jnp.einsum("bsr,rhn->bshn", c_kv, w_uk)
    v = jnp.einsum("bsr,rhv->bshv", c_kv, w_uv)
    q = jnp.concatenate([q_nope, q_pe], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_pe[:, :, None],
                                  (B, S, H, m.qk_rope_head_dim))], axis=-1)
    q = ctx.constrain(q, ("act_batch", "act_seq", "act_heads", None))
    k = ctx.constrain(k, ("act_batch", "act_seq", "act_heads", None))
    v = ctx.constrain(v, ("act_batch", "act_seq", "act_heads", None))
    out = chunked_attention(q, k, v.astype(q.dtype), causal=True,
                            q_block=knobs.q_block, kv_block=knobs.kv_block,
                            unroll=not knobs.scan_layers)
    y = jnp.einsum("bsk,kd->bsd", out.reshape(B, S, -1), p["wo"])
    if return_kv:
        return y, (c_kv, k_pe)
    return y


def mla_decode(cfg: ModelConfig, p: dict, h: jax.Array, cache: dict,
               pos: jax.Array, lengths: jax.Array, ctx: ShardCtx):
    m, H = cfg.mla, cfg.n_heads
    B = h.shape[0]
    positions = jnp.broadcast_to(pos, (B, 1)).astype(jnp.int32)
    q_nope, q_pe = _mla_q(cfg, p, h, positions)          # (B,1,H,·)
    c_new, kpe_new = _mla_latents(cfg, p, h, positions)  # (B,1,r), (B,1,rope)
    c_kv = lax.dynamic_update_slice(cache["c_kv"],
                                    c_new.astype(cache["c_kv"].dtype),
                                    (0, pos, 0))
    k_pe = lax.dynamic_update_slice(cache["k_pe"],
                                    kpe_new.astype(cache["k_pe"].dtype),
                                    (0, pos, 0))
    c_kv = ctx.constrain(c_kv, ("cache_batch", "cache_seq", None))
    k_pe = ctx.constrain(k_pe, ("cache_batch", "cache_seq", None))

    # absorbed scores: q_nope^T (W_uk c) == (W_uk^T q_nope)^T c
    w_uk = p["w_uk"].reshape(m.kv_lora_rank, H, m.qk_nope_head_dim)
    w_uv = p["w_uv"].reshape(m.kv_lora_rank, H, m.v_head_dim)
    q_abs = jnp.einsum("bqhn,rhn->bqhr", q_nope, w_uk)   # (B,1,H,r)
    s = (jnp.einsum("bqhr,bsr->bhqs", q_abs, c_kv,
                    preferred_element_type=jnp.float32)
         + jnp.einsum("bqhe,bse->bhqs", q_pe, k_pe,
                      preferred_element_type=jnp.float32))
    scale = (m.qk_nope_head_dim + m.qk_rope_head_dim) ** -0.5
    s = s * scale
    S = c_kv.shape[1]
    mask = jnp.arange(S)[None] < lengths[:, None]        # (B, S)
    s = jnp.where(mask[:, None, None], s, NEG_INF)
    attn = jax.nn.softmax(s, axis=-1)
    ctx_lat = jnp.einsum("bhqs,bsr->bqhr", attn, c_kv,
                         preferred_element_type=jnp.float32)
    out = jnp.einsum("bqhr,rhv->bqhv", ctx_lat.astype(h.dtype), w_uv)
    y = jnp.einsum("bsk,kd->bsd", out.reshape(B, 1, -1), p["wo"])
    return y, {"c_kv": c_kv, "k_pe": k_pe}
