"""Mamba-2 (SSD — state-space duality) blocks. Attention-free mixer.

Training/prefill uses the chunked SSD algorithm: within-chunk "attention
duality" (quadratic inside a small chunk) plus an inter-chunk state
recurrence carried by ``lax.scan`` — this is the same blocking structure the
Pallas kernel (``repro.kernels.ssd_scan``) implements for TPU VMEM.

Decode is the O(1) state recurrence: ``h = exp(dt·A)·h + dt·B⊗x``.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

from ..configs import ModelConfig
from ..sharding.rules import ShardCtx
from .common import (
    chunked_cross_entropy,
    cross_entropy,
    embed_tokens,
    lm_logits,
    rms_norm,
)
from .knobs import DEFAULT_KNOBS, RunKnobs
from .params import ParamSpec, scan_or_loop, stack


# ---------------------------------------------------------------------------
# Spec
# ---------------------------------------------------------------------------

def _dims(cfg: ModelConfig):
    s = cfg.ssm
    d_in = s.d_inner(cfg.d_model)
    H = s.n_heads(cfg.d_model)
    return s, d_in, H, s.head_dim, s.n_groups * s.d_state


def block_spec(cfg: ModelConfig) -> dict:
    s, d_in, H, P, gn = _dims(cfg)
    d = cfg.d_model
    return {
        "ln": ParamSpec((d,), ("embed",), "zeros"),
        "w_z": ParamSpec((d, d_in), ("embed", "ssm_inner"), "scaled_normal"),
        "w_x": ParamSpec((d, d_in), ("embed", "ssm_inner"), "scaled_normal"),
        "w_B": ParamSpec((d, gn), ("embed", None), "scaled_normal"),
        "w_C": ParamSpec((d, gn), ("embed", None), "scaled_normal"),
        "w_dt": ParamSpec((d, H), ("embed", None), "scaled_normal"),
        "conv_x": ParamSpec((s.d_conv, d_in), (None, "ssm_inner"), "scaled_normal"),
        "conv_B": ParamSpec((s.d_conv, gn), (None, None), "scaled_normal"),
        "conv_C": ParamSpec((s.d_conv, gn), (None, None), "scaled_normal"),
        "A_log": ParamSpec((H,), (None,), "zeros"),
        "D": ParamSpec((H,), (None,), "ones"),
        "dt_bias": ParamSpec((H,), (None,), "zeros"),
        "gate_norm": ParamSpec((d_in,), ("ssm_inner",), "zeros"),
        "w_out": ParamSpec((d_in, d), ("ssm_inner", "embed"), "scaled_normal"),
    }


def model_spec(cfg: ModelConfig) -> dict:
    v = cfg.padded_vocab()
    return {
        "embed": {"tok": ParamSpec((v, cfg.d_model), ("vocab", "embed"),
                                   "normal", 0.02)},
        "blocks": stack(block_spec(cfg), cfg.n_layers),
        "ln_f": ParamSpec((cfg.d_model,), ("embed",), "zeros"),
        "lm_head": ParamSpec((cfg.d_model, v), ("embed", "vocab"),
                             "scaled_normal"),
    }


# ---------------------------------------------------------------------------
# Causal depthwise conv (shift-sum form; SPMD-friendly, no conv primitive)
# ---------------------------------------------------------------------------

def causal_conv(x: jax.Array, kernel: jax.Array) -> jax.Array:
    """x: (B, S, C); kernel: (W, C). y[t] = sum_w k[w] * x[t - (W-1) + w]."""
    W = kernel.shape[0]
    out = x * kernel[W - 1]
    for w in range(W - 1):
        shift = W - 1 - w
        shifted = jnp.pad(x, ((0, 0), (shift, 0), (0, 0)))[:, :-shift]
        out = out + shifted * kernel[w]
    return out


def conv_step(window: jax.Array, kernel: jax.Array, x_new: jax.Array):
    """window: (B, W-1, C) past inputs; x_new: (B, 1, C).
    Returns (y (B, 1, C), new window)."""
    full = jnp.concatenate([window, x_new], axis=1)         # (B, W, C)
    y = jnp.einsum("bwc,wc->bc", full, kernel)[:, None]
    return y, full[:, 1:]


# ---------------------------------------------------------------------------
# SSD core (chunked scan)
# ---------------------------------------------------------------------------

def ssd_scan(
    x: jax.Array,        # (B, S, H, P)  — dt-scaled inputs
    a: jax.Array,        # (B, S, H)     — log decays (dt * A, negative)
    Bm: jax.Array,       # (B, S, H, N)
    Cm: jax.Array,       # (B, S, H, N)
    chunk: int,
    h0: Optional[jax.Array] = None,
    unroll: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    """Chunked SSD. Returns (y (B,S,H,P), final state (B,H,P,N))."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad), (0, 0), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, pad), (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), (0, pad), (0, 0), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, pad), (0, 0), (0, 0)))
    nc = (S + pad) // chunk
    xr = x.reshape(Bsz, nc, chunk, H, P)
    ar = a.reshape(Bsz, nc, chunk, H).astype(jnp.float32)
    Br = Bm.reshape(Bsz, nc, chunk, H, N)
    Cr = Cm.reshape(Bsz, nc, chunk, H, N)

    if h0 is None:
        h0 = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(h_prev, ci):
        xq, aq, bq, cq = xr[:, ci], ar[:, ci], Br[:, ci], Cr[:, ci]
        a_cum = jnp.cumsum(aq, axis=1)                       # (B,q,H)
        # intra-chunk (dual "attention" form): decay(i<-j) = exp(acum_i - acum_j)
        scores = jnp.einsum("bihn,bjhn->bhij", cq, bq,
                            preferred_element_type=jnp.float32)
        decay = jnp.exp(a_cum[:, :, None] - a_cum[:, None, :]  # (B,i,j,H)
                        ).transpose(0, 3, 1, 2)               # (B,H,i,j)
        mask = jnp.tril(jnp.ones((chunk, chunk), bool))
        L = jnp.where(mask[None, None], scores * decay, 0.0)
        y_intra = jnp.einsum("bhij,bjhp->bihp", L, xq.astype(jnp.float32))
        # inter-chunk: y_i += (C_i · h_prev) * exp(acum_i)
        y_inter = jnp.einsum("bihn,bhpn->bihp", cq.astype(jnp.float32), h_prev)
        y_inter = y_inter * jnp.exp(a_cum)[..., None]
        # state update
        chunk_decay = jnp.exp(a_cum[:, -1])                  # (B,H)
        in_decay = jnp.exp(a_cum[:, -1:, :] - a_cum)         # (B,q,H)
        dh = jnp.einsum("bqhn,bqhp,bqh->bhpn", bq.astype(jnp.float32),
                        xq.astype(jnp.float32), in_decay)
        h_new = chunk_decay[:, :, None, None] * h_prev + dh
        return h_new, (y_intra + y_inter).astype(x.dtype)

    if unroll:
        h_final, ys_l = h0, []
        for ci in range(nc):
            h_final, y_c = step(h_final, ci)
            ys_l.append(y_c)
        ys = jnp.stack(ys_l)
    else:
        h_final, ys = lax.scan(step, h0, jnp.arange(nc))     # ys (nc,B,q,H,P)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, S + pad, H, P)[:, :S]
    return y, h_final


def ssd_step(h: jax.Array, x: jax.Array, a: jax.Array, Bm: jax.Array,
             Cm: jax.Array) -> Tuple[jax.Array, jax.Array]:
    """Single-token recurrence. h (B,H,P,N); x (B,H,P); a (B,H);
    Bm/Cm (B,H,N). Returns (y (B,H,P), h_new)."""
    h_new = jnp.exp(a)[..., None, None] * h + jnp.einsum(
        "bhp,bhn->bhpn", x.astype(jnp.float32), Bm.astype(jnp.float32))
    y = jnp.einsum("bhpn,bhn->bhp", h_new, Cm.astype(jnp.float32))
    return y.astype(x.dtype), h_new


# ---------------------------------------------------------------------------
# Block forward
# ---------------------------------------------------------------------------

def _proj_inputs(cfg: ModelConfig, p: dict, h: jax.Array):
    """Shared between full and step paths. h already normed."""
    s, d_in, H, P, gn = _dims(cfg)
    z = jnp.einsum("bsd,di->bsi", h, p["w_z"])
    x = jnp.einsum("bsd,di->bsi", h, p["w_x"])
    Bm = jnp.einsum("bsd,dn->bsn", h, p["w_B"])
    Cm = jnp.einsum("bsd,dn->bsn", h, p["w_C"])
    dt = jnp.einsum("bsd,dh->bsh", h, p["w_dt"])
    return z, x, Bm, Cm, dt


def _gates(cfg, p, x, Bm, Cm, dt):
    """Post-conv activations + continuous-time discretization."""
    s, d_in, H, P, gn = _dims(cfg)
    Bsz, S = x.shape[:2]
    x = jax.nn.silu(x)
    Bm = jax.nn.silu(Bm)
    Cm = jax.nn.silu(Cm)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])   # (B,S,H)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))                  # (H,)
    a = dt * A                                                    # log decay
    xh = x.reshape(Bsz, S, H, P)
    x_dt = xh * dt[..., None].astype(xh.dtype)
    rep = H // s.n_groups
    Bh = jnp.repeat(Bm.reshape(Bsz, S, s.n_groups, s.d_state), rep, axis=2)
    Ch = jnp.repeat(Cm.reshape(Bsz, S, s.n_groups, s.d_state), rep, axis=2)
    return xh, x_dt, a, Bh, Ch


def block_full(cfg: ModelConfig, p: dict, x_res: jax.Array, ctx: ShardCtx,
               knobs: RunKnobs, collect_state: bool = False):
    s, d_in, H, P, gn = _dims(cfg)
    h = rms_norm(x_res, p["ln"], cfg.norm_eps)
    z, x, Bm, Cm, dt = _proj_inputs(cfg, p, h)
    conv_in = jnp.concatenate([x, Bm, Cm], axis=-1)
    x = causal_conv(x, p["conv_x"])
    Bm = causal_conv(Bm, p["conv_B"])
    Cm = causal_conv(Cm, p["conv_C"])
    xh, x_dt, a, Bh, Ch = _gates(cfg, p, x, Bm, Cm, dt)
    if knobs.use_kernels:
        from ..kernels import ops as kops
        y, h_final = kops.ssd(x_dt, a, Bh, Ch, chunk=s.chunk_size)
    else:
        y, h_final = ssd_scan(x_dt, a, Bh, Ch, chunk=s.chunk_size,
                              unroll=not knobs.scan_layers)
    y = y + p["D"][None, None, :, None] * xh                     # skip
    Bsz, S = x_res.shape[:2]
    y = y.reshape(Bsz, S, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    if collect_state:
        state = {"ssm": h_final,
                 "conv": conv_in[:, -(s.d_conv - 1):]}
        return x_res + out, state
    return x_res + out, None


def block_step(cfg: ModelConfig, p: dict, x_res: jax.Array, cache: dict,
               ctx: ShardCtx):
    """x_res: (B, 1, d). cache: {"ssm": (B,H,P,N), "conv": (B,W-1,C)}."""
    s, d_in, H, P, gn = _dims(cfg)
    h = rms_norm(x_res, p["ln"], cfg.norm_eps)
    z, x, Bm, Cm, dt = _proj_inputs(cfg, p, h)
    conv_in = jnp.concatenate([x, Bm, Cm], axis=-1)              # (B,1,C)
    kernel = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)
    y_conv, new_window = conv_step(cache["conv"], kernel, conv_in)
    x, Bm, Cm = jnp.split(y_conv, [d_in, d_in + gn], axis=-1)
    xh, x_dt, a, Bh, Ch = _gates(cfg, p, x, Bm, Cm, dt)
    y, h_new = ssd_step(cache["ssm"], x_dt[:, 0], a[:, 0], Bh[:, 0], Ch[:, 0])
    y = y[:, None] + p["D"][None, None, :, None] * xh
    Bsz = x_res.shape[0]
    y = y.reshape(Bsz, 1, d_in)
    y = rms_norm(y * jax.nn.silu(z), p["gate_norm"], cfg.norm_eps)
    out = jnp.einsum("bsi,id->bsd", y, p["w_out"])
    return x_res + out, {"ssm": h_new, "conv": new_window}


# ---------------------------------------------------------------------------
# Model-level API
# ---------------------------------------------------------------------------

def loss_fn(cfg, params, batch, ctx=ShardCtx(), knobs=DEFAULT_KNOBS,
            z_loss: float = 0.0):
    dtype = jnp.dtype(cfg.dtype)
    x = embed_tokens(params["embed"]["tok"], batch["tokens"], dtype)

    def body(x, lp):
        x = ctx.constrain(x, ("act_batch", "act_seq", "act_embed"))
        x, _ = block_full(cfg, lp, x, ctx, DEFAULT_KNOBS if knobs is None else knobs)
        return x, jnp.float32(0.0)

    from .transformer import _remat
    x, _ = scan_or_loop(_remat(body, knobs.remat), x, params["blocks"],
                        scan=knobs.scan_layers, length=cfg.n_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    if knobs.chunked_loss:
        ce = chunked_cross_entropy(x, params["lm_head"], batch["labels"],
                                   cfg.vocab_size, batch.get("mask"), z_loss,
                                   knobs.loss_chunk,
                                   unroll=not knobs.scan_layers)
    else:
        logits = lm_logits(x, params["lm_head"], cfg.vocab_size)
        ce = cross_entropy(logits, batch["labels"], batch.get("mask"), z_loss)
    return ce, {"ce": ce, "moe_aux": jnp.float32(0.0)}


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype) -> dict:
    s, d_in, H, P, gn = _dims(cfg)
    L = cfg.n_layers
    return {
        "layers": {
            "ssm": jnp.zeros((L, batch, H, P, s.d_state), jnp.float32),
            "conv": jnp.zeros((L, batch, s.d_conv - 1, d_in + 2 * gn), dtype),
        },
        "pos": jnp.zeros((), jnp.int32),
        "lengths": jnp.zeros((batch,), jnp.int32),
    }


def cache_axes(cfg: ModelConfig) -> dict:
    return {
        "layers": {
            "ssm": ("layers", "cache_batch", "act_heads", None, None),
            "conv": ("layers", "cache_batch", None, "ssm_inner"),
        },
        "pos": (),
        "lengths": ("cache_batch",),
    }


def prefill(cfg, params, batch, ctx=ShardCtx(), knobs=DEFAULT_KNOBS,
            cache_len=None):
    dtype = jnp.dtype(cfg.dtype)
    x = embed_tokens(params["embed"]["tok"], batch["tokens"], dtype)
    B, S = batch["tokens"].shape

    def body(x, lp):
        x = ctx.constrain(x, ("act_batch", "act_seq", "act_embed"))
        x, state = block_full(cfg, lp, x, ctx, knobs, collect_state=True)
        return x, state

    x, states = scan_or_loop(body, x, params["blocks"],
                             scan=knobs.scan_layers, length=cfg.n_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = lm_logits(x[:, -1:], params["lm_head"], cfg.vocab_size)
    cache = {"layers": states,
             "pos": jnp.int32(S),
             "lengths": jnp.full((B,), S, jnp.int32)}
    return logits[:, 0], cache


def decode_step(cfg, params, cache, batch, ctx=ShardCtx(),
                knobs=DEFAULT_KNOBS):
    dtype = jnp.dtype(cfg.dtype)
    x = embed_tokens(params["embed"]["tok"], batch["tokens"], dtype)

    def body(x, xs):
        lp, cache_l = xs
        x, new_cache_l = block_step(cfg, lp, x, cache_l, ctx)
        return x, new_cache_l

    x, new_layers = scan_or_loop(body, x, (params["blocks"], cache["layers"]),
                                 scan=knobs.scan_layers, length=cfg.n_layers)
    x = rms_norm(x, params["ln_f"], cfg.norm_eps)
    logits = lm_logits(x, params["lm_head"], cfg.vocab_size)
    return logits[:, 0], {"layers": new_layers, "pos": cache["pos"] + 1,
                          "lengths": cache["lengths"] + 1}
