"""Unified model API.

``get_model(cfg)`` returns a :class:`Model` facade dispatching to the family
implementation (transformer / ssm / rglru / encdec). The facade is what the
FaaS layer registers as *functions* (train_step / prefill / decode_step) and
what the dry-run lowers.

``input_specs(cfg, shape, kind)`` builds ShapeDtypeStruct stand-ins for every
model input — weak-type-correct, shardable, no device allocation.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp

from ..configs import ModelConfig, ShapeConfig
from ..sharding.rules import ShardCtx
from . import encdec, params as P, rglru, ssm, transformer
from .knobs import DEFAULT_KNOBS, RunKnobs


def _family_module(cfg: ModelConfig):
    if cfg.family == "ssm":
        return ssm
    if cfg.family == "hybrid":
        return rglru
    if cfg.family == "audio":
        return encdec
    return transformer       # dense | moe | vlm


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    @property
    def mod(self):
        return _family_module(self.cfg)

    # ---- parameters --------------------------------------------------------
    def spec(self) -> dict:
        return self.mod.model_spec(self.cfg)

    def init(self, key: jax.Array, dtype=None) -> Any:
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        return P.init_params(self.spec(), key, dtype)

    def abstract_params(self, dtype=None) -> Any:
        dtype = dtype or jnp.dtype(self.cfg.param_dtype)
        return P.abstract_params(self.spec(), dtype)

    def param_axes(self) -> Any:
        return P.logical_axes(self.spec())

    def param_count(self) -> int:
        return P.count_params(self.spec())

    def active_param_count(self) -> int:
        """Parameters touched per token (MoE discount). Used for 6·N·D."""
        total = self.param_count()
        m = self.cfg.moe
        if m is None:
            return total
        inactive_per_layer = 3 * (m.n_experts - m.top_k) * \
            self.cfg.d_model * m.d_ff_expert
        return total - inactive_per_layer * self.cfg.n_layers

    # ---- computations ------------------------------------------------------
    # Parameters are kept in ``param_dtype`` (fp32 master); computation casts
    # them to the activation dtype once at entry (mixed precision).
    def _cast(self, params):
        return P.cast_floats(params, jnp.dtype(self.cfg.dtype))

    def loss(self, params, batch, ctx: ShardCtx = ShardCtx(),
             knobs: RunKnobs = DEFAULT_KNOBS, z_loss: float = 0.0):
        return self.mod.loss_fn(self.cfg, self._cast(params), batch, ctx,
                                knobs, z_loss)

    def prefill(self, params, batch, ctx: ShardCtx = ShardCtx(),
                knobs: RunKnobs = DEFAULT_KNOBS, cache_len=None):
        return self.mod.prefill(self.cfg, self._cast(params), batch, ctx,
                                knobs, cache_len=cache_len)

    def decode_step(self, params, cache, batch, ctx: ShardCtx = ShardCtx(),
                    knobs: RunKnobs = DEFAULT_KNOBS):
        return self.mod.decode_step(self.cfg, self._cast(params), cache,
                                    batch, ctx, knobs)

    # ---- caches ------------------------------------------------------------
    def init_cache(self, batch: int, max_seq: int, dtype=None, **kw):
        dtype = dtype or jnp.dtype(self.cfg.dtype)
        return self.mod.init_cache(self.cfg, batch, max_seq, dtype, **kw)

    def abstract_cache(self, batch: int, max_seq: int, dtype=None, **kw):
        dtype = dtype or jnp.dtype(self.cfg.dtype)
        return jax.eval_shape(
            lambda: self.mod.init_cache(self.cfg, batch, max_seq, dtype, **kw))

    def cache_axes(self) -> dict:
        return self.mod.cache_axes(self.cfg)


def get_model(cfg: ModelConfig) -> Model:
    return Model(cfg)


# ---------------------------------------------------------------------------
# Input specs (ShapeDtypeStruct stand-ins for the dry-run & FaaS signatures)
# ---------------------------------------------------------------------------

def input_specs(cfg: ModelConfig, shape: ShapeConfig,
                kind: Optional[str] = None) -> Dict[str, Any]:
    """Abstract inputs for one (arch × shape) cell.

    kind: "train" | "prefill" | "decode" (defaults to shape.kind).
    For decode, the cache spec is produced separately via
    :meth:`Model.abstract_cache` — this returns only the step inputs.
    """
    kind = kind or shape.kind
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    bf16 = jnp.bfloat16
    tok = lambda b, s: jax.ShapeDtypeStruct((b, s), i32)

    if kind == "decode":
        return {"tokens": tok(B, 1)}

    if cfg.family == "audio":
        half = S // 2
        specs = {"frames": jax.ShapeDtypeStruct((B, half, cfg.d_model), bf16),
                 "tokens": tok(B, half)}
        if kind == "train":
            specs["labels"] = tok(B, half)
        return specs

    if cfg.family == "vlm":
        pfx = cfg.vlm.vision_prefix_len
        text = S - pfx
        specs = {"tokens": tok(B, text),
                 "patches": jax.ShapeDtypeStruct((B, pfx, cfg.d_model), bf16)}
        if kind == "train":
            specs["labels"] = tok(B, text)
        return specs

    specs = {"tokens": tok(B, S)}
    if kind == "train":
        specs["labels"] = tok(B, S)
    return specs


def concrete_batch(cfg: ModelConfig, shape: ShapeConfig, key: jax.Array,
                   kind: Optional[str] = None) -> Dict[str, jax.Array]:
    """Random concrete inputs matching input_specs (for smoke tests/examples)."""
    specs = input_specs(cfg, shape, kind)
    out = {}
    for name, s in specs.items():
        key, sub = jax.random.split(key)
        if jnp.issubdtype(s.dtype, jnp.integer):
            out[name] = jax.random.randint(sub, s.shape, 0, cfg.vocab_size,
                                           s.dtype)
        else:
            out[name] = jax.random.normal(sub, s.shape, jnp.float32).astype(s.dtype)
    return out


def decode_cache_kwargs(cfg: ModelConfig, shape: ShapeConfig) -> dict:
    """Per-family kwargs for init_cache at a decode cell."""
    if cfg.family == "audio":
        half = shape.seq_len // 2
        return {"batch": shape.global_batch, "max_seq": half,
                "src_len": half}
    return {"batch": shape.global_batch, "max_seq": shape.seq_len}
