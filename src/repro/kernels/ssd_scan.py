"""Pallas TPU kernel for the Mamba-2 SSD chunked scan.

Adaptation notes: the SSD "state-space duality" algorithm is already block
structured (quadratic within a chunk, linear state recurrence across
chunks), which maps directly onto TPU: each (batch, head) pair is a parallel
grid axis, chunks are the innermost "arbitrary" axis, and the (P × N) state
carried between chunks lives in VMEM scratch. The intra-chunk quadratic term
is an MXU matmul of (chunk × N) @ (N × chunk); the causal decay mask is
built with `broadcasted_iota` (2-D iota, TPU-legal).

Layouts: x (B, H, S, P) dt-scaled inputs; a (B, H, S, 1) log-decays;
Bm/Cm (B, H, S, N). Outputs: y (B, H, S, P) and final state (B, H, P, N).
"""
from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _ssd_kernel(x_ref, a_ref, b_ref, c_ref, y_ref, state_out_ref, h_ref,
                *, chunk: int):
    ci = pl.program_id(2)
    nc = pl.num_programs(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    xq = x_ref[0, 0].astype(jnp.float32)                  # (q, P)
    aq = a_ref[0, 0, :, 0].astype(jnp.float32)            # (q,)
    bq = b_ref[0, 0].astype(jnp.float32)                  # (q, N)
    cq = c_ref[0, 0].astype(jnp.float32)                  # (q, N)

    a_cum = jnp.cumsum(aq)                                # (q,)
    # intra-chunk: L[i, j] = C_i·B_j · exp(acum_i - acum_j) · [j <= i]
    scores = lax.dot_general(cq, bq, (((1,), (1,)), ((), ())),
                             preferred_element_type=jnp.float32)  # (q, q)
    ii = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    jj = lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    decay = jnp.exp(a_cum[:, None] - a_cum[None, :])
    L = jnp.where(jj <= ii, scores * decay, 0.0)
    y = lax.dot_general(L, xq, (((1,), (0,)), ((), ())),
                        preferred_element_type=jnp.float32)       # (q, P)

    # inter-chunk: y_i += exp(acum_i) · C_i · h_prev
    h = h_ref[...]                                        # (P, N)
    y_inter = lax.dot_general(cq, h, (((1,), (1,)), ((), ())),
                              preferred_element_type=jnp.float32)  # (q, P)
    y = y + y_inter * jnp.exp(a_cum)[:, None]
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state update: h ← exp(acum_end)·h + Σ_j exp(acum_end − acum_j)·x_j⊗B_j
    in_decay = jnp.exp(a_cum[-1] - a_cum)                 # (q,)
    dh = lax.dot_general(xq * in_decay[:, None], bq,
                         (((0,), (0,)), ((), ())),
                         preferred_element_type=jnp.float32)       # (P, N)
    h_ref[...] = jnp.exp(a_cum[-1]) * h + dh

    @pl.when(ci == nc - 1)
    def _final():
        state_out_ref[0, 0] = h_ref[...]


def ssd_scan_kernel(
    x: jax.Array,                 # (B, H, S, P)
    a: jax.Array,                 # (B, H, S)
    Bm: jax.Array,                # (B, H, S, N)
    Cm: jax.Array,                # (B, H, S, N)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> Tuple[jax.Array, jax.Array]:
    B, H, S, P = x.shape
    N = Bm.shape[-1]
    chunk = min(chunk, S)
    pad = (-S) % chunk
    if pad:
        # a=0 → decay exp(0)=1 and x=0 → no state contribution: exact padding
        x = jnp.pad(x, ((0, 0), (0, 0), (0, pad), (0, 0)))
        a = jnp.pad(a, ((0, 0), (0, 0), (0, pad)))
        Bm = jnp.pad(Bm, ((0, 0), (0, 0), (0, pad), (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), (0, 0), (0, pad), (0, 0)))
    nc = (S + pad) // chunk
    a4 = a[..., None]                                      # (B, H, S, 1)

    from jax.experimental.pallas import tpu as pltpu
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    y, state = pl.pallas_call(
        functools.partial(_ssd_kernel, chunk=chunk),
        grid=(B, H, nc),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, 1), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, chunk, N), lambda b, h, c: (b, h, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, c: (b, h, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, h, c: (b, h, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S + pad, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(x, a4, Bm, Cm)
    return y[:, :, :S], state
