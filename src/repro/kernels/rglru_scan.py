"""Pallas TPU kernel for the RG-LRU linear recurrence  h_t = a_t·h_t-1 + b_t.

Adaptation notes: on GPU this is usually a warp-parallel chunked scan; on TPU
we tile the *width* dimension across a parallel grid axis (each 128-lane tile
is an independent recurrence) and walk the sequence with an "arbitrary" grid
dimension whose carry lives in VMEM scratch. Inside a sequence block the
recurrence runs as a `fori_loop` over time — the VPU processes the whole
width tile per step.

Layout: a, b (B, S, W) → h (B, S, W). Grid: (B, nw, ns), ns innermost.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl


def _rglru_kernel(a_ref, b_ref, o_ref, h_ref, *, block_s: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    def step(t, h):
        h = a_ref[0, t, :].astype(jnp.float32) * h + \
            b_ref[0, t, :].astype(jnp.float32)
        o_ref[0, t, :] = h.astype(o_ref.dtype)
        return h

    h_ref[...] = lax.fori_loop(0, block_s, step, h_ref[...])


def rglru_scan_kernel(
    a: jax.Array,                 # (B, S, W) decay in (0, 1]
    b: jax.Array,                 # (B, S, W) input term
    *,
    block_s: int = 256,
    block_w: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, S, W = a.shape
    block_s = min(block_s, S)
    block_w = min(block_w, W)
    pad_s = (-S) % block_s
    pad_w = (-W) % block_w
    if pad_s or pad_w:
        # identity elements: a=1, b=0 keep the carry exact under padding
        a = jnp.pad(a, ((0, 0), (0, pad_s), (0, pad_w)), constant_values=1.0)
        b = jnp.pad(b, ((0, 0), (0, pad_s), (0, pad_w)))
    ns = (S + pad_s) // block_s
    nw = (W + pad_w) // block_w

    from jax.experimental.pallas import tpu as pltpu
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        functools.partial(_rglru_kernel, block_s=block_s),
        grid=(B, nw, ns),
        in_specs=[
            pl.BlockSpec((1, block_s, block_w), lambda b_, w, s: (b_, s, w)),
            pl.BlockSpec((1, block_s, block_w), lambda b_, w, s: (b_, s, w)),
        ],
        out_specs=pl.BlockSpec((1, block_s, block_w),
                               lambda b_, w, s: (b_, s, w)),
        out_shape=jax.ShapeDtypeStruct((B, S + pad_s, W + pad_w), b.dtype),
        scratch_shapes=[pltpu.VMEM((block_w,), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(a, b)
    return out[:, :S, :W]
