"""Pure-jnp oracles for every Pallas kernel. Deliberately naive — full
masks, sequential scans — so they are trivially auditable. Kernel tests
sweep shapes/dtypes and assert_allclose against these.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def ref_attention(
    q: jax.Array,                 # (B, Sq, H, D)   — model layout
    k: jax.Array,                 # (B, Sk, KVH, D)
    v: jax.Array,                 # (B, Sk, KVH, Dv)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    softmax_scale: Optional[float] = None,
) -> jax.Array:
    B, Sq, H, D = q.shape
    Sk, KVH = k.shape[1], k.shape[2]
    G = H // KVH
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    qr = (q * scale).reshape(B, Sq, KVH, G, D).astype(jnp.float32)
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qr, k.astype(jnp.float32))
    q_pos = jnp.arange(Sq)[:, None] + q_offset
    k_pos = jnp.arange(Sk)[None, :]
    mask = jnp.ones((Sq, Sk), bool)
    if causal:
        mask &= q_pos >= k_pos
    if window is not None:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return out.reshape(B, Sq, H, v.shape[-1]).astype(q.dtype)


def ref_rglru(a: jax.Array, b: jax.Array,
              h0: Optional[jax.Array] = None) -> jax.Array:
    """Sequential recurrence h_t = a_t·h_{t-1} + b_t. a, b: (B, S, W)."""
    B, S, W = a.shape
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)

    def step(h, t):
        h = a[:, t].astype(jnp.float32) * h + b[:, t].astype(jnp.float32)
        return h, h

    _, hs = lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(hs, 0, 1).astype(b.dtype)          # (B, S, W)


def ref_ssd(
    x: jax.Array,                 # (B, S, H, P) — model layout, dt-scaled
    a: jax.Array,                 # (B, S, H)    — log decays
    Bm: jax.Array,                # (B, S, H, N)
    Cm: jax.Array,                # (B, S, H, N)
    h0: Optional[jax.Array] = None,
) -> Tuple[jax.Array, jax.Array]:
    """Token-by-token SSD recurrence. Returns (y (B,S,H,P), h (B,H,P,N))."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    if h0 is None:
        h0 = jnp.zeros((B, H, P, N), jnp.float32)

    def step(h, t):
        decay = jnp.exp(a[:, t].astype(jnp.float32))[..., None, None]
        h = decay * h + jnp.einsum("bhp,bhn->bhpn",
                                   x[:, t].astype(jnp.float32),
                                   Bm[:, t].astype(jnp.float32))
        y = jnp.einsum("bhpn,bhn->bhp", h, Cm[:, t].astype(jnp.float32))
        return h, y

    h, ys = lax.scan(step, h0, jnp.arange(S))
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h
