"""Pallas TPU kernel for decode attention: one query token per sequence
against a long KV cache (the serving hot path; memory-bandwidth bound).

Adaptation notes: on GPU this is the "flash-decoding" split-K pattern with
inter-CTA reduction in global memory; on TPU we walk the cache blocks with
the innermost "arbitrary" grid dimension and carry the online-softmax
running stats in VMEM scratch — no cross-core reduction step is needed
because the sequential grid already owns the whole reduction. q stays
resident in VMEM for all cache blocks; each (b, h) pair is an independent
parallel grid cell.

Layouts: q (B, H, D); k/v cache (B, KVH, S, D); lengths (B,).
Grid: (B, H, nS) with nS innermost.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _decode_kernel(len_ref, q_ref, k_ref, v_ref, o_ref,
                   m_ref, l_ref, acc_ref, *,
                   scale: float, block_s: int,
                   window: Optional[int]):
    si = pl.program_id(2)
    ns = pl.num_programs(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    length = len_ref[0, 0]
    pos = si * block_s + lax.broadcasted_iota(jnp.int32, (1, block_s), 1)
    mask = pos < length
    if window is not None:
        mask = jnp.logical_and(mask, pos >= length - window)

    # skip cache blocks that are entirely beyond the valid length
    @pl.when(si * block_s < length)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (1, D)... (D,)
        k = k_ref[0, 0].astype(jnp.float32)                    # (bs, D)
        v = v_ref[0, 0].astype(jnp.float32)                    # (bs, D)
        s = lax.dot_general(q[None, :], k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32)  # (1, bs)
        s = jnp.where(mask, s, NEG_INF)
        m_prev, l_prev = m_ref[0], l_ref[0]
        m_new = jnp.maximum(m_prev, s.max())
        p = jnp.exp(s - m_new)
        corr = jnp.exp(m_prev - m_new)
        l_ref[0] = l_prev * corr + p.sum()
        m_ref[0] = m_new
        acc_ref[...] = acc_ref[...] * corr + lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)[0]

    @pl.when(si == ns - 1)
    def _finish():
        l = jnp.maximum(l_ref[0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l).astype(o_ref.dtype)


def decode_attention_kernel(
    q: jax.Array,                 # (B, H, D)
    k: jax.Array,                 # (B, KVH, S, D)
    v: jax.Array,                 # (B, KVH, S, D)
    lengths: jax.Array,           # (B,) int32
    *,
    window: Optional[int] = None,
    softmax_scale: Optional[float] = None,
    block_s: int = 512,
    interpret: bool = False,
) -> jax.Array:
    B, H, D = q.shape
    KVH, S = k.shape[1], k.shape[2]
    G = H // KVH
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    block_s = min(block_s, S)
    pad_s = (-S) % block_s
    if pad_s:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_s), (0, 0)))
    ns = (S + pad_s) // block_s

    from jax.experimental.pallas import tpu as pltpu
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"))

    out = pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, block_s=block_s,
                          window=window),
        grid=(B, H, ns),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, s: (b, 0)),   # lengths (B,1)
            pl.BlockSpec((1, 1, D), lambda b, h, s: (b, h, 0)),
            pl.BlockSpec((1, 1, block_s, D),
                         lambda b, h, s, G=G: (b, h // G, s, 0)),
            pl.BlockSpec((1, 1, block_s, D),
                         lambda b, h, s, G=G: (b, h // G, s, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, D), lambda b, h, s: (b, h, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, D), q.dtype),
        scratch_shapes=[pltpu.VMEM((1,), jnp.float32),
                        pltpu.VMEM((1,), jnp.float32),
                        pltpu.VMEM((D,), jnp.float32)],
        interpret=interpret,
        **kwargs,
    )(lengths.reshape(B, 1), q, k, v)
    return out
