"""Pallas TPU flash-attention kernel (causal / sliding-window, GQA-aware).

Adaptation notes (DESIGN.md §2): the GPU flash-attention algorithm is
re-blocked for the TPU memory hierarchy — q/k/v tiles live in VMEM via
BlockSpecs, the online-softmax running statistics live in VMEM scratch that
persists across the innermost ("arbitrary") kv-block grid dimension, and the
MXU sees (block_q × head_dim) @ (head_dim × block_k) matmuls with
128-aligned tiles. There is no warp-level shuffling to port; the reduction
is carried by the grid schedule instead.

Layout: q (B, H, Sq, D); k/v (B, KVH, Sk, D). Grid: (B, H, nq, nk) with nk
innermost so each (b, h, qi) accumulates over kv blocks sequentially.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,          # VMEM blocks
    o_ref,                        # output block
    m_ref, l_ref, acc_ref,        # VMEM scratch (persist across kv steps)
    *,
    scale: float,
    causal: bool,
    window: Optional[int],
    q_offset: int,
    sq: int,
    sk: int,
    block_q: int,
    block_k: int,
):
    qi = pl.program_id(2)
    ki = pl.program_id(3)
    nk = pl.num_programs(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_pos = qi * block_q + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0) + q_offset
    k_pos = ki * block_k + lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)

    # Skip compute for blocks that are fully masked (causal upper triangle /
    # outside the sliding window). The grid still visits them, but the MXU
    # work is gated out — the TPU analogue of early-exit per CTA.
    q_lo = qi * block_q + q_offset
    q_hi = q_lo + block_q - 1
    k_lo = ki * block_k
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_lo <= q_hi)
    if window is not None:
        k_hi_blk = k_lo + block_k - 1
        live = jnp.logical_and(live, k_hi_blk > q_lo - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * scale            # (bq, D)
        k = k_ref[0, 0].astype(jnp.float32)                    # (bk, D)
        v = v_ref[0, 0].astype(jnp.float32)                    # (bk, D)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32)
        mask = k_pos < sk
        if causal:
            mask = jnp.logical_and(mask, q_pos >= k_pos)
        if window is not None:
            mask = jnp.logical_and(mask, q_pos - k_pos < window)
        s = jnp.where(mask, s, NEG_INF)

        m_prev, l_prev = m_ref[...], l_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_prev * corr + p.sum(axis=-1)
        m_ref[...] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)

    @pl.when(ki == nk - 1)
    def _finish():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def flash_attention_kernel(
    q: jax.Array,                  # (B, H, Sq, D)
    k: jax.Array,                  # (B, KVH, Sk, D)
    v: jax.Array,                  # (B, KVH, Sk, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    softmax_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, H, Sq, D = q.shape
    KVH, Sk = k.shape[1], k.shape[2]
    G = H // KVH
    scale = softmax_scale if softmax_scale is not None else D ** -0.5

    block_q = min(block_q, Sq)
    block_k = min(block_k, Sk)
    pad_q = (-Sq) % block_q
    pad_k = (-Sk) % block_k
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_k:
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_k), (0, 0)))
    nq = (Sq + pad_q) // block_q
    nk = (Sk + pad_k) // block_k

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        q_offset=q_offset, sq=Sq, sk=Sk, block_q=block_q, block_k=block_k)

    from jax.experimental.pallas import tpu as pltpu
    kwargs = {}
    if not interpret:
        kwargs["compiler_params"] = pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary"))
    scratch = [pltpu.VMEM((block_q,), jnp.float32),
               pltpu.VMEM((block_q,), jnp.float32),
               pltpu.VMEM((block_q, D), jnp.float32)]

    out = pl.pallas_call(
        kernel,
        grid=(B, H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, i, j, G=G: (b, h // G, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, Sq + pad_q, D), q.dtype),
        scratch_shapes=scratch,
        interpret=interpret,
        **kwargs,
    )(q, k, v)
    return out[:, :, :Sq]
