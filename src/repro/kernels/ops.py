"""Jitted public wrappers around the Pallas kernels.

These keep the *model* layout at the boundary (B, S, H, D) and handle layout
transposition, head-dim padding to MXU-friendly multiples, and
interpret-mode selection (interpret=True on CPU — executes the kernel body
for correctness; compiled Mosaic on real TPU).
"""
from __future__ import annotations

import functools
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .decode_attention import decode_attention_kernel
from .flash_attention import flash_attention_kernel
from .rglru_scan import rglru_scan_kernel
from .ssd_scan import ssd_scan_kernel


def _interpret_default() -> bool:
    return jax.default_backend() != "tpu"


def _pad_last(x: jax.Array, multiple: int) -> Tuple[jax.Array, int]:
    d = x.shape[-1]
    pad = (-d) % multiple
    if pad:
        cfgs = [(0, 0)] * x.ndim
        cfgs[-1] = (0, pad)
        x = jnp.pad(x, cfgs)
    return x, pad


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "q_offset", "softmax_scale",
                     "block_q", "block_k", "interpret"))
def flash_attention(
    q: jax.Array,                 # (B, Sq, H, D) — model layout
    k: jax.Array,                 # (B, Sk, KVH, D)
    v: jax.Array,                 # (B, Sk, KVH, D)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    q_offset: int = 0,
    softmax_scale: Optional[float] = None,
    block_q: int = 128,
    block_k: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = _interpret_default()
    D = q.shape[-1]
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    # pad head dim to an MXU-friendly multiple (zeros do not perturb scores)
    q, _ = _pad_last(q, 128)
    k, _ = _pad_last(k, 128)
    v, pad_v = _pad_last(v, 128)
    qt = jnp.moveaxis(q, 2, 1)     # (B, H, Sq, Dp)
    kt = jnp.moveaxis(k, 2, 1)
    vt = jnp.moveaxis(v, 2, 1)
    out = flash_attention_kernel(
        qt, kt, vt, causal=causal, window=window, q_offset=q_offset,
        softmax_scale=scale, block_q=block_q, block_k=block_k,
        interpret=interpret)
    out = jnp.moveaxis(out, 1, 2)  # (B, Sq, H, Dp)
    if pad_v:
        out = out[..., :v.shape[-1] - pad_v]
    return out


@functools.partial(
    jax.jit,
    static_argnames=("window", "softmax_scale", "block_s", "interpret"))
def decode_attention(
    q: jax.Array,                 # (B, 1, H, D) — model layout
    k_cache: jax.Array,           # (B, S, KVH, D)
    v_cache: jax.Array,           # (B, S, KVH, D)
    lengths: jax.Array,           # (B,)
    *,
    window: Optional[int] = None,
    softmax_scale: Optional[float] = None,
    block_s: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = _interpret_default()
    D = q.shape[-1]
    scale = softmax_scale if softmax_scale is not None else D ** -0.5
    q2, _ = _pad_last(q, 128)
    k2, _ = _pad_last(k_cache, 128)
    v2, pad_v = _pad_last(v_cache, 128)
    out = decode_attention_kernel(
        q2[:, 0],                                  # (B, H, Dp)
        jnp.moveaxis(k2, 2, 1),                    # (B, KVH, S, Dp)
        jnp.moveaxis(v2, 2, 1),
        lengths.astype(jnp.int32),
        window=window, softmax_scale=scale, block_s=block_s,
        interpret=interpret)
    out = out[:, None]                             # (B, 1, H, Dp)
    if pad_v:
        out = out[..., :v_cache.shape[-1]]
    return out


@functools.partial(jax.jit,
                   static_argnames=("block_s", "block_w", "interpret"))
def rglru(
    a: jax.Array,                 # (B, S, W) decays
    b: jax.Array,                 # (B, S, W)
    *,
    block_s: int = 256,
    block_w: int = 128,
    interpret: Optional[bool] = None,
) -> jax.Array:
    if interpret is None:
        interpret = _interpret_default()
    return rglru_scan_kernel(a, b, block_s=block_s, block_w=block_w,
                             interpret=interpret)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd(
    x: jax.Array,                 # (B, S, H, P) — model layout, dt-scaled
    a: jax.Array,                 # (B, S, H)
    Bm: jax.Array,                # (B, S, H, N)
    Cm: jax.Array,                # (B, S, H, N)
    *,
    chunk: int = 256,
    interpret: Optional[bool] = None,
) -> Tuple[jax.Array, jax.Array]:
    if interpret is None:
        interpret = _interpret_default()
    xt = jnp.moveaxis(x, 2, 1)     # (B, H, S, P)
    at = jnp.moveaxis(a, 2, 1)     # (B, H, S)
    Bt = jnp.moveaxis(Bm, 2, 1)
    Ct = jnp.moveaxis(Cm, 2, 1)
    y, state = ssd_scan_kernel(xt, at, Bt, Ct, chunk=chunk,
                               interpret=interpret)
    return jnp.moveaxis(y, 1, 2), state
