"""Peer data plane (DESIGN.md §9): endpoint↔endpoint DataRef resolution.

The third communication topology. funcX's data fabric moves intermediate
data between endpoints without funneling bytes through the cloud service
(paper §5); here every endpoint agent runs a :class:`PeerServer` — a
TcpListener serving its local :class:`~repro.data.KVStore` over the same
framed transport the hub channels use — and a :class:`PeerClient` that
dials producers directly when stage-in meets a cross-endpoint DataRef.

The service stays in the *control* path only (service-brokered
signaling): endpoints advertise their peer listen address at Register,
and a consumer asks the service ``ResolvePeer(producer)`` to learn the
address plus a short-TTL HMAC peer-token minted with the producer's
per-endpoint secret. The producer's PeerServer validates that token
entirely offline — the service never touches the data path.

Fallback ladder (each rung taken only when the one above fails):

  1. local store / same-process store registry (shm-adjacent: zero wire)
  2. direct peer TCP  — PeerGet/PeerData on a cached connection
  3. hub relay        — HubFetch to the service, which pulls the key over
                        the producer's already-attached hub channel

Rung 3 is correct but expensive (two hops, bytes transit the hub); the
service counts ``hub_relay_bytes`` so benchmarks can assert the happy
path never takes it.
"""
from __future__ import annotations

import itertools
import threading
import time
from dataclasses import dataclass
from typing import Callable, Dict, Optional, Tuple

from ..data.store import KVStore
from ..data.transfer import DataRef
from .auth import validate_peer_token
from .comms import Channel, SocketReactor, TcpListener, TcpTransport, \
    parse_hostport
from .errors import AuthError
from .protocol import HubFetch, PeerData, PeerGet, ResolvePeer, \
    ResolvePeerAck, from_wire, to_wire, to_wire_parts


class PeerError(Exception):
    """A peer fetch failed for a reason a retry through the hub relay
    cannot fix (missing key, refused token after refresh, bad reply)."""


class PeerUnreachable(PeerError):
    """The producer could not be dialed / the connection died mid-fetch —
    the rung-3 hub relay is the right next move."""


@dataclass
class PeerStats:
    """Consumer-side gauges: the bench invariants live here and on the
    service's ``hub_relay_bytes``."""
    direct_fetches: int = 0
    direct_bytes: int = 0
    relay_fetches: int = 0
    relay_bytes: int = 0
    dials: int = 0
    dial_failures: int = 0
    resolves: int = 0

    def as_dict(self) -> Dict[str, int]:
        return dict(direct_fetches=self.direct_fetches,
                    direct_bytes=self.direct_bytes,
                    relay_fetches=self.relay_fetches,
                    relay_bytes=self.relay_bytes,
                    dials=self.dials, dial_failures=self.dial_failures,
                    resolves=self.resolves)


class PeerServer:
    """Serves the endpoint's local store to authenticated peers.

    One TcpListener on the shared reactor; each accepted peer connection
    gets a serving loop on the listener's handshake thread (peer
    connections are persistent and bounded by fleet size, so a thread per
    peer is the simple shape — the reactor still owns all socket reads).
    Requests are :class:`PeerGet` frames; replies are :class:`PeerData`
    with the raw store bytes riding as a borrowed zero-copy segment.
    """

    def __init__(self, endpoint_id: str, store: KVStore,
                 secret: bytes = b"", host: str = "127.0.0.1",
                 port: int = 0, reactor: Optional[SocketReactor] = None):
        self.endpoint_id = endpoint_id
        self.store = store
        self._secret = secret
        self._closed = threading.Event()
        self.serves = 0
        self.bytes_out = 0
        self.refused = 0
        # the peer plane's reactor is per-agent, distinct from the hub's
        # service-side one — name it so thread accounting can tell them
        # apart (test_transport pins one "socket-reactor" per service)
        self._own_reactor = reactor is None
        if reactor is None:
            reactor = SocketReactor(name="peer-reactor")
        self._reactor = reactor
        self._listener = TcpListener(host, port, self._serve,
                                     reactor=reactor)
        self.address = "%s:%d" % self._listener.address

    def set_secret(self, secret: bytes) -> None:
        """The secret arrives from the service in RegisterAck — until it
        lands, every tokened request is refused."""
        self._secret = secret

    def close(self) -> None:
        self._closed.set()
        self._listener.close()
        if self._own_reactor:
            self._reactor.close()

    # -- serving loop (one per peer connection) -------------------------------
    def _serve(self, transport: TcpTransport, peer) -> None:
        ch = Channel(transport=transport)
        while not self._closed.is_set() and transport.connected:
            got = ch.recv_at_service(timeout=0.25)
            if got is None:
                continue
            env, _tag = got
            try:
                msg = from_wire(env)
            except Exception:
                continue                     # poison frame: drop
            if isinstance(msg, PeerGet):
                self._answer(ch, msg)
        ch.close()

    def _answer(self, ch: Channel, msg: PeerGet) -> None:
        if self._secret:
            try:
                validate_peer_token(self._secret, msg.token,
                                    self.endpoint_id)
            except AuthError as e:
                self.refused += 1
                ch.send_to_endpoint(to_wire(PeerData(
                    req_id=msg.req_id, key=msg.key, ok=False,
                    error=f"refused: {e}")), tag="peer")
                return
        try:
            data = self.store.get_raw(msg.key)
        except KeyError:
            ch.send_to_endpoint(to_wire(PeerData(
                req_id=msg.req_id, key=msg.key, ok=False,
                error=f"no such key: {msg.key}")), tag="peer")
            return
        except Exception as e:               # noqa: BLE001 — report, serve on
            ch.send_to_endpoint(to_wire(PeerData(
                req_id=msg.req_id, key=msg.key, ok=False,
                error=f"{type(e).__name__}: {e}")), tag="peer")
            return
        env, segs = to_wire_parts(PeerData(
            req_id=msg.req_id, key=msg.key, ok=True, data=data))
        if ch.send_parts_to_endpoint(env, segs, tag="peer"):
            self.serves += 1
            self.bytes_out += len(data)


class _PeerConn:
    """One cached consumer→producer connection: a synchronously dialed
    socket (fast failure — the channel-grade dialing transport redials
    forever, which would stall the fallback ladder) plus a lock making
    request/response cycles on it atomic."""

    def __init__(self, addr: str, dial_timeout: float):
        import socket as _socket
        host, port = parse_hostport(addr)
        sock = _socket.create_connection((host, port),
                                         timeout=dial_timeout)
        sock.settimeout(None)
        try:
            sock.setsockopt(_socket.IPPROTO_TCP, _socket.TCP_NODELAY, 1)
        except OSError:
            pass
        self.transport = TcpTransport(sock=sock)
        self.channel = Channel(transport=self.transport)
        self.addr = addr
        self.lock = threading.Lock()

    @property
    def connected(self) -> bool:
        return self.transport.connected

    def close(self) -> None:
        self.channel.close()


class PeerClient:
    """Consumer side: resolve-and-fetch with grant, connection, and value
    caching.

    The client doesn't own a hub channel; the endpoint agent hands it
    ``signal`` — a callable that ships a protocol message to the service —
    and routes every :class:`ResolvePeerAck` / relayed :class:`PeerData`
    it receives back in through :meth:`handle_signal`. The client matches
    replies to waiters by req_id.
    """

    GRANT_SLACK = 2.0          # refresh a grant this close to expiry

    def __init__(self, endpoint_id: str,
                 signal: Optional[Callable[[object], bool]] = None,
                 dial_timeout: float = 2.0, fetch_timeout: float = 15.0,
                 resolve_timeout: float = 5.0):
        self.endpoint_id = endpoint_id
        self.signal = signal
        self.dial_timeout = dial_timeout
        self.fetch_timeout = fetch_timeout
        self.resolve_timeout = resolve_timeout
        self.stats = PeerStats()
        self._grants: Dict[str, ResolvePeerAck] = {}
        self._conns: Dict[str, _PeerConn] = {}
        self._lock = threading.RLock()
        self._req_ids = itertools.count(1)
        self._waiters: Dict[str, Tuple[threading.Event, list]] = {}

    # -- signaling (rides the agent's hub channel) ----------------------------
    def _next_req(self) -> str:
        return f"{self.endpoint_id}:{next(self._req_ids)}"

    def _rpc(self, req_id: str, msg, timeout: float):
        """Send a signaling message and wait for its correlated reply."""
        if self.signal is None:
            return None
        ev: threading.Event = threading.Event()
        slot: list = []
        with self._lock:
            self._waiters[req_id] = (ev, slot)
        try:
            if not self.signal(msg):
                return None
            if not ev.wait(timeout):
                return None
            return slot[0] if slot else None
        finally:
            with self._lock:
                self._waiters.pop(req_id, None)

    def handle_signal(self, msg) -> bool:
        """Feed a ResolvePeerAck or relayed PeerData from the agent's recv
        loop; returns True when it matched a waiter."""
        req_id = getattr(msg, "req_id", None)
        if not req_id:
            return False
        with self._lock:
            waiter = self._waiters.get(req_id)
        if waiter is None:
            return False
        ev, slot = waiter
        slot.append(msg)
        ev.set()
        return True

    # -- grants + connections -------------------------------------------------
    def _grant(self, producer: str, force: bool = False,
               hint: str = "") -> ResolvePeerAck:
        now = time.time()
        with self._lock:
            g = self._grants.get(producer)
        if (g is not None and not force
                and now < g.expires - self.GRANT_SLACK):
            return g
        if self.signal is None:
            if hint:
                # standalone (no service to broker): dial the ref's
                # location hint with an empty token — only a tokenless
                # PeerServer will serve it
                return ResolvePeerAck(endpoint_id=producer, ok=True,
                                      addr=hint, token="",
                                      expires=now + 3600.0)
            raise PeerUnreachable(
                f"cannot resolve peer {producer}: no signaling channel")
        req_id = self._next_req()
        ack = self._rpc(req_id, ResolvePeer(
            req_id=req_id, endpoint_id=producer,
            consumer=self.endpoint_id), self.resolve_timeout)
        self.stats.resolves += 1
        if not isinstance(ack, ResolvePeerAck) or not ack.ok:
            err = getattr(ack, "error", "resolve timed out")
            raise PeerUnreachable(f"cannot resolve peer {producer}: {err}")
        with self._lock:
            self._grants[producer] = ack
        return ack

    def _conn(self, producer: str, addr: str) -> _PeerConn:
        with self._lock:
            conn = self._conns.get(producer)
        if conn is not None and conn.connected and conn.addr == addr:
            return conn
        if conn is not None:
            conn.close()
        try:
            self.stats.dials += 1
            conn = _PeerConn(addr, self.dial_timeout)
        except OSError as e:
            self.stats.dial_failures += 1
            raise PeerUnreachable(f"dial {addr} failed: {e}") from e
        with self._lock:
            old = self._conns.get(producer)
            if old is not None and old is not conn and old.connected:
                # lost the dial race: keep the established one
                conn.close()
                return old
            self._conns[producer] = conn
        return conn

    def invalidate(self, producer: str) -> None:
        with self._lock:
            self._grants.pop(producer, None)
            conn = self._conns.pop(producer, None)
        if conn is not None:
            conn.close()

    def close(self) -> None:
        with self._lock:
            conns = list(self._conns.values())
            self._conns.clear()
            self._grants.clear()
        for c in conns:
            c.close()

    # -- the fetch ladder -----------------------------------------------------
    def fetch_direct(self, producer: str, key: str,
                     hint: str = "") -> bytes:
        """Rung 2: resolve, dial (or reuse), request, await the bytes."""
        ack = self._grant(producer, hint=hint)
        retried = False
        while True:
            conn = self._conn(producer, ack.addr)
            req_id = self._next_req()
            pd = self._request(conn, PeerGet(
                req_id=req_id, key=key, token=ack.token,
                consumer=self.endpoint_id))
            if pd.ok:
                # bytes-like, not bytes: large payloads arrive as a
                # read-only view over the frame's dedicated recv buffer
                # and flow into the consumer's store without a copy
                data = pd.data if pd.data is not None else b""
                self.stats.direct_fetches += 1
                self.stats.direct_bytes += len(data)
                return data
            if pd.error.startswith("refused") and not retried:
                # stale/expired token: one re-resolve with a fresh grant
                retried = True
                ack = self._grant(producer, force=True, hint=hint)
                continue
            raise PeerError(f"peer {producer} refused {key}: {pd.error}")

    def _request(self, conn: _PeerConn, msg: PeerGet) -> PeerData:
        with conn.lock:
            if not conn.channel.send_to_service(to_wire(msg), tag="peer"):
                conn.close()
                raise PeerUnreachable("peer connection lost on send")
            deadline = time.monotonic() + self.fetch_timeout
            while True:
                left = deadline - time.monotonic()
                if left <= 0 or not conn.connected:
                    conn.close()
                    raise PeerUnreachable(
                        "peer fetch timed out" if left <= 0
                        else "peer connection died mid-fetch")
                got = conn.channel.recv_at_endpoint(timeout=min(left, 0.25))
                if got is None:
                    continue
                try:
                    reply = from_wire(got[0])
                except Exception:
                    continue
                if isinstance(reply, PeerData) \
                        and reply.req_id == msg.req_id:
                    return reply

    def fetch_direct_many(self, producer: str, keys: list,
                          hint: str = "") -> Dict[str, bytes]:
        """Rung 2, pipelined: ship every PeerGet back-to-back on the
        cached connection, then collect the replies. One round-trip's
        latency for the whole batch instead of one per key — the server
        answers a connection's requests in order, so replies stream back
        while later requests are still in flight."""
        ack = self._grant(producer, hint=hint)
        conn = self._conn(producer, ack.addr)
        reqs = [PeerGet(req_id=self._next_req(), key=k, token=ack.token,
                        consumer=self.endpoint_id) for k in keys]
        out: Dict[str, bytes] = {}
        retry: list = []
        with conn.lock:
            for m in reqs:
                if not conn.channel.send_to_service(to_wire(m),
                                                    tag="peer"):
                    conn.close()
                    raise PeerUnreachable("peer connection lost on send")
            pending = {m.req_id: m.key for m in reqs}
            deadline = time.monotonic() + self.fetch_timeout
            while pending:
                left = deadline - time.monotonic()
                if left <= 0 or not conn.connected:
                    conn.close()
                    raise PeerUnreachable(
                        "peer fetch timed out" if left <= 0
                        else "peer connection died mid-fetch")
                got = conn.channel.recv_at_endpoint(
                    timeout=min(left, 0.25))
                if got is None:
                    continue
                try:
                    reply = from_wire(got[0])
                except Exception:
                    continue
                if not isinstance(reply, PeerData) \
                        or reply.req_id not in pending:
                    continue
                key = pending.pop(reply.req_id)
                if reply.ok:
                    data = reply.data \
                        if reply.data is not None else b""
                    self.stats.direct_fetches += 1
                    self.stats.direct_bytes += len(data)
                    out[key] = data
                elif reply.error.startswith("refused"):
                    retry.append(key)       # stale token: retry singly
                else:
                    raise PeerError(
                        f"peer {producer} refused {key}: {reply.error}")
        for key in retry:
            # fetch_direct re-resolves with a fresh grant on refusal
            out[key] = self.fetch_direct(producer, key, hint=hint)
        return out

    def fetch_relay(self, producer: str, key: str) -> bytes:
        """Rung 3: ask the service to pull the key over the producer's hub
        channel. Bytes transit the hub — counted there as relay traffic."""
        req_id = self._next_req()
        pd = self._rpc(req_id, HubFetch(
            req_id=req_id, endpoint_id=producer, key=key),
            self.fetch_timeout)
        if not isinstance(pd, PeerData) or not pd.ok:
            err = getattr(pd, "error", "relay timed out")
            raise PeerError(f"hub relay for {producer}/{key} failed: {err}")
        data = bytes(pd.data) if pd.data is not None else b""
        self.stats.relay_fetches += 1
        self.stats.relay_bytes += len(data)
        return data

    def fetch_raw(self, ref: DataRef) -> bytes:
        """Rungs 2→3 for one ref; rung 1 (local/same-process) is the
        caller's (staging's) business. Exactly-once: the relay fires only
        after the direct path has definitively failed."""
        producer = ref.endpoint
        try:
            return self.fetch_direct(producer, ref.key,
                                     hint=getattr(ref, "location", ""))
        except PeerUnreachable:
            self.invalidate(producer)
            return self.fetch_relay(producer, ref.key)

    def fetch_raw_many(self, refs: list) -> list:
        """Rungs 2→3 for a same-producer batch (pipelined direct fetch,
        per-key relay fallback). Returns values in ref order."""
        if not refs:
            return []
        producer = refs[0].endpoint
        hint = getattr(refs[0], "location", "")
        try:
            got = self.fetch_direct_many(
                producer, [r.key for r in refs], hint=hint)
            return [got[r.key] for r in refs]
        except PeerUnreachable:
            self.invalidate(producer)
            return [self.fetch_relay(producer, r.key) for r in refs]
