"""Forwarder↔endpoint channel (the ZeroMQ tier in funcX).

Duplex pair of queues carrying *packed* buffers (serialization facade with
routing tags, §4.5). Supports fault injection: ``disconnect()`` /
``reconnect()`` emulate network partitions; ``drop_rate`` emulates lossy
links — both used by the fault-tolerance tests to exercise the paper's
requeue-on-disconnect and heartbeat-loss behaviours.
"""
from __future__ import annotations

import queue
import random
import threading
import time
from typing import Any, Optional

from ..serialization import pack, unpack


class ChannelClosed(Exception):
    pass


class Channel:
    def __init__(self, drop_rate: float = 0.0, seed: int = 0):
        self._to_endpoint: "queue.Queue[bytes]" = queue.Queue()
        self._to_service: "queue.Queue[bytes]" = queue.Queue()
        self._connected = threading.Event()
        self._connected.set()
        self._closed = False
        self.drop_rate = drop_rate
        self._rng = random.Random(seed)
        # traffic accounting
        self.bytes_to_endpoint = 0
        self.bytes_to_service = 0

    # -- state ----------------------------------------------------------------
    @property
    def connected(self) -> bool:
        return self._connected.is_set() and not self._closed

    def disconnect(self) -> None:
        self._connected.clear()

    def reconnect(self) -> None:
        if not self._closed:
            self._connected.set()

    def close(self) -> None:
        self._closed = True
        self._connected.clear()

    def _maybe_drop(self) -> bool:
        return self.drop_rate > 0 and self._rng.random() < self.drop_rate

    # -- service → endpoint -----------------------------------------------------
    def send_to_endpoint(self, obj: Any, tag: str = "") -> bool:
        if not self.connected or self._maybe_drop():
            return False
        buf = pack(obj, tag=tag)
        self.bytes_to_endpoint += len(buf)
        self._to_endpoint.put(buf)
        return True

    def recv_at_endpoint(self, timeout: float = 0.1) -> Optional[tuple]:
        try:
            buf = self._to_endpoint.get(timeout=timeout)
        except queue.Empty:
            return None
        return unpack(buf)

    # -- endpoint → service -----------------------------------------------------
    def send_to_service(self, obj: Any, tag: str = "") -> bool:
        if not self.connected or self._maybe_drop():
            return False
        buf = pack(obj, tag=tag)
        self.bytes_to_service += len(buf)
        self._to_service.put(buf)
        return True

    def recv_at_service(self, timeout: float = 0.1) -> Optional[tuple]:
        try:
            buf = self._to_service.get(timeout=timeout)
        except queue.Empty:
            return None
        return unpack(buf)
