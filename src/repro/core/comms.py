"""Forwarder↔endpoint channel (the ZeroMQ tier in funcX).

Duplex pair of queues carrying *packed* buffers (serialization facade with
routing tags, §4.5). Supports fault injection: ``disconnect()`` /
``reconnect()`` emulate network partitions; ``drop_rate`` emulates lossy
links — both used by the fault-tolerance tests to exercise the paper's
requeue-on-disconnect and heartbeat-loss behaviours.

``ChannelHub`` is the select()-style multiplexer on top: one thread polls
the service side of many channels at once (the transport substrate for the
ForwarderPool — O(1) service threads for N endpoints).

Pack-once data plane (DESIGN.md §5): envelopes are protocol dicts whose
user data is already an opaque byte frame, so ``send_*`` packs them with a
``msgpack`` method hint (one C-speed encode, no trial loop, no payload
re-serialization); a caller may also hand over an already-packed
``PackedBuffer`` which is forwarded byte-identical. ``ChannelHub.poll``
returns *packed* buffers — routing happens on the header tag alone and
deserialization is deferred to the consumer.
"""
from __future__ import annotations

import queue
import random
import threading
import time
from typing import Any, Dict, List, Optional, Tuple

from ..serialization import (
    PackedBuffer,
    SerializationError,
    pack_buffer,
    unpack,
)


class ChannelClosed(Exception):
    pass


class Channel:
    def __init__(self, drop_rate: float = 0.0, seed: int = 0):
        self._to_endpoint: "queue.Queue[bytes]" = queue.Queue()
        self._to_service: "queue.Queue[bytes]" = queue.Queue()
        self._connected = threading.Event()
        self._connected.set()
        self._closed = False
        self.drop_rate = drop_rate
        self._rng = random.Random(seed)
        self._hub: Optional[Tuple["ChannelHub", str]] = None
        # traffic accounting
        self.bytes_to_endpoint = 0
        self.bytes_to_service = 0

    # -- state ----------------------------------------------------------------
    @property
    def connected(self) -> bool:
        return self._connected.is_set() and not self._closed

    def disconnect(self) -> None:
        self._connected.clear()

    def reconnect(self) -> None:
        if not self._closed:
            self._connected.set()

    def close(self) -> None:
        self._closed = True
        self._connected.clear()

    def _maybe_drop(self) -> bool:
        return self.drop_rate > 0 and self._rng.random() < self.drop_rate

    @staticmethod
    def _pack_envelope(obj: Any, tag: str) -> bytes:
        """Wire bytes for one message. Pre-packed buffers pass through
        untouched; envelope dicts get a msgpack method hint (protocol
        envelopes are plain dicts with bin frames — the hint skips the
        nd tree walk, and a hint miss still falls back to the trial)."""
        if isinstance(obj, PackedBuffer):
            return obj.data
        return pack_buffer(obj, tag=tag, method_hint="msgpack").data

    # -- service → endpoint -----------------------------------------------------
    def send_to_endpoint(self, obj: Any, tag: str = "") -> bool:
        if not self.connected or self._maybe_drop():
            return False
        buf = self._pack_envelope(obj, tag)
        self.bytes_to_endpoint += len(buf)
        self._to_endpoint.put(buf)
        return True

    def recv_at_endpoint(self, timeout: float = 0.1) -> Optional[tuple]:
        try:
            buf = self._to_endpoint.get(timeout=timeout)
        except queue.Empty:
            return None
        try:
            return unpack(buf)
        except SerializationError:
            return None                        # poison frame: drop

    # -- endpoint → service -----------------------------------------------------
    def send_to_service(self, obj: Any, tag: str = "") -> bool:
        if not self.connected or self._maybe_drop():
            return False
        buf = self._pack_envelope(obj, tag)
        self.bytes_to_service += len(buf)
        self._to_service.put(buf)
        hub = self._hub
        if hub is not None:
            hub[0]._notify(hub[1])
        return True

    def recv_at_service(self, timeout: float = 0.1) -> Optional[tuple]:
        try:
            buf = self._to_service.get(timeout=timeout)
        except queue.Empty:
            return None
        try:
            return unpack(buf)
        except SerializationError:
            return None                        # poison frame: drop

    def pending_to_service(self) -> int:
        return self._to_service.qsize()


class ChannelHub:
    """select()-style readiness multiplexer over many channels' service side.

    Channels registered with the hub push a readiness token whenever the
    endpoint sends a message, so one poller thread can sleep on a single
    queue instead of spinning over N channels. ``poll`` wakes on the first
    ready channel and then drains every token already available — one
    syscall-shaped wait per quiet period, not per channel.

    Tokens are advisory: ``poll`` re-checks the channel queue non-blockingly
    (a duplicate token — possible in the registration race window — yields
    nothing and is skipped), so correctness never rests on exact 1:1
    token/message accounting.
    """

    def __init__(self):
        self._ready: "queue.Queue[str]" = queue.Queue()
        self._channels: Dict[str, Channel] = {}
        self._lock = threading.Lock()

    def register(self, key: str, channel: Channel) -> None:
        with self._lock:
            self._channels[key] = channel
        channel._hub = (self, key)
        # Messages that arrived before registration (e.g. heartbeats queued
        # while a ForwarderPool was being restarted) get their tokens now.
        for _ in range(channel.pending_to_service()):
            self._ready.put(key)

    def unregister(self, key: str) -> None:
        with self._lock:
            ch = self._channels.pop(key, None)
        if ch is not None and ch._hub is not None and ch._hub[0] is self:
            ch._hub = None

    def keys(self) -> List[str]:
        with self._lock:
            return list(self._channels)

    def _notify(self, key: str) -> None:
        self._ready.put(key)

    def poll(self, timeout: float = 0.1) -> List[Tuple[str, PackedBuffer]]:
        """Block up to ``timeout`` for readiness, then drain everything
        already ready. Returns ``[(key, PackedBuffer), ...]`` — messages
        stay *packed*: the buffer's header tag is enough to route, and the
        consumer decides when (whether) to deserialize (§4.5: "only the
        buffers need to be unpacked and deserialized at the destination").
        """
        out: List[Tuple[str, PackedBuffer]] = []
        try:
            key = self._ready.get(timeout=timeout)
        except queue.Empty:
            return out
        pending = [key]
        while True:
            try:
                pending.append(self._ready.get_nowait())
            except queue.Empty:
                break
        # one snapshot of the channel map per poll, not one lock round-trip
        # per ready token
        with self._lock:
            channels = dict(self._channels)
        for key in pending:
            ch = channels.get(key)
            if ch is None:
                continue
            try:
                buf = ch._to_service.get_nowait()
            except queue.Empty:
                continue                       # duplicate/stale token
            try:
                out.append((key, PackedBuffer.from_bytes(buf)))
            except SerializationError:
                continue                       # poison frame: drop, don't
                #                                kill the shared poller
        return out
