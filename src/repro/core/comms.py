"""Forwarder↔endpoint transport tier (the ZeroMQ tier in funcX).

``Channel`` is the duplex message pipe carrying *packed* buffers
(serialization facade with routing tags, §4.5). What moves the bytes is a
pluggable :class:`Transport`:

  - :class:`LocalTransport` (default): the in-memory queue pair — the
    same-process deployment used by most tests and benchmarks, with fault
    injection (``disconnect()`` / ``reconnect()`` emulate partitions,
    ``drop_rate`` emulates lossy links);
  - :class:`TcpTransport`: length-prefixed frames over a real TCP socket —
    one side per OS process, nonblocking connect with reconnect + backoff
    on the dialing (endpoint) side. The frame body is the PackedBuffer's
    bytes verbatim, so the pack-once plane (DESIGN.md §5) extends across
    process boundaries: the bytes written to the socket are the bytes the
    facade produced at submit.

``ChannelHub`` is the select()-style multiplexer on top: one thread polls
the service side of many channels at once (the transport substrate for the
ForwarderPool — O(1) service threads for N endpoints). Channels push a
readiness token when a frame arrives on their service side — synchronously
from ``send_to_service`` for LocalTransport, from the shared
:class:`SocketReactor` selector thread for accepted TcpTransports — so
socket-backed and in-memory channels share one readiness path and the
service never grows per-endpoint threads.

Pack-once data plane (DESIGN.md §5): envelopes are protocol dicts whose
user data is already an opaque byte frame, so ``send_*`` packs them with a
``msgpack`` method hint (one C-speed encode, no trial loop, no payload
re-serialization); a caller may also hand over an already-packed
``PackedBuffer`` which is forwarded byte-identical. ``ChannelHub.poll``
returns *packed* buffers — routing happens on the header tag alone and
deserialization is deferred to the consumer.

Return-path frame tags (DESIGN.md §6): the batched result plane ships
``ResultBatch`` envelopes under the ``"results"`` tag (lone legacy
``ResultMsg`` frames keep ``"result"``); both are routing tags only — the
frame body is still one opaque msgpack dict either way, so every
transport carries the batched plane transparently.

Scatter-gather frames + shared memory (DESIGN.md §7): a segmented frame
is ``RPXS || u32 nseg || u32 len[nseg] || envelope || payload segments``
— transports gather the pieces with vectored I/O (``sendmsg``) instead of
joining them, the receiver re-slices them as borrowed memoryviews
(:func:`decode_frame`), and :class:`LocalTransport` passes the part list
through untouched. :class:`ShmTransport` moves the same byte stream
through a pair of :class:`ShmRing` SPSC rings when service and endpoint
share a host (negotiated at Register time), keeping TCP as the control
channel and doorbell carrier.
"""
from __future__ import annotations

import queue
import random
import selectors
import socket
import struct
import threading
from collections import deque
from time import monotonic as _monotonic, sleep as _sleep
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..serialization import (
    PackedBuffer,
    SerializationError,
    pack_buffer,
    unpack,
)

# Logical lanes of a duplex channel. A LocalTransport carries both in one
# object (same-process deployment); a TcpTransport is one *side* of the
# channel, so both lanes collapse onto its single socket.
TO_ENDPOINT = 0
TO_SERVICE = 1

_LEN_PREFIX = struct.Struct(">I")          # frame = u32 length + buffer bytes
MAX_FRAME = 64 * 1024 * 1024               # sanity bound; > payload limit

_HAS_SENDMSG = hasattr(socket.socket, "sendmsg")
_IOV_CAP = 512                             # stay far under IOV_MAX
_DOORBELL = _LEN_PREFIX.pack(0)            # zero-length frame = shm doorbell


class ChannelClosed(Exception):
    pass


# -- segmented frame codec (DESIGN.md §7) -------------------------------------
SEG_MAGIC = b"RPXS"
_SEG_COUNT = struct.Struct(">4sI")         # magic + number of segments
_U32 = struct.Struct(">I")


def segment_parts(header, segments: Sequence) -> list:
    """Frame-body pieces for a segmented envelope: a segment table, the
    packed envelope (segment 0), then each borrowed payload buffer.
    Nothing is joined here — transports gather the list with vectored
    I/O, or pass it through untouched (LocalTransport)."""
    lens = [len(header)]
    lens.extend(len(s) for s in segments)
    table = bytearray(_SEG_COUNT.size + 4 * len(lens))
    _SEG_COUNT.pack_into(table, 0, SEG_MAGIC, len(lens))
    off = _SEG_COUNT.size
    for n in lens:
        _U32.pack_into(table, off, n)
        off += 4
    return [bytes(table), header, *segments]


class SegmentedFrame:
    """Decoded view of a segmented frame: the envelope header (a packed
    dict) plus the borrowed payload segments — zero-copy views into the
    receive buffer, or the sender's own buffers over LocalTransport.
    Quacks like a PackedBuffer where the routing layer cares (``tag``,
    ``unpack()``)."""

    __slots__ = ("header", "segments")

    def __init__(self, header: PackedBuffer, segments: list):
        self.header = header
        self.segments = segments

    @property
    def tag(self) -> str:
        return self.header.tag

    def unpack(self):
        """Envelope dict with the borrowed segments attached under the
        reserved ``_segs`` key — ``protocol.from_wire`` resolves the
        ``payload_seg`` / ``result_seg`` indices against it."""
        env = self.header.unpack()
        if isinstance(env, dict):
            env["_segs"] = self.segments
        return env


def decode_frame(frame):
    """Wire frame → :class:`PackedBuffer` (legacy single-envelope frame)
    or :class:`SegmentedFrame`. Accepts bytes/bytearray/memoryview from
    byte-stream transports, or the part list a LocalTransport passed
    through. Raises SerializationError on a corrupt frame."""
    if isinstance(frame, (tuple, list)):       # LocalTransport pass-through
        if len(frame) < 2:
            raise SerializationError("short segment part list")
        return SegmentedFrame(PackedBuffer.from_bytes(frame[1]),
                              list(frame[2:]))
    view = frame if isinstance(frame, memoryview) else memoryview(frame)
    if view[:4] != SEG_MAGIC:
        return PackedBuffer.from_bytes(frame)
    try:
        _, nseg = _SEG_COUNT.unpack_from(view, 0)
        off = _SEG_COUNT.size + 4 * nseg
        if nseg < 1 or off > len(view):
            raise SerializationError("bad segment count")
        segs = []
        for i in range(nseg):
            (n,) = _U32.unpack_from(view, _SEG_COUNT.size + 4 * i)
            segs.append(view[off:off + n])
            off += n
        if off != len(view):
            raise SerializationError("segment table mismatch")
    except struct.error as e:
        raise SerializationError(f"corrupt segment frame: {e}") from e
    return SegmentedFrame(PackedBuffer.from_bytes(segs[0]), segs[1:])


class _FrameAssembler:
    """Incremental u32-length-prefix frame parser shared by every
    byte-stream path: reactor-fed sockets, the dialing reader, and the
    shm ring drain. Small frames accumulate through a scratch buffer as
    before; a body at or above ``DIRECT_MIN`` gets a dedicated pre-sized
    bytearray that ``read_from`` fills with ``recv_into`` — one kernel
    copy, no accumulate-then-slice double copy — and is delivered as a
    read-only memoryview (zero-copy into segment decode).

    A zero-length frame is the shm doorbell and is delivered as ``b""``.
    Completed frames queue up in ``frames``.
    """

    DIRECT_MIN = 32 * 1024

    def __init__(self, max_frame: int = MAX_FRAME):
        self.max_frame = max_frame
        self.frames: deque = deque()
        self._scratch = memoryview(bytearray(65536))
        self._rbuf = bytearray()
        self._body: Optional[bytearray] = None   # large frame in progress
        self._need = 0
        self._pos = 0

    def reset(self) -> None:
        self._rbuf.clear()
        self.frames.clear()
        self._body = None
        self._need = self._pos = 0

    def read_from(self, sock: socket.socket) -> str:
        """One recv into the right buffer. Returns ``"ok"`` / ``"eof"`` /
        ``"poison"``; timeouts and EAGAIN propagate to the caller."""
        if self._body is not None:
            n = sock.recv_into(memoryview(self._body)[self._pos:])
            if n == 0:
                return "eof"
            self._body_progress(n)
            return "ok"
        n = sock.recv_into(self._scratch)
        if n == 0:
            return "eof"
        return "ok" if self.feed(self._scratch[:n]) else "poison"

    def feed(self, chunk) -> bool:
        """Parse an arbitrary byte chunk (ring drains, scratch reads).
        False = poisoned stream (oversized frame): cut the link."""
        view = chunk if isinstance(chunk, memoryview) else memoryview(chunk)
        while view.nbytes:
            if self._body is not None:
                k = min(self._need - self._pos, view.nbytes)
                self._body[self._pos:self._pos + k] = view[:k]
                view = view[k:]
                self._body_progress(k)
                continue
            self._rbuf += view
            view = view[:0]
            if not self._parse_rbuf():
                return False
        return True

    def _body_progress(self, k: int) -> None:
        self._pos += k
        if self._pos == self._need:
            body, self._body = self._body, None
            self._need = self._pos = 0
            self.frames.append(memoryview(body).toreadonly())

    def _parse_rbuf(self) -> bool:
        rb = self._rbuf
        off = 0
        while True:
            avail = len(rb) - off
            if avail < 4:
                break
            (n,) = _LEN_PREFIX.unpack_from(rb, off)
            if n > self.max_frame:
                if off:
                    del rb[:off]
                return False
            if n == 0:                         # doorbell frame
                self.frames.append(b"")
                off += 4
                continue
            if n >= self.DIRECT_MIN:
                # switch this body to a dedicated pre-sized buffer
                body = bytearray(n)
                k = min(avail - 4, n)
                body[:k] = rb[off + 4:off + 4 + k]
                del rb[:off + 4 + k]
                off = 0
                self._body, self._need, self._pos = body, n, 0
                self._body_progress(k)
                if self._body is not None:
                    return True                # rest arrives via read_from
                continue
            if avail - 4 < n:
                break
            self.frames.append(bytes(rb[off + 4:off + 4 + n]))
            off += 4 + n
        if off:
            del rb[:off]
        return True


class Transport:
    """Byte mover beneath a :class:`Channel`: duplex lanes of opaque frames.

    Implementations deliver each sent frame at-most-once and in order per
    lane; a ``send`` returning ``False`` means the frame was *not*
    delivered (link down) — callers treat it like a dropped packet and the
    requeue machinery above recovers. ``on_receive`` fires whenever a
    frame lands on the receiving side (the hub-token hook).
    """

    on_receive: Optional[Callable[[], None]] = None

    def send(self, lane: int, buf: bytes) -> bool:
        raise NotImplementedError

    def send_parts(self, lane: int, parts: Sequence) -> bool:
        """Send a multi-part segmented frame (segment table + envelope +
        borrowed payload buffers) as ONE frame. Default joins the parts;
        byte-stream transports override with vectored I/O and
        LocalTransport passes the list through untouched."""
        return self.send(lane, b"".join(parts))

    def recv(self, lane: int, timeout: float) -> Optional[bytes]:
        raise NotImplementedError

    def recv_nowait(self, lane: int) -> Optional[bytes]:
        raise NotImplementedError

    def pending(self, lane: int) -> int:
        raise NotImplementedError

    def queue(self, lane: int) -> "queue.Queue[bytes]":
        """The inbound byte queue for a lane (test/fault-injection hook)."""
        raise NotImplementedError

    @property
    def connected(self) -> bool:
        return True

    def disconnect(self) -> None:          # fault injection; default no-op
        pass

    def reconnect(self) -> None:
        pass

    def close(self) -> None:
        pass


class LocalTransport(Transport):
    """The in-memory queue pair — byte-identical to the pre-Transport
    Channel internals. Both lanes live in one object, so a single instance
    serves both the service and endpoint sides of a same-process channel."""

    def __init__(self):
        self._queues: Tuple["queue.Queue[bytes]", "queue.Queue[bytes]"] = (
            queue.Queue(), queue.Queue())
        self.on_receive = None

    def send(self, lane: int, buf: bytes) -> bool:
        self._queues[lane].put(buf)
        if lane == TO_SERVICE:
            cb = self.on_receive
            if cb is not None:
                cb()
        return True

    def send_parts(self, lane: int, parts: Sequence) -> bool:
        """Segmented envelope: the part list crosses the queue untouched
        (no join, no copy) — ``decode_frame`` reads it directly."""
        self._queues[lane].put(tuple(parts))
        if lane == TO_SERVICE:
            cb = self.on_receive
            if cb is not None:
                cb()
        return True

    def recv(self, lane: int, timeout: float) -> Optional[bytes]:
        try:
            return self._queues[lane].get(timeout=timeout)
        except queue.Empty:
            return None

    def recv_nowait(self, lane: int) -> Optional[bytes]:
        try:
            return self._queues[lane].get_nowait()
        except queue.Empty:
            return None

    def pending(self, lane: int) -> int:
        return self._queues[lane].qsize()

    def queue(self, lane: int) -> "queue.Queue[bytes]":
        return self._queues[lane]


def _configure_socket(sock: socket.socket, timeout: float = 1.0) -> None:
    sock.settimeout(timeout)
    try:
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    except OSError:
        pass


class SocketReactor:
    """One selector thread for every accepted socket (and the listening
    socket itself): accepts connections and drains frames for all of them
    — the service side stays O(1) threads no matter how many endpoints
    dial in (per-connection threads exist only transiently, for the
    registration handshake).

    Members implement ``reactor_sock()`` / ``_on_readable() -> bool`` /
    ``_reactor_closed(sock)``. All selector mutation happens on the
    reactor thread (adds/removes arrive over a wakeup socketpair), so a
    socket is closed only after the selector has forgotten it — no stale
    fd can collide with a reused descriptor number.
    """

    def __init__(self, name: str = "socket-reactor"):
        self._selector = selectors.DefaultSelector()
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._wake_w.setblocking(False)
        self._selector.register(self._wake_r, selectors.EVENT_READ, None)
        self._pending: "queue.Queue[Tuple[str, Any]]" = queue.Queue()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name=name)
        self._thread.start()

    def add(self, member) -> None:
        self._pending.put(("add", member))
        self._wakeup()

    def remove(self, member) -> None:
        """Unregister + close a member's socket (on the reactor thread)."""
        self._pending.put(("remove", member))
        self._wakeup()

    def close(self) -> None:
        self._stop.set()
        self._wakeup()
        self._thread.join(timeout=2.0)

    def _wakeup(self) -> None:
        try:
            self._wake_w.send(b"\0")
        except OSError:
            pass

    def _process_pending(self) -> None:
        while True:
            try:
                op, member = self._pending.get_nowait()
            except queue.Empty:
                return
            sock = member.reactor_sock()
            if op == "add":
                if sock is None:
                    member._reactor_closed(sock)
                    continue
                try:
                    self._selector.register(sock, selectors.EVENT_READ,
                                            member)
                except (KeyError, ValueError, OSError):
                    member._reactor_closed(sock)
            else:
                if sock is not None:
                    try:
                        self._selector.unregister(sock)
                    except (KeyError, ValueError):
                        pass
                member._reactor_closed(sock)

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                events = self._selector.select(timeout=0.25)
            except OSError:
                continue
            self._process_pending()
            for key, _ in events:
                if key.data is None:           # wakeup pipe
                    try:
                        while self._wake_r.recv(4096):
                            pass
                    except OSError:
                        pass
                    continue
                if not key.data._on_readable():
                    try:
                        self._selector.unregister(key.fileobj)
                    except (KeyError, ValueError):
                        pass
                    key.data._reactor_closed(key.fileobj)
        # shutdown: release every member still registered
        for key in list(self._selector.get_map().values()):
            if key.data is None:
                continue
            try:
                self._selector.unregister(key.fileobj)
            except (KeyError, ValueError):
                pass
            key.data._reactor_closed(key.fileobj)
        self._selector.close()
        for s in (self._wake_r, self._wake_w):
            try:
                s.close()
            except OSError:
                pass


class TcpTransport(Transport):
    """One side of a channel over a real TCP socket.

    Frames are ``u32 big-endian length || PackedBuffer bytes`` — the body
    is exactly what :meth:`Channel._pack_envelope` produced, so pre-packed
    payload frames cross the wire byte-identical (pack-once, DESIGN.md §5).

    Two roles:

    - **accepted** (service side): built around an already-connected
      socket from :class:`TcpListener`. When the connection dies, the
      transport is dead for good — the peer re-dials and the service
      reattaches a *new* transport to the endpoint's line.
    - **dialing** (endpoint side): built with ``connect=(host, port)``.
      A background reader dials with exponential backoff, reads frames,
      and on connection loss closes + re-dials forever (until ``close``),
      firing ``on_connect`` after every successful dial so the endpoint
      agent can re-register.

    A frame cut short by a disconnect — mid-body or even mid-length-prefix
    — is dropped, never delivered truncated; the sender's requeue path
    (heartbeat loss → requeue in-flight) re-covers the loss.
    """

    def __init__(self, sock: Optional[socket.socket] = None, *,
                 connect: Optional[Tuple[str, int]] = None,
                 reactor: Optional[SocketReactor] = None,
                 backoff: float = 0.05, backoff_max: float = 2.0,
                 max_frame: int = MAX_FRAME,
                 on_connect: Optional[Callable[[], None]] = None):
        if (sock is None) == (connect is None):
            raise ValueError("exactly one of sock/connect is required")
        if reactor is not None and sock is None:
            raise ValueError("reactor mode requires an accepted socket")
        self._sock = sock
        self._connect_addr = connect
        self._reactor = reactor
        self._backoff = backoff
        self._backoff_max = backoff_max
        self._max_frame = max_frame
        self.on_connect = on_connect
        self.on_receive = None
        self.on_doorbell: Optional[Callable[[], None]] = None

        self._inbox: "queue.Queue[bytes]" = queue.Queue()
        self._asm = _FrameAssembler(max_frame)   # incremental frame parser
        self._send_lock = threading.Lock()
        self._connected = threading.Event()
        self._suspended = threading.Event()    # disconnect(): no redial
        self._stop = threading.Event()
        self.dials = 0                          # successful (re)connects
        self.frames_in = 0
        self.frames_out = 0
        if sock is not None:
            _configure_socket(sock)
            self._connected.set()
        if reactor is not None:                # fed by the shared selector
            reactor.add(self)
        else:                                  # dedicated reader thread
            self._reader = threading.Thread(target=self._reader_loop,
                                            daemon=True, name="tcp-reader")
            self._reader.start()

    # -- state ----------------------------------------------------------------
    @property
    def connected(self) -> bool:
        return self._connected.is_set() and not self._stop.is_set()

    def disconnect(self) -> None:
        """Fault injection: kill the live connection and (for a dialing
        transport) hold off re-dialing until :meth:`reconnect`."""
        self._suspended.set()
        self._drop_connection()

    def reconnect(self) -> None:
        self._suspended.clear()

    def close(self) -> None:
        self._stop.set()
        self._drop_connection()

    def _drop_connection(self) -> None:
        self._connected.clear()
        if self._reactor is not None:
            # reactor mode: shutdown only — the fd stays open until the
            # reactor sees EOF and forgets it, so the selector never holds
            # a closed (reusable) descriptor
            sock = self._sock
            if sock is not None:
                try:
                    sock.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
            return
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    # How long a send may go without the peer accepting a single byte
    # before the link is declared dead. Progress resets the clock, so a
    # large frame on a slow link is fine — only a truly stalled peer
    # (full receive buffer, hung process) trips it.
    SEND_STALL_TIMEOUT = 10.0

    # -- data plane -----------------------------------------------------------
    def send(self, lane: int, buf) -> bool:
        return self._send_bufs((_LEN_PREFIX.pack(len(buf)), buf))

    def send_parts(self, lane: int, parts: Sequence) -> bool:
        """Vectored send: one length prefix covering the gathered parts,
        then the parts themselves — ``sendmsg`` writes the whole iovec
        without joining (zero copies of the borrowed payload segments)."""
        total = 0
        for p in parts:
            total += len(p)
        return self._send_bufs((_LEN_PREFIX.pack(total), *parts))

    def send_doorbell(self) -> bool:
        """Zero-length frame: wakes the peer's shm ring drain (DESIGN.md
        §7). Rides the ordinary frame stream, so it sorts after every
        frame already sent on this socket."""
        return self._send_bufs((_DOORBELL,), count=False)

    def _send_bufs(self, bufs: Sequence, count: bool = True) -> bool:
        sock = self._sock
        if sock is None or not self.connected:
            return False
        iov = [b if isinstance(b, memoryview) else memoryview(b)
               for b in bufs]
        try:
            with self._send_lock:
                stall_deadline = None
                i = 0
                while i < len(iov):
                    try:
                        if _HAS_SENDMSG:
                            n = sock.sendmsg(iov[i:i + _IOV_CAP])
                        else:
                            n = sock.send(iov[i])
                    except socket.timeout:
                        # no bytes accepted within the socket timeout —
                        # keep pushing while the link is alive and the
                        # stall budget lasts (a *total* deadline would
                        # kill big frames on slow links)
                        if self._stop.is_set() \
                                or not self._connected.is_set():
                            raise OSError("link down mid-send")
                        now = _monotonic()
                        if stall_deadline is None:
                            stall_deadline = now + self.SEND_STALL_TIMEOUT
                        elif now >= stall_deadline:
                            raise OSError("peer stalled")
                        continue
                    stall_deadline = None
                    # resume across the iovec after a partial write
                    while n:
                        cur = iov[i]
                        if n >= len(cur):
                            n -= len(cur)
                            i += 1
                        else:
                            iov[i] = cur[n:]
                            n = 0
            if count:
                self.frames_out += 1
            return True
        except (OSError, ValueError):
            # a partially written frame poisons the stream — drop the
            # connection so the peer discards the fragment at EOF
            self._drop_connection()
            return False

    def recv(self, lane: int, timeout: float) -> Optional[bytes]:
        try:
            return self._inbox.get(timeout=timeout)
        except queue.Empty:
            return None

    def recv_nowait(self, lane: int) -> Optional[bytes]:
        try:
            return self._inbox.get_nowait()
        except queue.Empty:
            return None

    def pending(self, lane: int) -> int:
        return self._inbox.qsize()

    def queue(self, lane: int) -> "queue.Queue[bytes]":
        return self._inbox

    # -- frame delivery (shared by both reader styles + shm drain) ------------
    def deliver(self, frame) -> None:
        """Hand one inbound frame to the consumer side. The shm ring
        drain shares this inbox, so Channel/hub cannot tell which medium
        a frame crossed."""
        self._inbox.put(frame)
        self.frames_in += 1
        cb = self.on_receive
        if cb is not None:
            cb()

    def _deliver_frames(self) -> None:
        """Flush every frame the assembler completed. Zero-length frames
        are shm doorbells: they trigger the ring drain instead of
        entering the inbox."""
        frames = self._asm.frames
        while frames:
            frame = frames.popleft()
            if len(frame) == 0:
                cb = self.on_doorbell
                if cb is not None:
                    cb()
                continue
            self.deliver(frame)

    # -- reactor protocol (accepted side, shared selector thread) -------------
    def reactor_sock(self) -> Optional[socket.socket]:
        return self._sock

    def _on_readable(self) -> bool:
        """One recv per readiness event (the level-triggered selector
        re-signals leftovers). False ends the membership."""
        sock = self._sock
        if sock is None or self._stop.is_set():
            return False
        try:
            status = self._asm.read_from(sock)
        except (BlockingIOError, InterruptedError, socket.timeout):
            return True
        except OSError:
            self._connected.clear()
            return False
        self._deliver_frames()
        if status != "ok":                     # EOF (incl. our shutdown)
            self._connected.clear()
            return False
        return True

    def _reactor_closed(self, sock) -> None:
        self._connected.clear()
        s, self._sock = self._sock, None
        if s is not None:
            try:
                s.close()
            except OSError:
                pass

    # -- reader (dialing side: dedicated thread, redial with backoff) ---------
    def _dial(self) -> Optional[socket.socket]:
        backoff = self._backoff
        while not self._stop.is_set() and not self._suspended.is_set():
            try:
                sock = socket.create_connection(self._connect_addr,
                                                timeout=1.0)
                _configure_socket(sock)
                return sock
            except OSError:
                self._stop.wait(backoff)
                backoff = min(backoff * 2, self._backoff_max)
        return None

    def _reader_loop(self) -> None:
        while not self._stop.is_set():
            sock = self._sock
            if sock is None:
                if self._connect_addr is None:
                    return               # accepted side: gone for good
                if self._suspended.is_set():
                    self._stop.wait(0.05)
                    continue
                sock = self._dial()
                if sock is None:
                    continue
                self._sock = sock
                self._connected.set()
                self.dials += 1
                cb = self.on_connect
                if cb is not None:
                    try:
                        cb()
                    except Exception:
                        pass
            self._read_frames(sock)
            # connection over: any partial frame in the buffer is dropped
            if self._sock is sock:
                self._drop_connection()

    def _read_frames(self, sock: socket.socket) -> None:
        """Drain one connection. Only complete frames are delivered; a
        short read at EOF (mid-frame or mid-prefix) is discarded with the
        connection."""
        self._asm.reset()
        while not self._stop.is_set() and self._sock is sock:
            try:
                status = self._asm.read_from(sock)
            except socket.timeout:
                continue
            except (OSError, ValueError):
                return
            self._deliver_frames()
            if status != "ok":
                return                   # EOF or garbage: cut the link


class ShmRing:
    """SPSC byte ring over ``multiprocessing.shared_memory`` — the data
    plane of the same-host fast path (DESIGN.md §7). The byte stream
    inside is identical to the TCP stream (u32-length-prefixed frames),
    so the reader reuses :class:`_FrameAssembler` unchanged, and frames
    larger than the ring simply stream through in pieces.

    Header: ``u32 head`` (total bytes written, mod 2^32) | ``u32 tail``
    (total read) | ``u32 capacity`` | ``u32 reader-waiting``. Exactly one
    writer process and one reader process; head/tail are monotonic, so
    ``used == head - tail`` with no full/empty ambiguity (capacity is
    well below 2^31).

    Doorbell suppression: the reader sets ``waiting`` before going idle
    and re-checks for data (closing the publish/sleep race); the writer
    sends the TCP doorbell only when it observes ``waiting`` — a reader
    that is keeping up costs the writer zero syscalls.
    """

    HDR = 16
    _U32LE = struct.Struct("<I")

    def __init__(self, shm, owner: bool):
        self._shm = shm
        self._buf = shm.buf
        self._owner = owner
        (self.capacity,) = self._U32LE.unpack_from(self._buf, 8)
        self._data = self._buf[self.HDR:self.HDR + self.capacity]

    @property
    def name(self) -> str:
        return self._shm.name

    @classmethod
    def create(cls, capacity: int) -> "ShmRing":
        from multiprocessing import shared_memory
        shm = shared_memory.SharedMemory(create=True,
                                         size=cls.HDR + capacity)
        cls._U32LE.pack_into(shm.buf, 0, 0)               # head
        cls._U32LE.pack_into(shm.buf, 4, 0)               # tail
        cls._U32LE.pack_into(shm.buf, 8, capacity)
        cls._U32LE.pack_into(shm.buf, 12, 1)              # reader "waiting"
        return cls(shm, owner=True)

    @classmethod
    def attach(cls, name: str) -> "ShmRing":
        from multiprocessing import shared_memory
        shm = shared_memory.SharedMemory(name=name)
        try:
            # the attaching process must not unlink the segment at exit —
            # the creating (service) side owns the lifetime
            from multiprocessing import resource_tracker
            resource_tracker.unregister(shm._name, "shared_memory")
        except Exception:
            pass
        return cls(shm, owner=False)

    # -- header words ---------------------------------------------------------
    def _get(self, off: int) -> int:
        (v,) = self._U32LE.unpack_from(self._buf, off)
        return v

    def _set(self, off: int, v: int) -> None:
        self._U32LE.pack_into(self._buf, off, v & 0xFFFFFFFF)

    def used(self) -> int:
        return (self._get(0) - self._get(4)) & 0xFFFFFFFF

    def waiting(self) -> bool:
        return self._get(12) != 0

    def set_waiting(self, flag: bool) -> None:
        self._set(12, 1 if flag else 0)

    # -- data plane -----------------------------------------------------------
    def write_some(self, view: memoryview) -> int:
        """Copy as much of ``view`` as currently fits (two-part copy on
        wraparound), publish it, return bytes written."""
        head, tail = self._get(0), self._get(4)
        free = self.capacity - ((head - tail) & 0xFFFFFFFF)
        k = min(free, view.nbytes)
        if k <= 0:
            return 0
        pos = head % self.capacity
        first = min(k, self.capacity - pos)
        self._data[pos:pos + first] = view[:first]
        if k > first:
            self._data[:k - first] = view[first:k]
        self._set(0, head + k)
        return k

    def read_some(self, sink) -> int:
        """Feed every readable byte span to ``sink`` (≤ 2 calls on
        wraparound), then advance tail. Returns bytes consumed."""
        head, tail = self._get(0), self._get(4)
        used = (head - tail) & 0xFFFFFFFF
        if used == 0:
            return 0
        pos = tail % self.capacity
        first = min(used, self.capacity - pos)
        sink(self._data[pos:pos + first])
        if used > first:
            sink(self._data[:used - first])
        self._set(4, tail + used)
        return used

    # -- lifecycle ------------------------------------------------------------
    def close(self) -> None:
        try:
            self._data.release()
        except Exception:
            pass
        try:
            self._shm.close()
        except (BufferError, OSError):
            pass

    def unlink(self) -> None:
        try:
            self._shm.unlink()
        except (OSError, FileNotFoundError):
            pass


class ShmTransport(Transport):
    """Same-host fast path: frames stream through a pair of SPSC
    shared-memory rings while the TCP connection stays up as the control
    channel and doorbell carrier. Wraps the live :class:`TcpTransport` —
    inbox, readiness callbacks and connection state are shared, so the
    stack above (Channel, hub, coalescer) cannot tell the difference,
    except that per-frame socket syscalls are gone.

    Ordering stays total despite two byte streams: each producer switches
    to the ring exactly once (everything up to the ShmAttach confirm goes
    over TCP, everything after through the ring), and doorbells ride the
    same TCP stream — after any frame that preceded the switch. A
    connection loss kills both media at once; in-ring frames are lost
    exactly like in-flight TCP bytes and the requeue machinery recovers.
    """

    RING_STALL_TIMEOUT = 10.0

    def __init__(self, tcp: TcpTransport, tx: ShmRing, rx: ShmRing,
                 owns: Sequence[ShmRing] = ()):
        self._tcp = tcp
        self._tx = tx
        self._rx = rx
        self._owns = tuple(owns)
        self._shm_send_lock = threading.Lock()
        self._rx_lock = threading.Lock()
        self._rx_asm = _FrameAssembler(tcp._max_frame)
        self._closed = False
        tcp.on_doorbell = self._drain_rx
        # cover the install race: anything the peer wrote (and doorbelled)
        # before the handler existed is sitting in the ring already
        self._drain_rx()

    # -- shared state / inbox (delegates to the wrapped TCP transport) --------
    @property
    def connected(self) -> bool:
        return not self._closed and self._tcp.connected

    @property
    def on_receive(self):
        return self._tcp.on_receive

    @on_receive.setter
    def on_receive(self, cb) -> None:
        self._tcp.on_receive = cb

    def recv(self, lane: int, timeout: float):
        return self._tcp.recv(lane, timeout)

    def recv_nowait(self, lane: int):
        return self._tcp.recv_nowait(lane)

    def pending(self, lane: int) -> int:
        return self._tcp.pending(lane)

    def queue(self, lane: int):
        return self._tcp.queue(lane)

    def disconnect(self) -> None:
        self._tcp.disconnect()

    def reconnect(self) -> None:
        self._tcp.reconnect()

    def close(self) -> None:
        self._closed = True
        self._tcp.close()
        self.release_rings()

    def release_rings(self) -> None:
        """Unmap both rings (and unlink the ones this side owns — the
        service side; the attaching side owns none)."""
        self._closed = True
        self._tcp.on_doorbell = None
        for ring in (self._tx, self._rx):
            ring.close()
        for ring in self._owns:
            ring.unlink()

    def __getattr__(self, name):
        # metrics/introspection (frames_in, dials, _reactor, ...) proxy
        # to the wrapped transport; reached only for undefined names
        if name.startswith("__"):
            raise AttributeError(name)
        return getattr(self._tcp, name)

    # -- send: stream into the tx ring ----------------------------------------
    def send(self, lane: int, buf) -> bool:
        return self._send_frames((_LEN_PREFIX.pack(len(buf)), buf))

    def send_parts(self, lane: int, parts: Sequence) -> bool:
        total = 0
        for p in parts:
            total += len(p)
        return self._send_frames((_LEN_PREFIX.pack(total), *parts))

    def _send_frames(self, bufs: Sequence) -> bool:
        if not self.connected:
            return False
        with self._shm_send_lock:
            ok = self._write_stream(bufs)
        if ok:
            self._tcp.frames_out += 1
        return ok

    def _write_stream(self, bufs: Sequence) -> bool:
        """Stream the frame into the ring in as many pieces as needed —
        frames larger than the ring flow through as the reader drains.
        A reader that accepts nothing for RING_STALL_TIMEOUT (dead or
        wedged peer) fails the send; the link teardown recovers."""
        ring = self._tx
        stall_deadline = None
        for b in bufs:
            view = b if isinstance(b, memoryview) else memoryview(b)
            while view.nbytes:
                k = ring.write_some(view)
                if k:
                    view = view[k:]
                    stall_deadline = None
                    continue
                if self._closed or not self._tcp.connected:
                    return False
                self._ring_doorbell()          # reader may be asleep
                now = _monotonic()
                if stall_deadline is None:
                    stall_deadline = now + self.RING_STALL_TIMEOUT
                elif now >= stall_deadline:
                    return False
                _sleep(0.0005)
        # One doorbell per frame, after the whole frame is in the ring.
        # Ringing per chunk wakes the reader on the 4-byte length prefix,
        # which re-arms waiting on the incomplete frame and turns one
        # frame into several doorbell syscalls + reader wakes.
        self._ring_doorbell()
        return True

    def _ring_doorbell(self) -> None:
        if self._tx.waiting():
            self._tx.set_waiting(False)
            self._tcp.send_doorbell()

    # -- recv: drain the rx ring (runs on the TCP receive thread; the
    # lock covers the brief install-time drain racing a first doorbell) --------
    def _drain_rx(self) -> None:
        with self._rx_lock:
            self._drain_rx_locked()

    def _drain_rx_locked(self) -> None:
        rx = self._rx
        asm = self._rx_asm
        deliver = self._tcp.deliver
        while not self._closed:
            try:
                n = rx.read_some(self._feed_rx)
            except ValueError:
                return                         # ring released under us
            while asm.frames:
                frame = asm.frames.popleft()
                if len(frame):
                    deliver(frame)
            if n == 0:
                rx.set_waiting(True)
                if rx.used() == 0:
                    return
                rx.set_waiting(False)          # data raced in: go again

    def _feed_rx(self, view) -> None:
        if not self._rx_asm.feed(view):
            # oversized/corrupt frame in the ring poisons the stream —
            # kill the link, both sides fall back through re-register
            self._tcp._drop_connection()


class TcpListener:
    """Nonblocking accept on the shared :class:`SocketReactor`: every
    accepted connection becomes a reactor-fed :class:`TcpTransport`, and
    ``on_transport`` runs on a short-lived handshake thread so a slow
    dialer never blocks accepts (or the reactor). With no reactor given
    the listener makes its own — a service passes one in so listener
    restarts don't tear down live connections."""

    def __init__(self, host: str, port: int,
                 on_transport: Callable[[TcpTransport, Tuple[str, int]],
                                        None],
                 backlog: int = 128,
                 reactor: Optional[SocketReactor] = None):
        self._on_transport = on_transport
        self._own_reactor = reactor is None
        self.reactor = reactor if reactor is not None else SocketReactor()
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(backlog)
        self._sock.setblocking(False)
        self.address: Tuple[str, int] = self._sock.getsockname()[:2]
        self._closed = threading.Event()
        self.reactor.add(self)

    # -- reactor protocol ------------------------------------------------------
    def reactor_sock(self) -> Optional[socket.socket]:
        return self._sock

    def _on_readable(self) -> bool:
        if self._closed.is_set():
            return False
        while True:
            try:
                conn, peer = self._sock.accept()
            except (BlockingIOError, InterruptedError):
                return True
            except OSError:
                return not self._closed.is_set()
            transport = TcpTransport(sock=conn, reactor=self.reactor)
            threading.Thread(target=self._on_transport,
                             args=(transport, peer), daemon=True,
                             name="tcp-handshake").start()

    def _reactor_closed(self, sock) -> None:
        s, self._sock = self._sock, None
        if s is not None:
            try:
                s.close()
            except OSError:
                pass

    def close(self) -> None:
        """Stop accepting. Live connections stay up unless this listener
        owns its reactor (standalone use), in which case everything the
        reactor serves goes down with it."""
        self._closed.set()
        if self._own_reactor:
            self.reactor.close()
        else:
            self.reactor.remove(self)


class Channel:
    """Duplex message pipe over a :class:`Transport` (default: in-memory).

    With a :class:`TcpTransport` the instance represents one *side* of the
    channel — call only that side's ``send_to_*`` / ``recv_at_*`` pair; the
    peer process holds the mirror instance around its own transport.
    """

    def __init__(self, drop_rate: float = 0.0, seed: int = 0,
                 transport: Optional[Transport] = None):
        self.transport = transport if transport is not None \
            else LocalTransport()
        self.transport.on_receive = self._frame_arrived
        self._connected = threading.Event()
        self._connected.set()
        self._closed = False
        self.drop_rate = drop_rate
        self._rng = random.Random(seed)
        self._hub: Optional[Tuple["ChannelHub", str]] = None
        self._ready_armed = False          # a hub token is outstanding
        # traffic accounting
        self.bytes_to_endpoint = 0
        self.bytes_to_service = 0

    # -- state ----------------------------------------------------------------
    @property
    def connected(self) -> bool:
        return (self._connected.is_set() and not self._closed
                and self.transport.connected)

    def disconnect(self) -> None:
        self._connected.clear()
        self.transport.disconnect()

    def reconnect(self) -> None:
        if not self._closed:
            self._connected.set()
            self.transport.reconnect()

    def close(self) -> None:
        self._closed = True
        self._connected.clear()
        self.transport.close()

    def _maybe_drop(self) -> bool:
        return self.drop_rate > 0 and self._rng.random() < self.drop_rate

    def _frame_arrived(self) -> None:
        """Transport callback: a frame landed on the service side — push
        a hub readiness token (same path for local and socket frames)
        unless one is already outstanding: ``poll`` drains the whole
        queue per token, so a 32-frame burst costs one wakeup, not 32."""
        hub = self._hub
        if hub is not None and not self._ready_armed:
            self._ready_armed = True
            hub[0]._notify(hub[1])

    # Direct queue access, kept for fault-injection in tests (raw poison
    # bytes). For TCP transports both names alias the single inbox.
    @property
    def _to_endpoint(self) -> "queue.Queue[bytes]":
        return self.transport.queue(TO_ENDPOINT)

    @property
    def _to_service(self) -> "queue.Queue[bytes]":
        return self.transport.queue(TO_SERVICE)

    @staticmethod
    def _pack_envelope(obj: Any, tag: str) -> bytes:
        """Wire bytes for one message. Pre-packed buffers pass through
        untouched; envelope dicts get a msgpack method hint (protocol
        envelopes are plain dicts with bin frames — the hint skips the
        nd tree walk, and a hint miss still falls back to the trial)."""
        if isinstance(obj, PackedBuffer):
            return obj.data
        return pack_buffer(obj, tag=tag, method_hint="msgpack").data

    @staticmethod
    def _decode_wire(buf) -> Optional[tuple]:
        """One inbound frame → ``(obj, tag)``. Handles legacy envelope
        frames, segmented frames (the borrowed buffers come back attached
        under ``_segs``), and LocalTransport part lists."""
        try:
            frame = decode_frame(buf)
            if isinstance(frame, SegmentedFrame):
                return frame.unpack(), frame.tag
            return unpack(frame)
        except SerializationError:
            return None                        # poison frame: drop

    # -- service → endpoint -----------------------------------------------------
    def send_to_endpoint(self, obj: Any, tag: str = "") -> bool:
        if not self.connected or self._maybe_drop():
            return False
        buf = self._pack_envelope(obj, tag)
        if not self.transport.send(TO_ENDPOINT, buf):
            return False
        self.bytes_to_endpoint += len(buf)
        return True

    def recv_at_endpoint(self, timeout: float = 0.1) -> Optional[tuple]:
        buf = self.transport.recv(TO_ENDPOINT, timeout)
        if buf is None:
            return None
        return self._decode_wire(buf)

    # -- endpoint → service -----------------------------------------------------
    def send_to_service(self, obj: Any, tag: str = "") -> bool:
        if not self.connected or self._maybe_drop():
            return False
        buf = self._pack_envelope(obj, tag)
        if not self.transport.send(TO_SERVICE, buf):
            return False
        self.bytes_to_service += len(buf)
        return True

    def recv_at_service(self, timeout: float = 0.1) -> Optional[tuple]:
        buf = self.transport.recv(TO_SERVICE, timeout)
        if buf is None:
            return None
        return self._decode_wire(buf)

    def pending_to_service(self) -> int:
        return self.transport.pending(TO_SERVICE)

    # -- segmented sends (scatter-gather zero-copy, DESIGN.md §7) ---------------
    def _send_segmented(self, lane: int, env: dict, segments: list,
                        tag: str) -> Tuple[bool, int]:
        header = pack_buffer(env, tag=tag, method_hint="msgpack").data
        if not segments:
            # nothing borrowed: legacy single-envelope frame, byte-identical
            # to the pre-segment wire format
            return self.transport.send(lane, header), len(header)
        parts = segment_parts(header, segments)
        return (self.transport.send_parts(lane, parts),
                sum(len(p) for p in parts))

    def send_parts_to_endpoint(self, env: dict, segments: list,
                               tag: str = "") -> bool:
        if not self.connected or self._maybe_drop():
            return False
        ok, n = self._send_segmented(TO_ENDPOINT, env, segments, tag)
        if ok:
            self.bytes_to_endpoint += n
        return ok

    def send_parts_to_service(self, env: dict, segments: list,
                              tag: str = "") -> bool:
        if not self.connected or self._maybe_drop():
            return False
        ok, n = self._send_segmented(TO_SERVICE, env, segments, tag)
        if ok:
            self.bytes_to_service += n
        return ok


class ChannelHub:
    """select()-style readiness multiplexer over many channels' service side.

    Channels registered with the hub push a readiness token whenever a
    frame lands on their service side — synchronously for in-memory
    channels, from the reactor/reader thread for TCP-backed ones — so one
    poller thread can sleep on a single queue instead of spinning over N
    channels. ``poll`` wakes on the first ready channel and then drains
    every token already available — one syscall-shaped wait per quiet
    period, not per channel.

    Tokens are advisory: ``poll`` re-checks the channel queue non-blockingly
    (a duplicate token — possible in the registration race window — yields
    nothing and is skipped), so correctness never rests on exact 1:1
    token/message accounting.
    """

    def __init__(self):
        self._ready: "queue.Queue[str]" = queue.Queue()
        self._channels: Dict[str, Channel] = {}
        self._lock = threading.Lock()

    def register(self, key: str, channel: Channel) -> None:
        with self._lock:
            self._channels[key] = channel
        channel._hub = (self, key)
        # One unconditional token covers anything that arrived before
        # registration (e.g. heartbeats queued while a ForwarderPool was
        # being restarted) — poll drains the whole queue per token.
        channel._ready_armed = True
        self._ready.put(key)

    def unregister(self, key: str) -> None:
        with self._lock:
            ch = self._channels.pop(key, None)
        if ch is not None and ch._hub is not None and ch._hub[0] is self:
            ch._hub = None

    def keys(self) -> List[str]:
        with self._lock:
            return list(self._channels)

    def _notify(self, key: str) -> None:
        self._ready.put(key)

    def poll(self, timeout: float = 0.1) -> List[Tuple[str, Any]]:
        """Block up to ``timeout`` for readiness, then drain everything
        already ready. Returns ``[(key, frame), ...]`` where each frame
        is a :class:`PackedBuffer` or :class:`SegmentedFrame` — messages
        stay *packed*: the frame's header tag is enough to route, and the
        consumer decides when (whether) to deserialize (§4.5: "only the
        buffers need to be unpacked and deserialized at the destination").

        Tokens are batched (one per channel per quiet period, not one per
        frame): each token triggers a full drain of that channel's queue,
        so a 32-frame result burst costs one queue wakeup.
        """
        out: List[Tuple[str, Any]] = []
        try:
            key = self._ready.get(timeout=timeout)
        except queue.Empty:
            return out
        pending = [key]
        while True:
            try:
                pending.append(self._ready.get_nowait())
            except queue.Empty:
                break
        # one snapshot of the channel map per poll, not one lock round-trip
        # per ready token
        with self._lock:
            channels = dict(self._channels)
        for key in pending:
            ch = channels.get(key)
            if ch is None:
                continue
            # disarm BEFORE draining: a frame landing mid-drain re-arms
            # and gets a fresh token (worst case a spare token, never a
            # lost frame)
            ch._ready_armed = False
            transport = ch.transport
            while True:
                buf = transport.recv_nowait(TO_SERVICE)
                if buf is None:
                    break                      # drained (or stale token)
                try:
                    out.append((key, decode_frame(buf)))
                except SerializationError:
                    continue                   # poison frame: drop, don't
                    #                            kill the shared poller
        return out


def parse_hostport(s: str, default_host: str = "127.0.0.1") -> Tuple[str, int]:
    """``host:port`` / ``:port`` / ``port`` → ``(host, port)``."""
    host, sep, port = s.rpartition(":")
    if not sep:
        host, port = default_host, s
    return (host or default_host, int(port))
