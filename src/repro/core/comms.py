"""Forwarder↔endpoint transport tier (the ZeroMQ tier in funcX).

``Channel`` is the duplex message pipe carrying *packed* buffers
(serialization facade with routing tags, §4.5). What moves the bytes is a
pluggable :class:`Transport`:

  - :class:`LocalTransport` (default): the in-memory queue pair — the
    same-process deployment used by most tests and benchmarks, with fault
    injection (``disconnect()`` / ``reconnect()`` emulate partitions,
    ``drop_rate`` emulates lossy links);
  - :class:`TcpTransport`: length-prefixed frames over a real TCP socket —
    one side per OS process, nonblocking connect with reconnect + backoff
    on the dialing (endpoint) side. The frame body is the PackedBuffer's
    bytes verbatim, so the pack-once plane (DESIGN.md §5) extends across
    process boundaries: the bytes written to the socket are the bytes the
    facade produced at submit.

``ChannelHub`` is the select()-style multiplexer on top: one thread polls
the service side of many channels at once (the transport substrate for the
ForwarderPool — O(1) service threads for N endpoints). Channels push a
readiness token when a frame arrives on their service side — synchronously
from ``send_to_service`` for LocalTransport, from the shared
:class:`SocketReactor` selector thread for accepted TcpTransports — so
socket-backed and in-memory channels share one readiness path and the
service never grows per-endpoint threads.

Pack-once data plane (DESIGN.md §5): envelopes are protocol dicts whose
user data is already an opaque byte frame, so ``send_*`` packs them with a
``msgpack`` method hint (one C-speed encode, no trial loop, no payload
re-serialization); a caller may also hand over an already-packed
``PackedBuffer`` which is forwarded byte-identical. ``ChannelHub.poll``
returns *packed* buffers — routing happens on the header tag alone and
deserialization is deferred to the consumer.

Return-path frame tags (DESIGN.md §6): the batched result plane ships
``ResultBatch`` envelopes under the ``"results"`` tag (lone legacy
``ResultMsg`` frames keep ``"result"``); both are routing tags only — the
frame body is still one opaque msgpack dict either way, so every
transport carries the batched plane transparently.
"""
from __future__ import annotations

import queue
import random
import selectors
import socket
import struct
import threading
from time import monotonic as _monotonic
from typing import Any, Callable, Dict, List, Optional, Tuple

from ..serialization import (
    PackedBuffer,
    SerializationError,
    pack_buffer,
    unpack,
)

# Logical lanes of a duplex channel. A LocalTransport carries both in one
# object (same-process deployment); a TcpTransport is one *side* of the
# channel, so both lanes collapse onto its single socket.
TO_ENDPOINT = 0
TO_SERVICE = 1

_LEN_PREFIX = struct.Struct(">I")          # frame = u32 length + buffer bytes
MAX_FRAME = 64 * 1024 * 1024               # sanity bound; > payload limit


class ChannelClosed(Exception):
    pass


class Transport:
    """Byte mover beneath a :class:`Channel`: duplex lanes of opaque frames.

    Implementations deliver each sent frame at-most-once and in order per
    lane; a ``send`` returning ``False`` means the frame was *not*
    delivered (link down) — callers treat it like a dropped packet and the
    requeue machinery above recovers. ``on_receive`` fires whenever a
    frame lands on the receiving side (the hub-token hook).
    """

    on_receive: Optional[Callable[[], None]] = None

    def send(self, lane: int, buf: bytes) -> bool:
        raise NotImplementedError

    def recv(self, lane: int, timeout: float) -> Optional[bytes]:
        raise NotImplementedError

    def recv_nowait(self, lane: int) -> Optional[bytes]:
        raise NotImplementedError

    def pending(self, lane: int) -> int:
        raise NotImplementedError

    def queue(self, lane: int) -> "queue.Queue[bytes]":
        """The inbound byte queue for a lane (test/fault-injection hook)."""
        raise NotImplementedError

    @property
    def connected(self) -> bool:
        return True

    def disconnect(self) -> None:          # fault injection; default no-op
        pass

    def reconnect(self) -> None:
        pass

    def close(self) -> None:
        pass


class LocalTransport(Transport):
    """The in-memory queue pair — byte-identical to the pre-Transport
    Channel internals. Both lanes live in one object, so a single instance
    serves both the service and endpoint sides of a same-process channel."""

    def __init__(self):
        self._queues: Tuple["queue.Queue[bytes]", "queue.Queue[bytes]"] = (
            queue.Queue(), queue.Queue())
        self.on_receive = None

    def send(self, lane: int, buf: bytes) -> bool:
        self._queues[lane].put(buf)
        if lane == TO_SERVICE:
            cb = self.on_receive
            if cb is not None:
                cb()
        return True

    def recv(self, lane: int, timeout: float) -> Optional[bytes]:
        try:
            return self._queues[lane].get(timeout=timeout)
        except queue.Empty:
            return None

    def recv_nowait(self, lane: int) -> Optional[bytes]:
        try:
            return self._queues[lane].get_nowait()
        except queue.Empty:
            return None

    def pending(self, lane: int) -> int:
        return self._queues[lane].qsize()

    def queue(self, lane: int) -> "queue.Queue[bytes]":
        return self._queues[lane]


def _configure_socket(sock: socket.socket, timeout: float = 1.0) -> None:
    sock.settimeout(timeout)
    try:
        sock.setsockopt(socket.IPPROTO_TCP, socket.TCP_NODELAY, 1)
    except OSError:
        pass


class SocketReactor:
    """One selector thread for every accepted socket (and the listening
    socket itself): accepts connections and drains frames for all of them
    — the service side stays O(1) threads no matter how many endpoints
    dial in (per-connection threads exist only transiently, for the
    registration handshake).

    Members implement ``reactor_sock()`` / ``_on_readable() -> bool`` /
    ``_reactor_closed(sock)``. All selector mutation happens on the
    reactor thread (adds/removes arrive over a wakeup socketpair), so a
    socket is closed only after the selector has forgotten it — no stale
    fd can collide with a reused descriptor number.
    """

    def __init__(self):
        self._selector = selectors.DefaultSelector()
        self._wake_r, self._wake_w = socket.socketpair()
        self._wake_r.setblocking(False)
        self._wake_w.setblocking(False)
        self._selector.register(self._wake_r, selectors.EVENT_READ, None)
        self._pending: "queue.Queue[Tuple[str, Any]]" = queue.Queue()
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._run, daemon=True,
                                        name="socket-reactor")
        self._thread.start()

    def add(self, member) -> None:
        self._pending.put(("add", member))
        self._wakeup()

    def remove(self, member) -> None:
        """Unregister + close a member's socket (on the reactor thread)."""
        self._pending.put(("remove", member))
        self._wakeup()

    def close(self) -> None:
        self._stop.set()
        self._wakeup()
        self._thread.join(timeout=2.0)

    def _wakeup(self) -> None:
        try:
            self._wake_w.send(b"\0")
        except OSError:
            pass

    def _process_pending(self) -> None:
        while True:
            try:
                op, member = self._pending.get_nowait()
            except queue.Empty:
                return
            sock = member.reactor_sock()
            if op == "add":
                if sock is None:
                    member._reactor_closed(sock)
                    continue
                try:
                    self._selector.register(sock, selectors.EVENT_READ,
                                            member)
                except (KeyError, ValueError, OSError):
                    member._reactor_closed(sock)
            else:
                if sock is not None:
                    try:
                        self._selector.unregister(sock)
                    except (KeyError, ValueError):
                        pass
                member._reactor_closed(sock)

    def _run(self) -> None:
        while not self._stop.is_set():
            try:
                events = self._selector.select(timeout=0.25)
            except OSError:
                continue
            self._process_pending()
            for key, _ in events:
                if key.data is None:           # wakeup pipe
                    try:
                        while self._wake_r.recv(4096):
                            pass
                    except OSError:
                        pass
                    continue
                if not key.data._on_readable():
                    try:
                        self._selector.unregister(key.fileobj)
                    except (KeyError, ValueError):
                        pass
                    key.data._reactor_closed(key.fileobj)
        # shutdown: release every member still registered
        for key in list(self._selector.get_map().values()):
            if key.data is None:
                continue
            try:
                self._selector.unregister(key.fileobj)
            except (KeyError, ValueError):
                pass
            key.data._reactor_closed(key.fileobj)
        self._selector.close()
        for s in (self._wake_r, self._wake_w):
            try:
                s.close()
            except OSError:
                pass


class TcpTransport(Transport):
    """One side of a channel over a real TCP socket.

    Frames are ``u32 big-endian length || PackedBuffer bytes`` — the body
    is exactly what :meth:`Channel._pack_envelope` produced, so pre-packed
    payload frames cross the wire byte-identical (pack-once, DESIGN.md §5).

    Two roles:

    - **accepted** (service side): built around an already-connected
      socket from :class:`TcpListener`. When the connection dies, the
      transport is dead for good — the peer re-dials and the service
      reattaches a *new* transport to the endpoint's line.
    - **dialing** (endpoint side): built with ``connect=(host, port)``.
      A background reader dials with exponential backoff, reads frames,
      and on connection loss closes + re-dials forever (until ``close``),
      firing ``on_connect`` after every successful dial so the endpoint
      agent can re-register.

    A frame cut short by a disconnect — mid-body or even mid-length-prefix
    — is dropped, never delivered truncated; the sender's requeue path
    (heartbeat loss → requeue in-flight) re-covers the loss.
    """

    def __init__(self, sock: Optional[socket.socket] = None, *,
                 connect: Optional[Tuple[str, int]] = None,
                 reactor: Optional[SocketReactor] = None,
                 backoff: float = 0.05, backoff_max: float = 2.0,
                 max_frame: int = MAX_FRAME,
                 on_connect: Optional[Callable[[], None]] = None):
        if (sock is None) == (connect is None):
            raise ValueError("exactly one of sock/connect is required")
        if reactor is not None and sock is None:
            raise ValueError("reactor mode requires an accepted socket")
        self._sock = sock
        self._connect_addr = connect
        self._reactor = reactor
        self._backoff = backoff
        self._backoff_max = backoff_max
        self._max_frame = max_frame
        self.on_connect = on_connect
        self.on_receive = None

        self._inbox: "queue.Queue[bytes]" = queue.Queue()
        self._rbuf = bytearray()               # incremental frame parser
        self._send_lock = threading.Lock()
        self._connected = threading.Event()
        self._suspended = threading.Event()    # disconnect(): no redial
        self._stop = threading.Event()
        self.dials = 0                          # successful (re)connects
        self.frames_in = 0
        self.frames_out = 0
        if sock is not None:
            _configure_socket(sock)
            self._connected.set()
        if reactor is not None:                # fed by the shared selector
            reactor.add(self)
        else:                                  # dedicated reader thread
            self._reader = threading.Thread(target=self._reader_loop,
                                            daemon=True, name="tcp-reader")
            self._reader.start()

    # -- state ----------------------------------------------------------------
    @property
    def connected(self) -> bool:
        return self._connected.is_set() and not self._stop.is_set()

    def disconnect(self) -> None:
        """Fault injection: kill the live connection and (for a dialing
        transport) hold off re-dialing until :meth:`reconnect`."""
        self._suspended.set()
        self._drop_connection()

    def reconnect(self) -> None:
        self._suspended.clear()

    def close(self) -> None:
        self._stop.set()
        self._drop_connection()

    def _drop_connection(self) -> None:
        self._connected.clear()
        if self._reactor is not None:
            # reactor mode: shutdown only — the fd stays open until the
            # reactor sees EOF and forgets it, so the selector never holds
            # a closed (reusable) descriptor
            sock = self._sock
            if sock is not None:
                try:
                    sock.shutdown(socket.SHUT_RDWR)
                except OSError:
                    pass
            return
        sock, self._sock = self._sock, None
        if sock is not None:
            try:
                sock.close()
            except OSError:
                pass

    # How long a send may go without the peer accepting a single byte
    # before the link is declared dead. Progress resets the clock, so a
    # large frame on a slow link is fine — only a truly stalled peer
    # (full receive buffer, hung process) trips it.
    SEND_STALL_TIMEOUT = 10.0

    # -- data plane -----------------------------------------------------------
    def send(self, lane: int, buf: bytes) -> bool:
        sock = self._sock
        if sock is None or not self.connected:
            return False
        data = memoryview(_LEN_PREFIX.pack(len(buf)) + buf)
        try:
            with self._send_lock:
                stall_deadline = None
                while data:
                    try:
                        n = sock.send(data)
                    except socket.timeout:
                        # no bytes accepted within the socket timeout —
                        # keep pushing while the link is alive and the
                        # stall budget lasts (sendall would treat its
                        # timeout as a *total* deadline and kill big
                        # frames on slow links)
                        if self._stop.is_set() \
                                or not self._connected.is_set():
                            raise OSError("link down mid-send")
                        now = _monotonic()
                        if stall_deadline is None:
                            stall_deadline = now + self.SEND_STALL_TIMEOUT
                        elif now >= stall_deadline:
                            raise OSError("peer stalled")
                        continue
                    data = data[n:]
                    stall_deadline = None
            self.frames_out += 1
            return True
        except (OSError, ValueError):
            # a partially written frame poisons the stream — drop the
            # connection so the peer discards the fragment at EOF
            self._drop_connection()
            return False

    def recv(self, lane: int, timeout: float) -> Optional[bytes]:
        try:
            return self._inbox.get(timeout=timeout)
        except queue.Empty:
            return None

    def recv_nowait(self, lane: int) -> Optional[bytes]:
        try:
            return self._inbox.get_nowait()
        except queue.Empty:
            return None

    def pending(self, lane: int) -> int:
        return self._inbox.qsize()

    def queue(self, lane: int) -> "queue.Queue[bytes]":
        return self._inbox

    # -- frame parsing (shared by both reader styles) -------------------------
    def _feed(self, chunk: bytes) -> bool:
        """Accumulate raw bytes; deliver every complete frame. Returns
        False when the stream is poisoned (oversized frame) — cut the
        link; a trailing partial frame just waits for more bytes and is
        discarded if the connection dies first."""
        self._rbuf += chunk
        while len(self._rbuf) >= _LEN_PREFIX.size:
            (n,) = _LEN_PREFIX.unpack_from(self._rbuf)
            if n > self._max_frame:
                return False
            if len(self._rbuf) < _LEN_PREFIX.size + n:
                break
            frame = bytes(self._rbuf[_LEN_PREFIX.size:_LEN_PREFIX.size + n])
            del self._rbuf[:_LEN_PREFIX.size + n]
            self._inbox.put(frame)
            self.frames_in += 1
            cb = self.on_receive
            if cb is not None:
                cb()
        return True

    # -- reactor protocol (accepted side, shared selector thread) -------------
    def reactor_sock(self) -> Optional[socket.socket]:
        return self._sock

    def _on_readable(self) -> bool:
        """One recv per readiness event (the level-triggered selector
        re-signals leftovers). False ends the membership."""
        sock = self._sock
        if sock is None or self._stop.is_set():
            return False
        try:
            chunk = sock.recv(65536)
        except (BlockingIOError, InterruptedError, socket.timeout):
            return True
        except OSError:
            self._connected.clear()
            return False
        if not chunk:                          # EOF (incl. our shutdown)
            self._connected.clear()
            return False
        return self._feed(chunk)

    def _reactor_closed(self, sock) -> None:
        self._connected.clear()
        s, self._sock = self._sock, None
        if s is not None:
            try:
                s.close()
            except OSError:
                pass

    # -- reader (dialing side: dedicated thread, redial with backoff) ---------
    def _dial(self) -> Optional[socket.socket]:
        backoff = self._backoff
        while not self._stop.is_set() and not self._suspended.is_set():
            try:
                sock = socket.create_connection(self._connect_addr,
                                                timeout=1.0)
                _configure_socket(sock)
                return sock
            except OSError:
                self._stop.wait(backoff)
                backoff = min(backoff * 2, self._backoff_max)
        return None

    def _reader_loop(self) -> None:
        while not self._stop.is_set():
            sock = self._sock
            if sock is None:
                if self._connect_addr is None:
                    return               # accepted side: gone for good
                if self._suspended.is_set():
                    self._stop.wait(0.05)
                    continue
                sock = self._dial()
                if sock is None:
                    continue
                self._sock = sock
                self._connected.set()
                self.dials += 1
                cb = self.on_connect
                if cb is not None:
                    try:
                        cb()
                    except Exception:
                        pass
            self._read_frames(sock)
            # connection over: any partial frame in the buffer is dropped
            if self._sock is sock:
                self._drop_connection()

    def _read_frames(self, sock: socket.socket) -> None:
        """Drain one connection. Only complete frames are delivered; a
        short read at EOF (mid-frame or mid-prefix) is discarded with the
        connection."""
        self._rbuf.clear()
        while not self._stop.is_set() and self._sock is sock:
            try:
                chunk = sock.recv(65536)
            except socket.timeout:
                continue
            except (OSError, ValueError):
                return
            if not chunk:
                return                   # EOF
            if not self._feed(chunk):
                return                   # garbage stream: cut the link


class TcpListener:
    """Nonblocking accept on the shared :class:`SocketReactor`: every
    accepted connection becomes a reactor-fed :class:`TcpTransport`, and
    ``on_transport`` runs on a short-lived handshake thread so a slow
    dialer never blocks accepts (or the reactor). With no reactor given
    the listener makes its own — a service passes one in so listener
    restarts don't tear down live connections."""

    def __init__(self, host: str, port: int,
                 on_transport: Callable[[TcpTransport, Tuple[str, int]],
                                        None],
                 backlog: int = 128,
                 reactor: Optional[SocketReactor] = None):
        self._on_transport = on_transport
        self._own_reactor = reactor is None
        self.reactor = reactor if reactor is not None else SocketReactor()
        self._sock = socket.socket(socket.AF_INET, socket.SOCK_STREAM)
        self._sock.setsockopt(socket.SOL_SOCKET, socket.SO_REUSEADDR, 1)
        self._sock.bind((host, port))
        self._sock.listen(backlog)
        self._sock.setblocking(False)
        self.address: Tuple[str, int] = self._sock.getsockname()[:2]
        self._closed = threading.Event()
        self.reactor.add(self)

    # -- reactor protocol ------------------------------------------------------
    def reactor_sock(self) -> Optional[socket.socket]:
        return self._sock

    def _on_readable(self) -> bool:
        if self._closed.is_set():
            return False
        while True:
            try:
                conn, peer = self._sock.accept()
            except (BlockingIOError, InterruptedError):
                return True
            except OSError:
                return not self._closed.is_set()
            transport = TcpTransport(sock=conn, reactor=self.reactor)
            threading.Thread(target=self._on_transport,
                             args=(transport, peer), daemon=True,
                             name="tcp-handshake").start()

    def _reactor_closed(self, sock) -> None:
        s, self._sock = self._sock, None
        if s is not None:
            try:
                s.close()
            except OSError:
                pass

    def close(self) -> None:
        """Stop accepting. Live connections stay up unless this listener
        owns its reactor (standalone use), in which case everything the
        reactor serves goes down with it."""
        self._closed.set()
        if self._own_reactor:
            self.reactor.close()
        else:
            self.reactor.remove(self)


class Channel:
    """Duplex message pipe over a :class:`Transport` (default: in-memory).

    With a :class:`TcpTransport` the instance represents one *side* of the
    channel — call only that side's ``send_to_*`` / ``recv_at_*`` pair; the
    peer process holds the mirror instance around its own transport.
    """

    def __init__(self, drop_rate: float = 0.0, seed: int = 0,
                 transport: Optional[Transport] = None):
        self.transport = transport if transport is not None \
            else LocalTransport()
        self.transport.on_receive = self._frame_arrived
        self._connected = threading.Event()
        self._connected.set()
        self._closed = False
        self.drop_rate = drop_rate
        self._rng = random.Random(seed)
        self._hub: Optional[Tuple["ChannelHub", str]] = None
        # traffic accounting
        self.bytes_to_endpoint = 0
        self.bytes_to_service = 0

    # -- state ----------------------------------------------------------------
    @property
    def connected(self) -> bool:
        return (self._connected.is_set() and not self._closed
                and self.transport.connected)

    def disconnect(self) -> None:
        self._connected.clear()
        self.transport.disconnect()

    def reconnect(self) -> None:
        if not self._closed:
            self._connected.set()
            self.transport.reconnect()

    def close(self) -> None:
        self._closed = True
        self._connected.clear()
        self.transport.close()

    def _maybe_drop(self) -> bool:
        return self.drop_rate > 0 and self._rng.random() < self.drop_rate

    def _frame_arrived(self) -> None:
        """Transport callback: a frame landed on the service side — push
        the hub readiness token (same path for local and socket frames)."""
        hub = self._hub
        if hub is not None:
            hub[0]._notify(hub[1])

    # Direct queue access, kept for fault-injection in tests (raw poison
    # bytes). For TCP transports both names alias the single inbox.
    @property
    def _to_endpoint(self) -> "queue.Queue[bytes]":
        return self.transport.queue(TO_ENDPOINT)

    @property
    def _to_service(self) -> "queue.Queue[bytes]":
        return self.transport.queue(TO_SERVICE)

    @staticmethod
    def _pack_envelope(obj: Any, tag: str) -> bytes:
        """Wire bytes for one message. Pre-packed buffers pass through
        untouched; envelope dicts get a msgpack method hint (protocol
        envelopes are plain dicts with bin frames — the hint skips the
        nd tree walk, and a hint miss still falls back to the trial)."""
        if isinstance(obj, PackedBuffer):
            return obj.data
        return pack_buffer(obj, tag=tag, method_hint="msgpack").data

    # -- service → endpoint -----------------------------------------------------
    def send_to_endpoint(self, obj: Any, tag: str = "") -> bool:
        if not self.connected or self._maybe_drop():
            return False
        buf = self._pack_envelope(obj, tag)
        if not self.transport.send(TO_ENDPOINT, buf):
            return False
        self.bytes_to_endpoint += len(buf)
        return True

    def recv_at_endpoint(self, timeout: float = 0.1) -> Optional[tuple]:
        buf = self.transport.recv(TO_ENDPOINT, timeout)
        if buf is None:
            return None
        try:
            return unpack(buf)
        except SerializationError:
            return None                        # poison frame: drop

    # -- endpoint → service -----------------------------------------------------
    def send_to_service(self, obj: Any, tag: str = "") -> bool:
        if not self.connected or self._maybe_drop():
            return False
        buf = self._pack_envelope(obj, tag)
        if not self.transport.send(TO_SERVICE, buf):
            return False
        self.bytes_to_service += len(buf)
        return True

    def recv_at_service(self, timeout: float = 0.1) -> Optional[tuple]:
        buf = self.transport.recv(TO_SERVICE, timeout)
        if buf is None:
            return None
        try:
            return unpack(buf)
        except SerializationError:
            return None                        # poison frame: drop

    def pending_to_service(self) -> int:
        return self.transport.pending(TO_SERVICE)


class ChannelHub:
    """select()-style readiness multiplexer over many channels' service side.

    Channels registered with the hub push a readiness token whenever a
    frame lands on their service side — synchronously for in-memory
    channels, from the reactor/reader thread for TCP-backed ones — so one
    poller thread can sleep on a single queue instead of spinning over N
    channels. ``poll`` wakes on the first ready channel and then drains
    every token already available — one syscall-shaped wait per quiet
    period, not per channel.

    Tokens are advisory: ``poll`` re-checks the channel queue non-blockingly
    (a duplicate token — possible in the registration race window — yields
    nothing and is skipped), so correctness never rests on exact 1:1
    token/message accounting.
    """

    def __init__(self):
        self._ready: "queue.Queue[str]" = queue.Queue()
        self._channels: Dict[str, Channel] = {}
        self._lock = threading.Lock()

    def register(self, key: str, channel: Channel) -> None:
        with self._lock:
            self._channels[key] = channel
        channel._hub = (self, key)
        # Messages that arrived before registration (e.g. heartbeats queued
        # while a ForwarderPool was being restarted) get their tokens now.
        for _ in range(channel.pending_to_service()):
            self._ready.put(key)

    def unregister(self, key: str) -> None:
        with self._lock:
            ch = self._channels.pop(key, None)
        if ch is not None and ch._hub is not None and ch._hub[0] is self:
            ch._hub = None

    def keys(self) -> List[str]:
        with self._lock:
            return list(self._channels)

    def _notify(self, key: str) -> None:
        self._ready.put(key)

    def poll(self, timeout: float = 0.1) -> List[Tuple[str, PackedBuffer]]:
        """Block up to ``timeout`` for readiness, then drain everything
        already ready. Returns ``[(key, PackedBuffer), ...]`` — messages
        stay *packed*: the buffer's header tag is enough to route, and the
        consumer decides when (whether) to deserialize (§4.5: "only the
        buffers need to be unpacked and deserialized at the destination").
        """
        out: List[Tuple[str, PackedBuffer]] = []
        try:
            key = self._ready.get(timeout=timeout)
        except queue.Empty:
            return out
        pending = [key]
        while True:
            try:
                pending.append(self._ready.get_nowait())
            except queue.Empty:
                break
        # one snapshot of the channel map per poll, not one lock round-trip
        # per ready token
        with self._lock:
            channels = dict(self._channels)
        for key in pending:
            ch = channels.get(key)
            if ch is None:
                continue
            buf = ch.transport.recv_nowait(TO_SERVICE)
            if buf is None:
                continue                       # duplicate/stale token
            try:
                out.append((key, PackedBuffer.from_bytes(buf)))
            except SerializationError:
                continue                       # poison frame: drop, don't
                #                                kill the shared poller
        return out


def parse_hostport(s: str, default_host: str = "127.0.0.1") -> Tuple[str, int]:
    """``host:port`` / ``:port`` / ``port`` → ``(host, port)``."""
    host, sep, port = s.rpartition(":")
    if not sep:
        host, port = default_host, s
    return (host or default_host, int(port))
