"""Hierarchical interchange (DESIGN.md §11): the paper's mid-tier relay.

funcX reached 130k+ concurrent workers and >100k queued tasks through an
*interchange* that sits between the cloud service and the workers,
queueing and fanning out tasks asynchronously (paper §5, fig. 4; the
same component anchors the earlier Serverless-Supercomputing prototype).
This module makes that tier real:

- **Upstream** the :class:`Interchange` is indistinguishable from one
  ordinary endpoint: it dials the service's TCP listener, performs the
  same ``Register``/``RegisterAck`` handshake, re-registers after
  connection cuts, and advertises one synthesized :class:`Heartbeat`
  whose load/warmth/build-cost fields aggregate the whole subtree — so
  federation routing sees "one big warm endpoint" and the service stays
  at O(1) threads no matter how many leaves hang below.
- **Downstream** it runs its own :class:`SocketReactor` + listener +
  :class:`ChannelHub` mini-forwarder speaking the *identical* wire
  protocol, so anything that can register with the service can register
  with an interchange — including another interchange (relay-of-relays
  nesting falls out for free).
- **Between** the two sides sits a deep task backlog (``depth``,
  default 150k specs) whose remaining room is advertised upstream as
  ``Heartbeat.credits`` — the backpressure signal the service-side
  forwarder respects — and drained by warmth-aware internal routing
  (the same ``make_router(tier="endpoint")`` machinery the service
  uses) under per-leaf outstanding-task windows.

Pack-once holds through the hop: task payloads arrive as opaque
``PackedBuffer`` frames and re-emit as borrowed segments — the relay
never deserializes or re-serializes a payload byte.

Exactly-once is preserved per tier with the PR 4/5 invariants:

- leaf death (missed heartbeats) or leaf removal requeues that leaf's
  in-flight specs into the central backlog for redispatch;
- an upstream cut parks outgoing result envelopes; the heartbeat loop
  retransmits them after the automatic re-dial + re-register, and the
  service's ``task.done`` check drops any duplicate that races a
  requeued re-execution.

Elasticity: the interchange exposes the ``pending_tasks`` /
``idle_workers`` / ``block_idle`` surface :class:`ElasticStrategy`
drives, and :class:`LeafProvider` turns provider blocks into whole leaf
endpoint *processes* dialing the downstream listener — backlog grows,
leaves spawn; backlog drains, leaves reap.
"""
from __future__ import annotations

import argparse
import collections
import hmac
import itertools
import signal
import threading
import time
from typing import Deque, Dict, List, Optional, Set, Tuple

from ..serialization import SerializationError
from .comms import (
    Channel,
    ChannelHub,
    SocketReactor,
    TcpListener,
    TcpTransport,
    parse_hostport,
)
from .endpoint import RemoteEndpointRunner, _BoundedSet, \
    spawn_endpoint_process
from .errors import RegistrationError
from .protocol import (
    Ack,
    FnRequest,
    FnResponse,
    Heartbeat,
    HubFetch,
    PeerData,
    ProtocolError,
    Register,
    RegisterAck,
    ResolvePeer,
    ResolvePeerAck,
    ResultBatch,
    ResultMsg,
    TaskBatch,
    TaskSpec,
    from_wire,
    to_wire,
    to_wire_parts,
)
from .provisioning import Provider
from .routing import EndpointInfo, RoutingContext, WarmthView, make_router
from .tasks import now


class LeafLine:
    """One downstream leaf's state inside the interchange — the mirror of
    the service-side ``EndpointLine``, except it holds the dispatched
    :class:`TaskSpec` objects themselves: the interchange has no
    TaskStore, so the specs must survive in the line for
    requeue-on-leaf-death."""

    def __init__(self, endpoint_id: str, channel: Channel,
                 lock: threading.RLock):
        self.endpoint_id = endpoint_id
        self.channel = channel
        self._lock = lock
        self.in_flight: Dict[str, TaskSpec] = {}
        self.advertised = Heartbeat(endpoint_id=endpoint_id)
        self.last_heartbeat = time.time()
        self.connected = True
        # tasks sent since the last heartbeat refreshed the leaf's credit
        # advertisement (only consulted when the leaf advertises credits,
        # i.e. is itself an interchange)
        self.sent_since_credit = 0
        self.dispatched = 0
        self.results = 0

    def in_flight_count(self) -> int:
        with self._lock:
            return len(self.in_flight)

    def info(self) -> EndpointInfo:
        """Snapshot for the interchange's internal endpoint-tier router."""
        adv = self.advertised
        warmth = WarmthView.from_heartbeat(adv)    # snapshot-local copy
        return EndpointInfo(
            endpoint_id=self.endpoint_id,
            connected=self.connected and self.channel.connected,
            service_queue=0,
            in_flight=self.in_flight_count(),
            queued=adv.queued,
            idle_workers=adv.idle_workers,
            capacity=adv.capacity,
            warm_idle=warmth.idle,
            warm_total=warmth.total,
        )

    def window(self, default_window: int, queue_factor: int) -> int:
        """How many more tasks this leaf may have outstanding.

        A leaf that advertises credits (a nested interchange) sets the
        window itself: its remaining credits minus what we sent since
        that advertisement. A plain leaf gets ``capacity ×
        queue_factor`` (or ``default_window`` before its first
        heartbeat) minus what is already in flight — deep enough to keep
        every worker busy through the RTT, shallow enough that the bulk
        of an absorbed burst stays in the central backlog where it can
        be rerouted when a leaf dies."""
        adv = self.advertised
        with self._lock:
            outstanding = len(self.in_flight)
            sent = self.sent_since_credit
        if adv.credits >= 0:
            return max(0, adv.credits - sent)
        budget = adv.capacity * queue_factor if adv.capacity > 0 \
            else default_window
        return max(0, budget - outstanding)


class Interchange:
    """A relay node: one endpoint upstream, a mini-service downstream.

    ``start()`` opens the downstream listener, dials ``address``,
    registers (same handshake as a remote endpoint), and starts the five
    relay threads: upstream recv, downstream dispatch, downstream recv
    (hub select over all leaves), heartbeat synthesis, and leaf
    liveness monitoring. Leaves connect to :attr:`leaf_address` with the
    ordinary endpoint CLI (``python -m repro.core.endpoint --connect``)
    — or another Interchange dials it for relay-of-relays nesting.
    """

    def __init__(self, address, token: str, *,
                 name: str = "interchange",
                 listen_host: str = "127.0.0.1", listen_port: int = 0,
                 depth: int = 150_000,
                 router: str = "warming_aware",
                 batch_size: int = 64,
                 heartbeat_interval: float = 0.05,
                 leaf_timeout: float = 0.5,
                 register_timeout: float = 30.0,
                 handshake_timeout: float = 5.0,
                 leaf_window: int = 32,
                 queue_factor: int = 4,
                 leaf_token: Optional[str] = None,
                 dedup_capacity: int = 262_144):
        self.address = (parse_hostport(address)
                        if isinstance(address, str) else address)
        self._token = token
        self.name = name
        self.listen_host = listen_host
        self.listen_port = listen_port
        self.depth = depth
        self.router = make_router(router, tier="endpoint")
        self.batch_size = batch_size
        self.heartbeat_interval = heartbeat_interval
        self.leaf_timeout = leaf_timeout
        self.register_timeout = register_timeout
        self.handshake_timeout = handshake_timeout
        self.leaf_window = leaf_window
        self.queue_factor = queue_factor
        # downstream registration credential: leaves present the same
        # token the interchange uses upstream unless told otherwise
        self.leaf_token = token if leaf_token is None else leaf_token

        # upstream side
        self.endpoint_id: Optional[str] = None
        self.channel: Optional[Channel] = None
        self.transport: Optional[TcpTransport] = None
        self.re_registrations = 0
        self.rejected = False

        # downstream side
        self._reactor: Optional[SocketReactor] = None
        self._listener: Optional[TcpListener] = None
        self._hub = ChannelHub()
        self._leaves: Dict[str, LeafLine] = {}
        self._leaf_counter = itertools.count()
        self._leaf_procs: Dict[str, object] = {}   # LeafProvider children

        # the deep bounded backlog between the two sides
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._backlog: Deque[TaskSpec] = collections.deque()
        self._known: Set[str] = set()       # queued or in flight downstream
        self._completed = _BoundedSet(dedup_capacity)
        self._unsent: Deque[List[ResultMsg]] = collections.deque()
        self._unsent_lock = threading.Lock()

        # function-body cache: leaves pull FnRequest from us; we pull
        # from upstream once per function and fan the body out
        self._fn_lock = threading.Lock()
        self._fn_cache: Dict[str, FnResponse] = {}
        self._fn_waiters: Dict[str, Set[str]] = {}

        # subtree build-cost aggregation (EWMA per warmth key)
        self._costs_lock = threading.Lock()
        self._build_costs: Dict[str, float] = {}

        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self.strategy = None                # ElasticStrategy, if driven

        # metrics
        self.tasks_received = 0
        self.tasks_dispatched = 0
        self.task_envelopes = 0
        self.results_forwarded = 0
        self.requeues = 0
        self.dedup_dropped = 0
        self.backlog_peak = 0

    # ------------------------------------------------------------- lifecycle
    @property
    def leaf_address(self) -> str:
        """``host:port`` leaves (or nested interchanges) dial into."""
        host, port = self._listener.address
        return f"{host}:{port}"

    def start(self) -> str:
        """Listen downstream, register upstream, start the relay loops.
        Returns the endpoint id the upstream tier assigned."""
        self._reactor = SocketReactor()
        self._listener = TcpListener(self.listen_host, self.listen_port,
                                     self._handle_leaf_connection,
                                     reactor=self._reactor)
        # on_connect installed before the first dial: every re-dial —
        # including one racing startup — re-registers under the assigned
        # id (same invariant as RemoteEndpointRunner)
        self.transport = TcpTransport(connect=self.address,
                                      on_connect=self._re_register)
        self.channel = Channel(transport=self.transport)
        self.endpoint_id = self._handshake()
        for tname, fn in [("up-recv", self._upstream_loop),
                          ("dispatch", self._dispatch_loop),
                          ("leaf-recv", self._leaf_recv_loop),
                          ("hb", self._heartbeat_loop),
                          ("monitor", self._monitor_loop)]:
            t = threading.Thread(target=fn, daemon=True,
                                 name=f"ix-{self.name}-{tname}")
            t.start()
            self._threads.append(t)
        return self.endpoint_id

    def stop(self) -> None:
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        if self.strategy is not None:
            self.strategy.stop()
        for proc in list(self._leaf_procs.values()):
            try:
                proc.terminate()
            except Exception:
                pass
        self._leaf_procs.clear()
        with self._lock:
            lines = list(self._leaves.values())
            self._leaves.clear()
        for line in lines:
            self._hub.unregister(line.endpoint_id)
            line.channel.close()
        if self._listener is not None:
            self._listener.close()
        if self._reactor is not None:
            self._reactor.close()
        if self.channel is not None:
            self.channel.close()

    # ------------------------------------------------------ upstream handshake
    def _register_msg(self, endpoint_id: str = "") -> dict:
        return to_wire(Register(name=self.name, token=self._token,
                                endpoint_id=endpoint_id))

    def _handshake(self) -> str:
        """First registration: the upstream recv loop is not running yet,
        so the ack is read straight off the channel (duplicate acks from
        resent Registers are ignored)."""
        deadline = time.time() + self.register_timeout
        while time.time() < deadline:
            if not self.channel.send_to_service(self._register_msg(),
                                                tag="register"):
                time.sleep(0.05)       # still dialing (transport backoff)
                continue
            wire = self.channel.recv_at_endpoint(timeout=2.0)
            if wire is None:
                continue
            env, _tag = wire
            try:
                msg = from_wire(env)
            except (ProtocolError, SerializationError):
                continue
            if isinstance(msg, RegisterAck):
                if not msg.ok:
                    raise RegistrationError(
                        f"interchange registration refused: {msg.error}")
                self.endpoint_id = msg.endpoint_id
                return msg.endpoint_id
        raise RegistrationError(
            f"no RegisterAck from {self.address} "
            f"within {self.register_timeout}s")

    def _re_register(self) -> None:
        """TcpTransport.on_connect — re-attach under the assigned id after
        any upstream cut. The service requeues what it had in flight; our
        ``_known`` intake dedup absorbs the re-dispatch of anything still
        held here, and parked result envelopes flush on the next beat."""
        if self.channel is None or self.endpoint_id is None:
            return
        self.re_registrations += 1
        self.channel.reconnect()
        self.channel.send_to_service(self._register_msg(self.endpoint_id),
                                     tag="register")

    # ----------------------------------------------------- downstream accept
    def _handle_leaf_connection(self, transport: TcpTransport,
                                peer: Tuple[str, int]) -> None:
        """Per-leaf handshake (own thread, spawned by the listener) — the
        same protocol the service speaks, so plain endpoints and nested
        interchanges register identically."""
        channel = Channel(transport=transport)
        msg = None
        deadline = time.time() + self.handshake_timeout
        while time.time() < deadline and not self._stop.is_set():
            wire = channel.recv_at_service(timeout=0.25)
            if wire is None:
                continue
            env, _tag = wire
            try:
                m = from_wire(env)
            except (ProtocolError, SerializationError):
                continue
            if isinstance(m, Register):
                msg = m
                break
        if msg is None:
            channel.close()
            return
        if self.leaf_token and not hmac.compare_digest(msg.token,
                                                       self.leaf_token):
            channel.send_to_endpoint(to_wire(RegisterAck(
                ok=False, error="interchange: leaf token mismatch")),
                tag="register")
            channel.close()
            return
        if msg.endpoint_id:            # reattach after a connection loss
            with self._lock:
                line = self._leaves.get(msg.endpoint_id)
            if line is None:
                channel.send_to_endpoint(to_wire(RegisterAck(
                    ok=False, error=f"unknown leaf {msg.endpoint_id}")),
                    tag="register")
                channel.close()
                return
            eid = msg.endpoint_id
            self._reattach_leaf(line, channel)
        else:
            eid = f"{self.name}/leaf{next(self._leaf_counter)}"
            line = LeafLine(eid, channel, self._lock)
            with self._lock:
                self._leaves[eid] = line
            self._hub.register(eid, channel)
        channel.send_to_endpoint(
            to_wire(RegisterAck(ok=True, endpoint_id=eid)), tag="register")
        with self._cond:
            self._cond.notify()

    def _reattach_leaf(self, line: LeafLine, channel: Channel) -> None:
        with self._lock:
            old = line.channel
            line.channel = channel
            line.connected = True
            line.last_heartbeat = time.time()
        self._hub.unregister(line.endpoint_id)
        self._hub.register(line.endpoint_id, channel)
        if old is not channel:
            old.close()
        self.requeue_in_flight(line)

    def remove_leaf(self, endpoint_id: str) -> None:
        """Reap one leaf (provider scale-in, or operator action): its
        in-flight specs go back into the backlog for redispatch."""
        with self._lock:
            line = self._leaves.pop(endpoint_id, None)
        if line is None:
            return
        self._hub.unregister(endpoint_id)
        self.requeue_in_flight(line)
        line.channel.close()

    def leaf_lines(self) -> List[LeafLine]:
        with self._lock:
            return list(self._leaves.values())

    def leaf_infos(self) -> List[EndpointInfo]:
        return [ln.info() for ln in self.leaf_lines()]

    # ------------------------------------------------------- upstream intake
    def _upstream_loop(self) -> None:
        while not self._stop.is_set():
            wire = self.channel.recv_at_endpoint(timeout=0.05)
            if wire is None:
                continue
            env, _tag = wire
            try:
                msg = from_wire(env)
            except (ProtocolError, SerializationError):
                continue               # poison frame: drop, keep the loop
            if isinstance(msg, TaskBatch):
                self._absorb(msg.tasks)
            elif isinstance(msg, FnResponse):
                self._handle_fn_response(msg)
            elif isinstance(msg, RegisterAck):
                if not msg.ok:
                    self.rejected = True

    def _absorb(self, specs: List[TaskSpec]) -> None:
        """Take one upstream TaskBatch into the backlog. Payloads stay
        packed (opaque ``PackedBuffer`` frames) — this is the queueing
        hop, not a serialization hop. Duplicates of tasks still held
        here (the service requeued in-flight work across a reconnect)
        are dropped; anything already completed re-executes downstream
        and the upstream ``task.done`` check drops the extra result."""
        if not specs:
            return
        t_recv = now()
        fresh = []
        with self._cond:
            for s in specs:
                if s.task_id in self._known:
                    self.dedup_dropped += 1
                    continue
                self._known.add(s.task_id)
                fresh.append(s)
            self._backlog.extend(fresh)
            depth = len(self._backlog)
            if depth > self.backlog_peak:
                self.backlog_peak = depth
            if fresh:
                self._cond.notify()
        self.tasks_received += len(fresh)
        self.channel.send_to_service(
            to_wire(Ack(task_ids=[s.task_id for s in specs],
                        t_endpoint_recv=t_recv)), tag="ack")

    # ---------------------------------------------------- downstream dispatch
    def _pop_run(self, limit: int) -> List[TaskSpec]:
        """Pop up to ``limit`` consecutive backlog specs sharing one
        (warmth_key, container_type) — a run routes as one packed
        TaskBatch to one leaf. Caller must hold the lock."""
        q = self._backlog
        specs: List[TaskSpec] = []
        if not q:
            return specs
        key = (q[0].warmth_key, q[0].container_type)
        while q and len(specs) < limit and \
                (q[0].warmth_key, q[0].container_type) == key:
            specs.append(q.popleft())
        return specs

    def _requeue_front(self, specs: List[TaskSpec]) -> None:
        """Caller must hold the lock."""
        self._backlog.extendleft(reversed(specs))

    def _eligible_lines(self) -> List[LeafLine]:
        return [ln for ln in self.leaf_lines()
                if ln.connected and ln.channel.connected
                and ln.window(self.leaf_window, self.queue_factor) > 0]

    def _dispatch_loop(self) -> None:
        while not self._stop.is_set():
            with self._cond:
                if not self._backlog:
                    self._cond.wait(timeout=0.05)
                    continue
            lines = self._eligible_lines()
            if not lines:
                # backlog but nowhere to send (leaves full/absent): the
                # backlog is the buffer — that's its job
                time.sleep(0.005)
                continue
            self._dispatch_round(lines)

    def _dispatch_round(self, lines: List[LeafLine]) -> None:
        """Drain up to one window's worth of backlog across ``lines``:
        route each key-run with the endpoint-tier policy over the leaf
        snapshots, feeding picks back so a round spreads instead of
        dog-piling the momentary best leaf."""
        by_id = {ln.endpoint_id: ln for ln in lines}
        infos = [ln.info() for ln in lines]
        windows = {ln.endpoint_id:
                   ln.window(self.leaf_window, self.queue_factor)
                   for ln in lines}
        budget = sum(windows.values())
        while budget > 0 and not self._stop.is_set():
            with self._cond:
                specs = self._pop_run(min(self.batch_size, budget))
            if not specs:
                return
            head = specs[0]
            ctx = RoutingContext(warmth_key=head.warmth_key or None,
                                 container_type=head.container_type)
            pool = [i for i in infos if windows[i.endpoint_id] > 0]
            eid = self.router.select_ctx(ctx, pool)
            if eid is None:
                with self._cond:
                    self._requeue_front(specs)
                return
            room = windows[eid]
            if len(specs) > room:
                with self._cond:
                    self._requeue_front(specs[room:])
                specs = specs[:room]
            if self._send_batch(by_id[eid], specs):
                windows[eid] -= len(specs)
                budget -= len(specs)
                for inf in infos:
                    if inf.endpoint_id == eid:
                        for _ in specs:
                            inf.note_pick(ctx)
                        break
            else:
                with self._cond:
                    self._requeue_front(specs)
                return

    def _send_batch(self, line: LeafLine, specs: List[TaskSpec]) -> bool:
        # Record in-flight BEFORE the send: a fast leaf can execute a
        # noop and return its result before this thread re-acquires the
        # lock, and a result that finds no in-flight entry would leak
        # one unit of the leaf's dispatch window forever (enough leaks
        # freeze dispatch with work still in the backlog).
        with self._lock:
            for s in specs:
                line.in_flight[s.task_id] = s
        # scatter-gather re-emit: the packed payload buffers ride behind
        # the envelope as borrowed views — byte-identical through the hop
        env, segs = to_wire_parts(TaskBatch(tasks=specs))
        if not line.channel.send_parts_to_endpoint(env, segs, tag="tasks"):
            with self._lock:
                for s in specs:
                    line.in_flight.pop(s.task_id, None)
            return False
        with self._lock:
            line.sent_since_credit += len(specs)
            line.dispatched += len(specs)
        self.tasks_dispatched += len(specs)
        self.task_envelopes += 1
        return True

    # --------------------------------------------------------- downstream recv
    def _leaf_recv_loop(self) -> None:
        while not self._stop.is_set():
            for eid, buf in self._hub.poll(timeout=0.05):
                with self._lock:
                    line = self._leaves.get(eid)
                if line is None:
                    continue
                try:
                    msg = from_wire(buf.unpack())
                except (ProtocolError, SerializationError):
                    continue
                if isinstance(msg, Heartbeat):
                    self._leaf_heartbeat(line, msg)
                elif isinstance(msg, Ack):
                    pass               # receipt only; specs stay in flight
                elif isinstance(msg, ResultBatch):
                    self._leaf_results(line, msg)
                elif isinstance(msg, ResultMsg):
                    self._leaf_results(line, ResultBatch(results=[msg]))
                elif isinstance(msg, FnRequest):
                    self._leaf_fn_request(line, msg)
                elif isinstance(msg, ResolvePeer):
                    line.channel.send_to_endpoint(to_wire(ResolvePeerAck(
                        req_id=msg.req_id, endpoint_id=msg.endpoint_id,
                        ok=False, error="interchange: no peer signaling")),
                        tag="peer")
                elif isinstance(msg, HubFetch):
                    line.channel.send_to_endpoint(to_wire(PeerData(
                        req_id=msg.req_id, key=msg.key, ok=False,
                        error="interchange: no hub relay")), tag="peer")

    def _leaf_heartbeat(self, line: LeafLine, hb: Heartbeat) -> None:
        line.last_heartbeat = time.time()
        line.advertised = hb
        with self._lock:
            line.sent_since_credit = 0     # credit window refreshed
        if hb.build_costs:
            with self._costs_lock:
                for k, v in hb.build_costs.items():
                    prev = self._build_costs.get(k)
                    self._build_costs[k] = (v if prev is None
                                            else 0.8 * prev + 0.2 * v)
        if not line.connected:
            line.connected = True          # leaf came back
            with self._cond:
                self._cond.notify()

    def _leaf_results(self, line: LeafLine, batch: ResultBatch) -> None:
        if not batch.results:
            return
        fresh: List[ResultMsg] = []
        with self._cond:
            for res in batch.results:
                line.in_flight.pop(res.task_id, None)
                if not self._completed.add(res.task_id):
                    continue       # duplicate (requeue raced a result)
                self._known.discard(res.task_id)
                fresh.append(res)
        if not fresh:
            return
        line.results += len(fresh)
        self.results_forwarded += len(fresh)
        self._forward_results(fresh)

    def _forward_results(self, results: List[ResultMsg]) -> None:
        """Re-emit one ResultBatch upstream, packed results as borrowed
        segments. A refused send (upstream cut) parks the member results
        for batch-wise retransmission by the heartbeat loop — without
        the parking, a result produced during an outage would be lost
        forever (the task is in ``_completed``, so the re-execution the
        service requeues would be dropped as a duplicate here)."""
        env, segs = to_wire_parts(ResultBatch(results=results))
        if not self.channel.send_parts_to_service(env, segs, tag="results"):
            with self._unsent_lock:
                self._unsent.append(results)

    def flush_unsent(self) -> None:
        while True:
            with self._unsent_lock:
                if not self._unsent:
                    return
                results = self._unsent[0]
            env, segs = to_wire_parts(ResultBatch(results=results))
            if not self.channel.send_parts_to_service(env, segs,
                                                      tag="results"):
                return
            with self._unsent_lock:
                self._unsent.popleft()

    # ------------------------------------------------------- function plane
    def _leaf_fn_request(self, line: LeafLine, req: FnRequest) -> None:
        """Leaves pull function bodies from us exactly like they would
        from the service; we pull each body upstream once and serve the
        whole subtree from cache (the leaf's fetch re-sends about once a
        second, so an upstream frame lost to a cut is re-pulled)."""
        fid = req.function_id
        with self._fn_lock:
            resp = self._fn_cache.get(fid)
            if resp is None:
                self._fn_waiters.setdefault(fid, set()).add(line.endpoint_id)
        if resp is not None:
            line.channel.send_to_endpoint(to_wire(resp), tag="fn")
            return
        self.channel.send_to_service(to_wire(FnRequest(function_id=fid)),
                                     tag="fn")

    def _handle_fn_response(self, resp: FnResponse) -> None:
        with self._fn_lock:
            if not resp.error:
                self._fn_cache[resp.function_id] = resp
            waiters = self._fn_waiters.pop(resp.function_id, set())
        for eid in waiters:
            with self._lock:
                line = self._leaves.get(eid)
            if line is not None:
                line.channel.send_to_endpoint(to_wire(resp), tag="fn")

    # ------------------------------------------------- heartbeat + liveness
    def _heartbeat_loop(self) -> None:
        while not self._stop.is_set():
            self.flush_unsent()
            self.channel.send_to_service(to_wire(self._heartbeat()),
                                         tag="hb")
            time.sleep(self.heartbeat_interval)

    def _heartbeat(self) -> Heartbeat:
        """Synthesize the subtree as one endpoint: aggregate load, merged
        warm dicts, aggregated build costs — plus the backpressure fields
        (``credits`` = remaining backlog room) the upstream forwarder
        caps its dispatch against."""
        lines = self.leaf_lines()
        views = []
        queued_down = idle = cap = 0
        with self._lock:
            in_flight = sum(len(ln.in_flight) for ln in lines)
        for ln in lines:
            adv = ln.advertised
            views.append(WarmthView.from_heartbeat(adv))
            queued_down += adv.queued
            idle += adv.idle_workers
            cap += adv.capacity
        merged = WarmthView.merge(views)
        with self._cond:
            backlog = len(self._backlog)
        with self._costs_lock:
            costs = dict(self._build_costs)
        held = backlog + in_flight
        return Heartbeat(endpoint_id=self.endpoint_id or "",
                         ts=time.time(),
                         queued=held + queued_down,
                         idle_workers=idle, capacity=cap,
                         warm_idle=merged.idle, warm_total=merged.total,
                         build_costs=costs,
                         credits=max(0, self.depth - held),
                         backlog=backlog, depth=self.depth)

    def _monitor_loop(self) -> None:
        """Leaf liveness (the per-tier half of requeue-on-disconnect): a
        leaf that misses heartbeats gets its in-flight specs back into
        the central backlog for redispatch to surviving leaves."""
        while not self._stop.is_set():
            time.sleep(self.leaf_timeout / 4)
            cutoff = time.time() - self.leaf_timeout
            for line in self.leaf_lines():
                if line.connected and line.last_heartbeat < cutoff:
                    line.connected = False
                    self.requeue_in_flight(line)

    def requeue_in_flight(self, line: LeafLine) -> None:
        with self._cond:
            specs = [s for s in line.in_flight.values()
                     if s.task_id not in self._completed]
            line.in_flight.clear()
            self._requeue_front(specs)
            self.requeues += len(specs)
            if specs:
                self._cond.notify()

    # ------------------------------------------- ElasticStrategy surface
    def pending_tasks(self) -> int:
        """Queued backlog depth + downstream in-flight — what the
        strategy's backlog_per_block sizing consumes."""
        with self._cond:
            backlog = len(self._backlog)
        with self._lock:
            in_flight = sum(len(ln.in_flight)
                            for ln in self._leaves.values())
        return backlog + in_flight

    def idle_workers(self) -> int:
        return sum(ln.advertised.idle_workers for ln in self.leaf_lines())

    def block_idle(self, leaf_ids: List[str]) -> bool:
        """A provider block (one or more whole leaves) is reapable when
        every member leaf is drained and fully idle. Missing leaves
        (already reaped) don't block the decision."""
        for eid in leaf_ids:
            with self._lock:
                line = self._leaves.get(eid)
            if line is None:
                continue
            adv = line.advertised
            if line.in_flight_count() or adv.queued:
                return False
            if adv.capacity and adv.idle_workers < adv.capacity:
                return False
        return True


# ---------------------------------------------------------------------------
# Providers whose blocks are whole leaves (ElasticStrategy drives these
# against an Interchange instead of a manager-growing EndpointAgent)
# ---------------------------------------------------------------------------

class LeafProvider(Provider):
    """Each block is ``nodes_per_block`` leaf endpoint *subprocesses*
    dialing the interchange's downstream listener — elastic scale-out
    spawns real processes, scale-in terminates them (their in-flight
    work requeues into the backlog)."""

    name = "leaf"

    def __init__(self, interchange: Interchange, *,
                 managers_per_leaf: int = 1, acquire_delay: float = 0.0,
                 spawn_kw: Optional[dict] = None, **kw):
        super().__init__(**kw)
        self.ix = interchange
        self.managers_per_leaf = managers_per_leaf
        self.acquire_delay = acquire_delay
        self.spawn_kw = spawn_kw or {}

    def acquisition_delay(self) -> float:
        return self.acquire_delay

    def start_block(self, endpoint) -> list:
        delay = self.acquisition_delay()
        if delay > 0:
            time.sleep(delay)
        ids = []
        for _ in range(self.nodes_per_block):
            proc, eid = spawn_endpoint_process(
                self.ix.leaf_address, self.ix.leaf_token,
                name=f"{self.ix.name}-leaf",
                n_managers=self.managers_per_leaf,
                workers=self.workers_per_node,
                shm=False, peer=False, **self.spawn_kw)
            self.ix._leaf_procs[eid] = proc
            ids.append(eid)
        return ids

    def stop_block(self, endpoint, leaf_ids: list) -> None:
        for eid in leaf_ids:
            proc = self.ix._leaf_procs.pop(eid, None)
            self.ix.remove_leaf(eid)
            if proc is not None:
                try:
                    proc.terminate()
                except Exception:
                    pass


class ThreadLeafProvider(Provider):
    """In-process variant (tests, examples): each leaf is a full
    :class:`RemoteEndpointRunner` speaking the real wire protocol over
    loopback from threads in this process."""

    name = "leaf-threads"

    def __init__(self, interchange: Interchange, *,
                 managers_per_leaf: int = 1, acquire_delay: float = 0.0,
                 runner_kw: Optional[dict] = None, **kw):
        super().__init__(**kw)
        self.ix = interchange
        self.managers_per_leaf = managers_per_leaf
        self.acquire_delay = acquire_delay
        self.runner_kw = runner_kw or {}
        self._runners: Dict[str, RemoteEndpointRunner] = {}

    def acquisition_delay(self) -> float:
        return self.acquire_delay

    def start_block(self, endpoint) -> list:
        delay = self.acquisition_delay()
        if delay > 0:
            time.sleep(delay)
        ids = []
        for _ in range(self.nodes_per_block):
            runner = RemoteEndpointRunner(
                self.ix.leaf_address, self.ix.leaf_token,
                name=f"{self.ix.name}-leaf",
                n_managers=self.managers_per_leaf,
                workers_per_manager=self.workers_per_node,
                shm=False, peer=False, **self.runner_kw)
            eid = runner.start()
            self._runners[eid] = runner
            ids.append(eid)
        return ids

    def stop_block(self, endpoint, leaf_ids: list) -> None:
        for eid in leaf_ids:
            runner = self._runners.pop(eid, None)
            self.ix.remove_leaf(eid)
            if runner is not None:
                runner.stop()

    def stop_all(self) -> None:
        for eid in list(self._runners):
            self.stop_block(None, [eid])


def spawn_interchange_process(address, token: str, *,
                              name: str = "relay",
                              depth: int = 150_000,
                              min_blocks: int = 0, max_blocks: int = 4,
                              backlog_per_block: int = 0,
                              idle_timeout: float = 2.0,
                              leaf_workers: int = 4,
                              leaf_managers: int = 1,
                              acquire_delay: float = 0.0,
                              extra_args: Optional[list] = None,
                              stderr=None):
    """Spawn ``python -m repro.core.interchange`` as a child process and
    block until its readiness line. Returns
    ``(proc, endpoint_id, leaf_address)`` — dial ``leaf_address`` to hang
    endpoints (or more interchanges) below it."""
    import os
    import subprocess
    import sys
    import tempfile
    if not isinstance(address, str):
        address = f"{address[0]}:{address[1]}"
    src_dir = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
    capture = tempfile.TemporaryFile("w+") if stderr is None else None
    argv = [sys.executable, "-m", "repro.core.interchange",
            "--connect", address, "--token", token, "--name", name,
            "--depth", str(depth),
            "--min-blocks", str(min_blocks),
            "--max-blocks", str(max_blocks),
            "--backlog-per-block", str(backlog_per_block),
            "--idle-timeout", str(idle_timeout),
            "--leaf-workers", str(leaf_workers),
            "--leaf-managers", str(leaf_managers),
            "--acquire-delay", str(acquire_delay)]
    argv += extra_args or []
    proc = subprocess.Popen(
        argv, env=env, stdout=subprocess.PIPE,
        stderr=capture if capture is not None else stderr, text=True)
    line = (proc.stdout.readline() or "").strip()
    if not line.startswith("INTERCHANGE_READY"):
        proc.terminate()
        err = ""
        if capture is not None:
            proc.wait(timeout=5)
            capture.seek(0)
            err = capture.read()
        raise RuntimeError(
            f"interchange subprocess failed (got {line!r}): {err[-2000:]}")
    if capture is not None:
        capture.close()
    fields = line.split()
    leaf_addr = fields[2].partition("=")[2] if len(fields) > 2 else ""
    return proc, fields[1], leaf_addr


def main(argv: Optional[List[str]] = None) -> int:
    from .provisioning import ElasticStrategy
    p = argparse.ArgumentParser(
        prog="python -m repro.core.interchange",
        description="Hierarchical interchange: register upstream as one "
                    "endpoint, fan out downstream to elastic leaf "
                    "endpoint processes over the same wire protocol "
                    "(DESIGN.md §11).")
    p.add_argument("--connect", required=True, metavar="HOST:PORT",
                   help="upstream listener (a FuncXService — or another "
                        "interchange's leaf address, for nesting)")
    p.add_argument("--token", default="",
                   help="bearer token: raw string, or @FILE")
    p.add_argument("--name", default="interchange")
    p.add_argument("--listen-host", default="127.0.0.1")
    p.add_argument("--listen-port", type=int, default=0)
    p.add_argument("--depth", type=int, default=150_000,
                   help="backlog capacity advertised as heartbeat credits")
    p.add_argument("--router", default="warming_aware")
    p.add_argument("--batch", type=int, default=64)
    p.add_argument("--heartbeat", type=float, default=0.05)
    p.add_argument("--leaf-timeout", type=float, default=0.5)
    p.add_argument("--min-blocks", type=int, default=0)
    p.add_argument("--max-blocks", type=int, default=4)
    p.add_argument("--backlog-per-block", type=int, default=0,
                   help="tasks one leaf block absorbs (ElasticStrategy "
                        "backlog-depth sizing; 0 = pending-vs-idle)")
    p.add_argument("--idle-timeout", type=float, default=2.0)
    p.add_argument("--strategy-interval", type=float, default=0.05)
    p.add_argument("--leaf-workers", type=int, default=4)
    p.add_argument("--leaf-managers", type=int, default=1)
    p.add_argument("--acquire-delay", type=float, default=0.0,
                   help="simulated scheduler/cloud acquisition delay per "
                        "leaf block")
    args = p.parse_args(argv)
    token = args.token
    if token.startswith("@"):
        with open(token[1:]) as f:
            token = f.read().strip()
    ix = Interchange(args.connect, token, name=args.name,
                     listen_host=args.listen_host,
                     listen_port=args.listen_port,
                     depth=args.depth, router=args.router,
                     batch_size=args.batch,
                     heartbeat_interval=args.heartbeat,
                     leaf_timeout=args.leaf_timeout)
    eid = ix.start()
    provider = LeafProvider(ix, workers_per_node=args.leaf_workers,
                            managers_per_leaf=args.leaf_managers,
                            acquire_delay=args.acquire_delay)
    strategy = ElasticStrategy(ix, provider,
                               min_blocks=args.min_blocks,
                               max_blocks=args.max_blocks,
                               backlog_per_block=args.backlog_per_block,
                               idle_timeout=args.idle_timeout,
                               interval=args.strategy_interval)
    ix.strategy = strategy
    strategy.start()
    # parseable readiness line — parents wait on this before submitting
    print(f"INTERCHANGE_READY {eid} leaf={ix.leaf_address}", flush=True)
    # SIGTERM (what a supervising parent's .terminate() sends) must run
    # the same shutdown as Ctrl-C: ix.stop() reaps the elastic leaf
    # subprocesses, which would otherwise outlive the relay as orphans.
    def _terminate(signum, frame):
        raise KeyboardInterrupt
    signal.signal(signal.SIGTERM, _terminate)
    try:
        while True:
            time.sleep(0.5)
    except KeyboardInterrupt:
        pass
    finally:
        strategy.stop()
        ix.stop()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
