"""Identity and access management (paper §4.7 — the Globus Auth tier).

Reproduces the *protocol shape*: scoped bearer tokens, endpoint agents as
native clients with dependent scopes, delegation (a user grants another
identity a subset of their scopes), and per-API scope enforcement. Tokens
are HMAC-signed (stdlib) rather than OAuth2 — the flows are the same.
"""
from __future__ import annotations

import hashlib
import hmac
import json
import os
import threading
import time
import uuid
from dataclasses import dataclass
from typing import FrozenSet, Iterable, Optional, Set

from .errors import AuthError

# funcX-style scopes
SCOPE_REGISTER_FUNCTION = "urn:repro:auth:scope:register_function"
SCOPE_RUN = "urn:repro:auth:scope:run"
SCOPE_ENDPOINT = "urn:repro:auth:scope:endpoint"
SCOPE_TRANSFER = "urn:repro:auth:scope:transfer"
ALL_SCOPES = frozenset({SCOPE_REGISTER_FUNCTION, SCOPE_RUN, SCOPE_ENDPOINT,
                        SCOPE_TRANSFER})


@dataclass(frozen=True)
class Token:
    token_id: str
    identity: str
    scopes: FrozenSet[str]
    issued_by: str                 # == identity unless delegated
    expires: float
    signature: str

    def encode(self) -> str:
        return json.dumps({
            "token_id": self.token_id, "identity": self.identity,
            "scopes": sorted(self.scopes), "issued_by": self.issued_by,
            "expires": self.expires, "signature": self.signature})

    @classmethod
    def decode(cls, s: str) -> "Token":
        """Inverse of :meth:`encode` — how a bearer token crosses a
        process boundary (e.g. the ``--token`` argument of a remote
        endpoint agent). The signature still has to validate against the
        issuing service's secret; decoding grants nothing by itself."""
        try:
            d = json.loads(s)
            return cls(token_id=d["token_id"], identity=d["identity"],
                       scopes=frozenset(d["scopes"]),
                       issued_by=d["issued_by"], expires=float(d["expires"]),
                       signature=d["signature"])
        except (ValueError, KeyError, TypeError) as e:
            raise AuthError(f"malformed token: {e}") from e


class AuthService:
    def __init__(self, ttl: float = 3600.0):
        self._secret = os.urandom(32)
        self._identities: Set[str] = set()
        self._revoked: Set[str] = set()
        self._lock = threading.RLock()
        self.ttl = ttl

    def _sign(self, token_id: str, identity: str, scopes: Iterable[str],
              issued_by: str, expires: float) -> str:
        msg = f"{token_id}|{identity}|{','.join(sorted(scopes))}|" \
              f"{issued_by}|{expires:.3f}".encode()
        return hmac.new(self._secret, msg, hashlib.sha256).hexdigest()

    def register_identity(self, name: str) -> str:
        with self._lock:
            self._identities.add(name)
        return name

    def issue(self, identity: str, scopes: Iterable[str],
              issued_by: Optional[str] = None) -> Token:
        with self._lock:
            if identity not in self._identities:
                raise AuthError(f"unknown identity {identity!r}")
        scopes = frozenset(scopes)
        bad = scopes - ALL_SCOPES
        if bad:
            raise AuthError(f"unknown scopes {bad}")
        token_id = str(uuid.uuid4())
        expires = time.time() + self.ttl
        sig = self._sign(token_id, identity, scopes, issued_by or identity,
                         expires)
        return Token(token_id, identity, scopes, issued_by or identity,
                     expires, sig)

    def validate(self, token: Token, required_scope: str) -> str:
        """Returns the authenticated identity or raises AuthError."""
        if token.token_id in self._revoked:
            raise AuthError("token revoked")
        if time.time() > token.expires:
            raise AuthError("token expired")
        expect = self._sign(token.token_id, token.identity, token.scopes,
                            token.issued_by, token.expires)
        if not hmac.compare_digest(expect, token.signature):
            raise AuthError("bad signature")
        if required_scope not in token.scopes:
            raise AuthError(f"missing scope {required_scope}")
        return token.identity

    def delegate(self, token: Token, to_identity: str,
                 scopes: Iterable[str]) -> Token:
        """Secure delegation (paper: 'a user may allow the funcX service or
        another user to access their endpoint'). Subset-of-scopes only."""
        self.validate(token, next(iter(token.scopes)))
        scopes = frozenset(scopes)
        if not scopes <= token.scopes:
            raise AuthError("delegation must narrow scopes")
        with self._lock:
            self._identities.add(to_identity)
        return self.issue(to_identity, scopes, issued_by=token.identity)

    def revoke(self, token: Token) -> None:
        with self._lock:
            self._revoked.add(token.token_id)


# ---------------------------------------------------------------------------
# Peer-tokens (peer data plane, DESIGN.md §9).
#
# Unlike the bearer tokens above these are *capability grants for one
# producer*: the service holds a per-endpoint peer secret (shared with that
# endpoint at registration), and signs (producer, consumer, expires) with
# it. The producer's PeerServer validates incoming grants against its own
# secret — entirely offline, no service round-trip on the data path. TTLs
# are short (seconds to minutes): a consumer re-resolves through the
# service when its grant lapses, which is also the hook that lets the
# service stop brokering a producer whose store dropped the refs.

PEER_TOKEN_TTL = 60.0


def _peer_sign(secret: bytes, producer: str, consumer: str,
               expires: float) -> str:
    msg = f"{producer}|{consumer}|{expires:.3f}".encode()
    return hmac.new(secret, msg, hashlib.sha256).hexdigest()


def mint_peer_token(secret: bytes, producer: str, consumer: str,
                    ttl: float = PEER_TOKEN_TTL) -> "tuple[str, float]":
    """Returns ``(token, expires)`` granting ``consumer`` fetch access to
    ``producer``'s PeerServer until ``expires``."""
    expires = time.time() + ttl
    sig = _peer_sign(secret, producer, consumer, expires)
    tok = json.dumps({"producer": producer, "consumer": consumer,
                      "expires": expires, "sig": sig})
    return tok, expires


def validate_peer_token(secret: bytes, token: str, producer: str) -> str:
    """Returns the consumer identity or raises AuthError."""
    try:
        d = json.loads(token)
        t_producer = d["producer"]
        consumer = d["consumer"]
        expires = float(d["expires"])
        sig = d["sig"]
    except (ValueError, KeyError, TypeError) as e:
        raise AuthError(f"malformed peer token: {e}") from e
    if t_producer != producer:
        raise AuthError("peer token for a different producer")
    if time.time() > expires:
        raise AuthError("peer token expired")
    expect = _peer_sign(secret, t_producer, consumer, expires)
    if not hmac.compare_digest(expect, sig):
        raise AuthError("bad peer token signature")
    return consumer
