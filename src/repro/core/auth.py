"""Identity and access management (paper §4.7 — the Globus Auth tier).

Reproduces the *protocol shape*: scoped bearer tokens, endpoint agents as
native clients with dependent scopes, delegation (a user grants another
identity a subset of their scopes), and per-API scope enforcement. Tokens
are HMAC-signed (stdlib) rather than OAuth2 — the flows are the same.
"""
from __future__ import annotations

import hashlib
import hmac
import json
import os
import threading
import time
import uuid
from dataclasses import dataclass
from typing import FrozenSet, Iterable, Optional, Set

from .errors import AuthError

# funcX-style scopes
SCOPE_REGISTER_FUNCTION = "urn:repro:auth:scope:register_function"
SCOPE_RUN = "urn:repro:auth:scope:run"
SCOPE_ENDPOINT = "urn:repro:auth:scope:endpoint"
SCOPE_TRANSFER = "urn:repro:auth:scope:transfer"
ALL_SCOPES = frozenset({SCOPE_REGISTER_FUNCTION, SCOPE_RUN, SCOPE_ENDPOINT,
                        SCOPE_TRANSFER})


@dataclass(frozen=True)
class Token:
    token_id: str
    identity: str
    scopes: FrozenSet[str]
    issued_by: str                 # == identity unless delegated
    expires: float
    signature: str

    def encode(self) -> str:
        return json.dumps({
            "token_id": self.token_id, "identity": self.identity,
            "scopes": sorted(self.scopes), "issued_by": self.issued_by,
            "expires": self.expires, "signature": self.signature})

    @classmethod
    def decode(cls, s: str) -> "Token":
        """Inverse of :meth:`encode` — how a bearer token crosses a
        process boundary (e.g. the ``--token`` argument of a remote
        endpoint agent). The signature still has to validate against the
        issuing service's secret; decoding grants nothing by itself."""
        try:
            d = json.loads(s)
            return cls(token_id=d["token_id"], identity=d["identity"],
                       scopes=frozenset(d["scopes"]),
                       issued_by=d["issued_by"], expires=float(d["expires"]),
                       signature=d["signature"])
        except (ValueError, KeyError, TypeError) as e:
            raise AuthError(f"malformed token: {e}") from e


class AuthService:
    def __init__(self, ttl: float = 3600.0):
        self._secret = os.urandom(32)
        self._identities: Set[str] = set()
        self._revoked: Set[str] = set()
        self._lock = threading.RLock()
        self.ttl = ttl

    def _sign(self, token_id: str, identity: str, scopes: Iterable[str],
              issued_by: str, expires: float) -> str:
        msg = f"{token_id}|{identity}|{','.join(sorted(scopes))}|" \
              f"{issued_by}|{expires:.3f}".encode()
        return hmac.new(self._secret, msg, hashlib.sha256).hexdigest()

    def register_identity(self, name: str) -> str:
        with self._lock:
            self._identities.add(name)
        return name

    def issue(self, identity: str, scopes: Iterable[str],
              issued_by: Optional[str] = None) -> Token:
        with self._lock:
            if identity not in self._identities:
                raise AuthError(f"unknown identity {identity!r}")
        scopes = frozenset(scopes)
        bad = scopes - ALL_SCOPES
        if bad:
            raise AuthError(f"unknown scopes {bad}")
        token_id = str(uuid.uuid4())
        expires = time.time() + self.ttl
        sig = self._sign(token_id, identity, scopes, issued_by or identity,
                         expires)
        return Token(token_id, identity, scopes, issued_by or identity,
                     expires, sig)

    def validate(self, token: Token, required_scope: str) -> str:
        """Returns the authenticated identity or raises AuthError."""
        if token.token_id in self._revoked:
            raise AuthError("token revoked")
        if time.time() > token.expires:
            raise AuthError("token expired")
        expect = self._sign(token.token_id, token.identity, token.scopes,
                            token.issued_by, token.expires)
        if not hmac.compare_digest(expect, token.signature):
            raise AuthError("bad signature")
        if required_scope not in token.scopes:
            raise AuthError(f"missing scope {required_scope}")
        return token.identity

    def delegate(self, token: Token, to_identity: str,
                 scopes: Iterable[str]) -> Token:
        """Secure delegation (paper: 'a user may allow the funcX service or
        another user to access their endpoint'). Subset-of-scopes only."""
        self.validate(token, next(iter(token.scopes)))
        scopes = frozenset(scopes)
        if not scopes <= token.scopes:
            raise AuthError("delegation must narrow scopes")
        with self._lock:
            self._identities.add(to_identity)
        return self.issue(to_identity, scopes, issued_by=token.identity)

    def revoke(self, token: Token) -> None:
        with self._lock:
            self._revoked.add(token.token_id)
