"""Typed wire protocol for the service↔endpoint channel (paper §4.5).

One dataclass per message kind, replacing the ad-hoc ``{"type": ...}``
dict envelopes that used to be assembled by hand on both ends of the
channel. Messages still travel as plain dicts (the serialization facade
msgpacks dicts on its fast path), but every encode/decode goes through
the single ``to_wire`` / ``from_wire`` entry point, so field names exist
in exactly one place.

Wire kinds:

  ``task_batch``    service → endpoint   batch of TaskSpec (internal batching §4.6)
  ``ack``           endpoint → service   receipt of a batch (hierarchical queuing)
  ``heartbeat``     endpoint → service   liveness + load/warm-container
                                         advertisement (feeds federation routing)
  ``result``        endpoint → service   one task outcome (legacy/lone form)
  ``result_batch``  endpoint → service   coalesced outcomes + receipt acks —
                                         the batched return path (§4.6 made
                                         symmetric; see DESIGN.md §6)
  ``register``      endpoint → service   transport handshake: authenticate and
                                         attach (or re-attach) an endpoint that
                                         dialed in over a socket transport
  ``register_ack``  service → endpoint   handshake outcome + assigned endpoint id
  ``fn_request``    endpoint → service   fetch a registered function's body
  ``fn_response``   service → endpoint   serialized function bytes (funcX ships
                                         serialized function bodies to agents)

Peer data plane (DESIGN.md §9) — the third topology, endpoint↔endpoint:

  ``resolve_peer``      endpoint → service   where does endpoint X's PeerServer
                                             listen? (service-brokered signaling)
  ``resolve_peer_ack``  service → endpoint   producer address + short-TTL HMAC
                                             peer-token minted for the consumer
  ``peer_get``          endpoint → endpoint  fetch raw store bytes behind a
                                             DataRef key (direct TCP); also
                                             service → endpoint on hub relay
  ``peer_data``         endpoint → endpoint  the bytes (zero-copy segment) or
                                             the refusal; also rides the hub
                                             channels on relay fallback
  ``hub_fetch``         endpoint → service   relay fallback: ask the service to
                                             pull the key over the producer's
                                             already-attached hub channel

Pack-once data plane (DESIGN.md §5): task payloads and result values that
are already :class:`~repro.serialization.PackedBuffer`\\ s travel inside the
envelope as **opaque byte frames** (msgpack bin — one memcpy, zero
re-serialization) under the ``payload_b`` / ``result_b`` keys, and are
re-wrapped as PackedBuffers on decode without touching the payload bytes.
Plain objects keep the legacy inline embedding, so hand-built messages and
endpoint-internal requeues are unaffected.

Scatter-gather frames (DESIGN.md §7): even the msgpack bin embed is one
forced memcpy per payload. When the caller passes a ``segments`` list to
``to_wire`` (see :func:`to_wire_parts`), payloads at or above
``SEGMENT_MIN`` bytes are **borrowed** instead of embedded: the envelope
records only a segment index (``payload_seg`` / ``result_seg``) and the
raw buffer rides as its own length-prefixed frame segment. Transports
gather the segments with vectored I/O; the decoder re-attaches them from
the reserved ``_segs`` envelope key without copying. Envelopes encoded
without a segments list are byte-identical to the pre-segment wire
format, so mixed-version peers interoperate.
"""
from __future__ import annotations

from dataclasses import dataclass, field, fields
from typing import Any, ClassVar, Dict, List, Optional, Tuple

from ..serialization import PackedBuffer

# Payloads below this embed inline (one small memcpy beats an extra iovec
# entry plus a 4-byte segment-table slot); at or above it they ride as
# borrowed zero-copy segments.
SEGMENT_MIN = 1024


class _WireStats:
    """Process-wide gauge counters for the zero-copy claim: how many
    PackedBuffer payload bytes were embedded into envelopes (one memcpy
    each) vs borrowed as segments (zero copies). benchmarks/latency.py
    derives ``copies_per_payload_byte`` from these."""

    __slots__ = ("embedded_payload_bytes", "segment_payload_bytes")

    def __init__(self) -> None:
        self.reset()

    def reset(self) -> None:
        self.embedded_payload_bytes = 0
        self.segment_payload_bytes = 0


WIRE_STATS = _WireStats()


class ProtocolError(Exception):
    pass


def _emit_payload(d: dict, key: str, data,
                  segments: Optional[list]) -> None:
    """Embed a packed payload inline (``key_b``) or borrow it as a frame
    segment (``key_seg``) depending on size and whether the caller's
    transport can gather segments at all."""
    if segments is not None and len(data) >= SEGMENT_MIN:
        d[key + "_seg"] = len(segments)
        segments.append(data)
        WIRE_STATS.segment_payload_bytes += len(data)
    else:
        d[key + "_b"] = data
        WIRE_STATS.embedded_payload_bytes += len(data)


@dataclass
class TaskSpec:
    """One task as shipped service → endpoint (element of a TaskBatch)."""
    task_id: str
    function_id: str
    container_type: str
    payload: Any = None
    stamps: Dict[str, float] = field(default_factory=dict)
    # Warmth key refining the container type (DESIGN.md §10): routes the
    # task toward workers advertising this key warm. Empty = the
    # container type itself (the paper's original behaviour).
    warmth_key: str = ""
    # Endpoint-internal only (set when a lost manager's task is requeued
    # with its already-resolved function); never serialized.
    resolved: Optional[Tuple] = None

    def to_dict(self, segments: Optional[list] = None) -> dict:
        d = {"task_id": self.task_id, "function_id": self.function_id,
             "container_type": self.container_type}
        if self.warmth_key:
            d["warmth_key"] = self.warmth_key
        if self.stamps:
            d["stamps"] = self.stamps
        if isinstance(self.payload, PackedBuffer):
            _emit_payload(d, "payload", self.payload.data, segments)
        elif self.payload is not None:
            d["payload"] = self.payload
        return d

    @classmethod
    def from_dict(cls, d: dict,
                  segments: Optional[list] = None) -> "TaskSpec":
        pb = d.get("payload_b")
        if pb is None and segments is not None:
            seg = d.get("payload_seg")
            if seg is not None:
                pb = segments[seg]
        payload = (PackedBuffer.from_bytes(pb) if pb is not None
                   else d.get("payload"))
        return cls(task_id=d["task_id"], function_id=d["function_id"],
                   container_type=d["container_type"],
                   payload=payload, stamps=dict(d.get("stamps", {})),
                   warmth_key=d.get("warmth_key", ""))


@dataclass
class TaskBatch:
    kind: ClassVar[str] = "task_batch"
    tasks: List[TaskSpec] = field(default_factory=list)


@dataclass
class Ack:
    kind: ClassVar[str] = "ack"
    task_ids: List[str] = field(default_factory=list)
    t_endpoint_recv: float = 0.0


@dataclass
class Heartbeat:
    """Liveness beacon. Beyond bare liveness it advertises the endpoint's
    load and warm-container state so the service-side EndpointRouter can
    do federation-level warming-aware placement (paper §6.2, lifted one
    tier up)."""
    kind: ClassVar[str] = "heartbeat"
    endpoint_id: str = ""
    ts: float = 0.0
    queued: int = 0                    # tasks pending inside the endpoint
    idle_workers: int = 0
    capacity: int = 0                  # total workers across managers
    warm_idle: Dict[str, int] = field(default_factory=dict)
    warm_total: Dict[str, int] = field(default_factory=dict)
    # Store inventory advertisement (peer data plane, DESIGN.md §9):
    # version-stamped like the warm-container dicts — the service uses the
    # version to invalidate peer grants whose producer evicted refs, without
    # the endpoint shipping a key list every beat.
    store_version: int = 0
    store_keys: int = 0
    store_bytes: int = 0
    # Measured cold-build costs (warmth_key → EWMA seconds), aggregated
    # endpoint-side from worker build reports. The service feeds these to
    # cost-aware federation routers (observe_build — DESIGN.md §10), so
    # cold-cost estimates track reality instead of default_cold_cost.
    build_costs: Dict[str, float] = field(default_factory=dict)
    # Backpressure advertisement (DESIGN.md §11). An interchange — or any
    # endpoint with a bounded intake — advertises how many more tasks it
    # can absorb (``credits``) and how deep its local backlog already is
    # (``backlog``). credits < 0 means "unbounded / not advertised" so
    # plain endpoints (which never set it) keep today's behaviour; the
    # upstream forwarder caps queue+in_flight at ``credits`` when it is
    # >= 0. ``depth`` mirrors the bounded-queue capacity for gauges.
    credits: int = -1
    backlog: int = 0
    depth: int = 0


@dataclass
class ResultMsg:
    kind: ClassVar[str] = "result"
    task_id: str = ""
    status: str = "SUCCESS"            # SUCCESS | FAILED | LOST
    result: Any = None
    error: Optional[str] = None
    remote_traceback: str = ""
    stamps: Dict[str, float] = field(default_factory=dict)
    cold_start: bool = False
    build_time: float = 0.0
    worker_id: str = ""
    manager_id: str = ""

    # field-name tuple resolved once — fields() per message is measurable
    # at batch decode rates (set right after the class body below)
    _FIELDS: ClassVar[Tuple[str, ...]] = ()

    def to_dict(self, segments: Optional[list] = None) -> dict:
        """Wire dict for this outcome — standalone envelope body and
        ``ResultBatch`` element share it. A packed result travels as an
        opaque byte frame (``result_b``) or, when the caller gathers
        segments and the value is large enough, as a borrowed zero-copy
        segment (``result_seg``) — same scheme as ``TaskSpec.payload_b``.
        Default-valued fields are omitted (``from_dict`` restores the
        defaults): at 32 results per envelope, encoding five always-empty
        fields per result is real batch-path work."""
        d: Dict[str, Any] = {"task_id": self.task_id, "status": self.status}
        if isinstance(self.result, PackedBuffer):
            _emit_payload(d, "result", self.result.data, segments)
        elif self.result is not None:
            d["result"] = self.result
        if self.stamps:
            d["stamps"] = self.stamps
        if self.error:
            d["error"] = self.error
        if self.remote_traceback:
            d["remote_traceback"] = self.remote_traceback
        if self.cold_start:
            d["cold_start"] = True
        if self.build_time:
            d["build_time"] = self.build_time
        if self.worker_id:
            d["worker_id"] = self.worker_id
        if self.manager_id:
            d["manager_id"] = self.manager_id
        return d

    @classmethod
    def from_dict(cls, d: dict,
                  segments: Optional[list] = None) -> "ResultMsg":
        kwargs = {name: d[name] for name in cls._FIELDS if name in d}
        rb = d.get("result_b")
        if rb is None and segments is not None:
            seg = d.get("result_seg")
            if seg is not None:
                rb = segments[seg]
        if rb is not None:
            kwargs["result"] = PackedBuffer.from_bytes(rb)
        return cls(**kwargs)


ResultMsg._FIELDS = tuple(f.name for f in fields(ResultMsg))


@dataclass
class ResultBatch:
    """Coalesced return path (DESIGN.md §6): N task outcomes and any
    pending receipt acks in **one** wire envelope. The forward path has
    batched since PR 1 (``TaskBatch``); this is the symmetric half — the
    endpoint's result coalescer fills it under load and degenerates to a
    single-element batch when the line is idle, so lone tasks pay no
    extra latency while loaded lines pay one envelope per ~batch_size
    completions. Each member result keeps pack-once semantics (its
    ``PackedBuffer`` bytes embed verbatim via ``result_b``)."""
    kind: ClassVar[str] = "result_batch"
    results: List[ResultMsg] = field(default_factory=list)
    acks: List[Ack] = field(default_factory=list)


@dataclass
class Register:
    """Socket-transport handshake, endpoint → service: the first frame on
    a freshly dialed connection. ``token`` is a :meth:`Token.encode` string
    (validated against the service's AuthService); a non-empty
    ``endpoint_id`` asks to re-attach to an existing registration after a
    connection loss — the service swaps the line's channel and requeues
    whatever was in flight (requeue-on-disconnect semantics).

    ``host`` + ``shm`` advertise the shared-memory fast path (DESIGN.md
    §7): when the service sees its own hostname and a loopback peer it
    may offer a ring pair in the ack. Old peers ignore both fields."""
    kind: ClassVar[str] = "register"
    name: str = ""
    token: str = ""
    endpoint_id: str = ""
    host: str = ""                     # endpoint's hostname (shm negotiation)
    shm: bool = False                  # endpoint can attach shm rings
    peer_addr: str = ""                # host:port of the PeerServer (DESIGN §9)


@dataclass
class RegisterAck:
    """``shm``, when non-empty, is the service's shared-memory ring offer:
    ``{"s2e": <ring name>, "e2s": <ring name>, "size": <capacity>}``. The
    endpoint answers with :class:`ShmAttach` over TCP; until that lands
    (or if attach fails) both sides keep talking plain TCP."""
    kind: ClassVar[str] = "register_ack"
    ok: bool = True
    endpoint_id: str = ""
    error: str = ""
    shm: Dict[str, Any] = field(default_factory=dict)
    # Per-endpoint peer secret (hex), minted at first registration and
    # stable across re-attach: the endpoint's PeerServer validates incoming
    # peer-tokens against it locally — no service round-trip per fetch.
    peer_secret: str = ""


@dataclass
class ShmAttach:
    """Endpoint → service confirmation of a ring offer. ``ok=False`` (or
    the service never hearing back before the line drops) releases the
    rings and leaves the line on TCP — graceful fallback."""
    kind: ClassVar[str] = "shm_attach"
    endpoint_id: str = ""
    ok: bool = False
    ring: str = ""         # s2e segment name — ties the confirm to its offer
    error: str = ""


@dataclass
class FnRequest:
    """Endpoint-side function fetch over the wire (funcX endpoints pull
    serialized function bodies from the service on first use)."""
    kind: ClassVar[str] = "fn_request"
    function_id: str = ""


@dataclass
class FnResponse:
    kind: ClassVar[str] = "fn_response"
    function_id: str = ""
    payload: bytes = b""               # pickled function body
    wants_env: bool = False
    error: str = ""


@dataclass
class ResolvePeer:
    """Signaling lookup, consumer endpoint → service: where does
    ``endpoint_id``'s PeerServer listen, and mint me a token for it."""
    kind: ClassVar[str] = "resolve_peer"
    req_id: str = ""
    endpoint_id: str = ""              # producer being resolved
    consumer: str = ""                 # requesting endpoint


@dataclass
class ResolvePeerAck:
    kind: ClassVar[str] = "resolve_peer_ack"
    req_id: str = ""
    endpoint_id: str = ""
    ok: bool = False
    addr: str = ""                     # producer's peer listen address
    token: str = ""                    # short-TTL HMAC peer-token
    expires: float = 0.0               # epoch seconds the token dies
    error: str = ""


@dataclass
class PeerGet:
    """Fetch the raw store bytes behind a key. Direct form travels on a
    peer TCP connection and must carry a valid peer-token; the relay form
    travels service → producer over the (already authenticated) hub
    channel with an empty token."""
    kind: ClassVar[str] = "peer_get"
    req_id: str = ""
    key: str = ""
    token: str = ""
    consumer: str = ""


@dataclass
class HubFetch:
    """Relay fallback, consumer endpoint → service: pull ``key`` from the
    producer's store over its hub channel because the direct dial failed.
    The answer comes back as a :class:`PeerData` with the same req_id."""
    kind: ClassVar[str] = "hub_fetch"
    req_id: str = ""
    endpoint_id: str = ""              # producer
    key: str = ""


@dataclass
class PeerData:
    """The bytes behind a PeerGet/HubFetch — or the refusal. ``data`` is
    the producer store's raw value, verbatim (usually a pack() frame, but
    the store owes no such guarantee — it stays opaque bytes end to end);
    it rides as an inline byte embed or, on segment-gathering transports,
    as a borrowed zero-copy segment (same scheme as ``result_b``)."""
    kind: ClassVar[str] = "peer_data"
    req_id: str = ""
    key: str = ""
    ok: bool = False
    data: Any = None                   # raw store bytes (bytes/memoryview)
    error: str = ""

    def to_dict(self, segments: Optional[list] = None) -> dict:
        d: Dict[str, Any] = {"req_id": self.req_id, "key": self.key,
                             "ok": self.ok}
        data = self.data
        if isinstance(data, PackedBuffer):
            data = data.data
        if data is not None:
            _emit_payload(d, "data", data, segments)
        if self.error:
            d["error"] = self.error
        return d

    @classmethod
    def from_dict(cls, d: dict,
                  segments: Optional[list] = None) -> "PeerData":
        db = d.get("data_b")
        if db is None and segments is not None:
            seg = d.get("data_seg")
            if seg is not None:
                db = segments[seg]
        return cls(req_id=d.get("req_id", ""), key=d.get("key", ""),
                   ok=d.get("ok", False), data=db,
                   error=d.get("error", ""))


Message = object                      # union of the classes below
WIRE_TYPES = {cls.kind: cls for cls in (
    TaskBatch, Ack, Heartbeat, ResultMsg, ResultBatch,
    Register, RegisterAck, ShmAttach, FnRequest, FnResponse,
    ResolvePeer, ResolvePeerAck, PeerGet, HubFetch, PeerData)}


def to_wire(msg, segments: Optional[list] = None) -> dict:
    """Encode a protocol message to its wire dict (``{"type": kind, ...}``).

    With ``segments`` (a list the caller owns), large packed payloads are
    appended to it as borrowed buffers instead of being embedded — the
    transport then gathers envelope + segments into one frame
    (:func:`to_wire_parts` is the usual entry)."""
    kind = getattr(type(msg), "kind", None)
    if kind not in WIRE_TYPES:
        raise ProtocolError(f"not a protocol message: {type(msg).__name__}")
    env: Dict[str, Any] = {"type": kind}
    if isinstance(msg, TaskBatch):
        env["tasks"] = [t.to_dict(segments) for t in msg.tasks]
        return env
    if isinstance(msg, ResultBatch):
        env["results"] = [r.to_dict(segments) for r in msg.results]
        env["acks"] = [{"task_ids": a.task_ids,
                        "t_endpoint_recv": a.t_endpoint_recv}
                       for a in msg.acks]
        return env
    if isinstance(msg, ResultMsg):
        env.update(msg.to_dict(segments))
        return env
    if isinstance(msg, PeerData):
        env.update(msg.to_dict(segments))
        return env
    for f in fields(msg):
        env[f.name] = getattr(msg, f.name)
    return env


def to_wire_parts(msg) -> Tuple[dict, list]:
    """Segment-gathering encode: returns ``(envelope, segments)`` for
    ``Channel.send_parts_*``. ``segments`` is empty when every payload
    embedded inline — the caller then sends a plain legacy frame."""
    segments: list = []
    env = to_wire(msg, segments)
    return env, segments


def from_wire(env: dict):
    """Decode a wire dict back into its typed message. A segmented frame's
    decoder attaches the borrowed payload buffers under the reserved
    ``_segs`` key (see ``comms.SegmentedFrame.unpack``); legacy envelopes
    simply lack it."""
    kind = env.get("type")
    cls = WIRE_TYPES.get(kind)
    if cls is None:
        raise ProtocolError(f"unknown wire type: {kind!r}")
    segs = env.get("_segs")
    if cls is TaskBatch:
        return TaskBatch(tasks=[TaskSpec.from_dict(t, segs)
                                for t in env.get("tasks", [])])
    if cls is ResultBatch:
        return ResultBatch(
            results=[ResultMsg.from_dict(r, segs)
                     for r in env.get("results", [])],
            acks=[Ack(task_ids=list(a.get("task_ids", [])),
                      t_endpoint_recv=a.get("t_endpoint_recv", 0.0))
                  for a in env.get("acks", [])])
    if cls is ResultMsg:
        return ResultMsg.from_dict(env, segs)
    if cls is PeerData:
        return PeerData.from_dict(env, segs)
    kwargs = {f.name: env[f.name] for f in fields(cls) if f.name in env}
    return cls(**kwargs)
