"""ForwarderPool (paper §4.1, multiplexed): the service-side forwarder tier.

The seed implementation ran one ``Forwarder`` per registered endpoint —
three dedicated threads each (dispatch / recv / monitor), so N endpoints
cost 3N service threads. The paper's service scales to thousands of
endpoints; thread-per-endpoint cannot. This pool keeps the exact same
per-endpoint semantics (service-side FIFO queue, batch dispatch, in-flight
tracking, heartbeat liveness, requeue-on-disconnect) but multiplexes all
endpoints over **one** dispatch loop, **one** recv loop (a ``ChannelHub``
select), and **one** monitor loop — O(1) threads for any fleet size.

Per-endpoint state lives in an ``EndpointLine``; the pool's condition
variable wakes the dispatch loop whenever any line has work.
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Callable, Deque, Dict, List, Optional, Tuple

from ..serialization import SerializationError
from .comms import Channel, ChannelHub
from .protocol import (
    Ack,
    FnRequest,
    FnResponse,
    Heartbeat,
    HubFetch,
    PeerData,
    ProtocolError,
    ResolvePeer,
    ResultBatch,
    ResultMsg,
    ShmAttach,
    TaskBatch,
    TaskSpec,
    from_wire,
    to_wire,
    to_wire_parts,
)
from .routing import EndpointInfo, WarmthView
from .tasks import TaskStatus, TaskStore, now


class EndpointLine:
    """One endpoint's service-side state inside the pool.

    Exposes the slice of the old ``Forwarder`` API that callers (service,
    tests, benchmarks) observe: ``endpoint_connected``, ``queue_len()``,
    ``in_flight_count()``, ``send_rtt``, and the dispatch metrics.
    All mutation happens under the owning pool's lock.
    """

    def __init__(self, endpoint_id: str, channel: Channel,
                 lock: threading.RLock):
        self.endpoint_id = endpoint_id
        self.channel = channel
        self._lock = lock
        self.queue: Deque[str] = collections.deque()
        self.in_flight: Dict[str, float] = {}
        self.last_heartbeat = time.time()
        self.endpoint_connected = True
        self.send_rtt = 0.0             # per-message latency (benchmarks)
        self.next_send_at = 0.0         # send_rtt gate; never blocks others
        self.advertised = Heartbeat(endpoint_id=endpoint_id)
        # tasks dispatched since the last heartbeat refreshed the credit
        # advertisement — only consulted when the endpoint advertises a
        # bounded intake (an interchange, DESIGN.md §11)
        self.sent_since_credit = 0
        self.peer_addr = ""             # PeerServer address from Register
        #   ("" → endpoint runs no peer server; ResolvePeer answers no)
        # metrics
        self.dispatched = 0
        self.task_envelopes = 0         # TaskBatch frames sent (gauge:
        #                                 tasks per envelope → submit-side
        #                                 batching efficiency, DESIGN.md §8)
        self.results_received = 0
        self.result_envelopes = 0       # ResultBatch frames (gauge: results
        #                                 per envelope → batching efficiency)
        self.requeues = 0

    def queue_len(self) -> int:
        with self._lock:
            return len(self.queue)

    def in_flight_count(self) -> int:
        with self._lock:
            return len(self.in_flight)

    def info(self) -> EndpointInfo:
        """Snapshot for the federation-level EndpointRouter."""
        adv = self.advertised
        with self._lock:
            service_queue = len(self.queue)
            in_flight = len(self.in_flight)
        warmth = WarmthView.from_heartbeat(adv)   # snapshot-local copy
        return EndpointInfo(
            endpoint_id=self.endpoint_id,
            connected=self.endpoint_connected and self.channel.connected,
            service_queue=service_queue,
            in_flight=in_flight,
            queued=adv.queued,
            idle_workers=adv.idle_workers,
            capacity=adv.capacity,
            warm_idle=warmth.idle,
            warm_total=warmth.total,
        )


class ForwarderPool:
    def __init__(
        self,
        task_store: TaskStore,
        *,
        batch_size: int = 32,
        heartbeat_timeout: float = 0.5,
        fn_resolver: Optional[Callable[[str], Tuple[bytes, bool]]] = None,
        on_shm_attach: Optional[Callable[["EndpointLine", ShmAttach],
                                         None]] = None,
        on_peer_msg: Optional[Callable[["EndpointLine", object],
                                       None]] = None,
    ):
        self.task_store = task_store
        self.batch_size = batch_size
        self.heartbeat_timeout = heartbeat_timeout
        # (function_id) -> (serialized body, wants_env); serves FnRequest
        # from remote endpoints (same-process agents call the service's
        # export hook directly and never send one).
        self.fn_resolver = fn_resolver
        # endpoint confirmed/refused a shared-memory ring attach: the
        # service owns the rings, so the swap decision lives there
        self.on_shm_attach = on_shm_attach
        # peer-plane signaling (ResolvePeer / HubFetch / relayed PeerData):
        # grant minting and relay correlation are service policy, not
        # transport — the pool only routes
        self.on_peer_msg = on_peer_msg
        # heartbeat-advertised build costs → cost-aware router feedback
        # (set by the service when its router implements observe_build)
        self.on_build_costs: Optional[Callable[[Dict[str, float]],
                                               None]] = None

        self.hub = ChannelHub()
        self._lines: Dict[str, EndpointLine] = {}
        self._lock = threading.RLock()
        self._cond = threading.Condition(self._lock)
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        # metrics (pool-wide; per-endpoint counts live on the lines)
        self.dispatched = 0
        self.task_envelopes = 0
        self.results_received = 0
        self.result_envelopes = 0
        self.requeues = 0

    # ------------------------------------------------------------------ control
    def start(self) -> None:
        for name, fn in [("dispatch", self._dispatch_loop),
                         ("recv", self._recv_loop),
                         ("monitor", self._monitor_loop)]:
            t = threading.Thread(target=fn, daemon=True, name=f"pool-{name}")
            t.start()
            self._threads.append(t)

    def stop(self) -> None:
        self._stop.set()
        with self._cond:
            self._cond.notify_all()

    @property
    def healthy(self) -> bool:
        return all(t.is_alive() for t in self._threads) and \
            not self._stop.is_set()

    # -------------------------------------------------------------- membership
    def register(self, endpoint_id: str, channel: Channel) -> EndpointLine:
        line = EndpointLine(endpoint_id, channel, self._lock)
        with self._cond:
            self._lines[endpoint_id] = line
        self.hub.register(endpoint_id, channel)
        return line

    def unregister(self, endpoint_id: str) -> Optional[EndpointLine]:
        self.hub.unregister(endpoint_id)
        with self._cond:
            return self._lines.pop(endpoint_id, None)

    def reattach(self, endpoint_id: str, channel: Channel) -> EndpointLine:
        """Swap the channel under an existing line — an endpoint that lost
        its socket dialed back in. The line keeps its queue and metrics;
        everything that was in flight on the dead channel is requeued
        (requeue-on-disconnect semantics, paper §4.3), so tasks dispatched
        into the void complete after the reconnect."""
        with self._cond:
            line = self._lines[endpoint_id]
            old = line.channel
            line.channel = channel
            line.endpoint_connected = True
            line.last_heartbeat = time.time()
            self._cond.notify()
        self.hub.unregister(endpoint_id)
        self.hub.register(endpoint_id, channel)
        if old is not channel:
            old.close()
        self.requeue_in_flight(line)
        return line

    def line(self, endpoint_id: str) -> EndpointLine:
        with self._lock:
            return self._lines[endpoint_id]

    def lines(self) -> List[EndpointLine]:
        with self._lock:
            return list(self._lines.values())

    def endpoint_infos(self) -> List[EndpointInfo]:
        return [ln.info() for ln in self.lines()]

    # ------------------------------------------------------------------ intake
    def enqueue(self, endpoint_id: str, task_id: str,
                front: bool = False) -> None:
        with self._cond:
            line = self._lines[endpoint_id]
            if front:
                line.queue.appendleft(task_id)
            else:
                line.queue.append(task_id)
            self._cond.notify()

    def enqueue_many(self, endpoint_id: str, task_ids: List[str]) -> None:
        with self._cond:
            self._lines[endpoint_id].queue.extend(task_ids)
            self._cond.notify()

    # ------------------------------------------------------------------- loops
    def _sendable(self) -> List[Tuple[EndpointLine, List[str]]]:
        """Pop up to batch_size queued ids from every line that is ready to
        send. Caller must hold the lock."""
        out = []
        now_t = time.time()
        for line in self._lines.values():
            if not line.queue:
                continue
            if not line.endpoint_connected or not line.channel.connected:
                continue
            if line.send_rtt and line.next_send_at > now_t:
                continue               # emulated RTT not elapsed yet
            limit = self.batch_size
            credits = line.advertised.credits
            if credits >= 0:
                # bounded-intake endpoint (interchange): respect the
                # advertised backlog room, net of what we sent since the
                # advertisement — backpressure instead of overrun
                room = credits - line.sent_since_credit
                if room <= 0:
                    continue
                limit = min(limit, room)
            batch = []
            while line.queue and len(batch) < limit:
                batch.append(line.queue.popleft())
            out.append((line, batch))
        return out

    def _wait_timeout(self) -> float:
        """How long the dispatch loop may sleep: wake early if an
        RTT-gated line with queued work comes due sooner than the default
        poll interval. Caller must hold the lock."""
        t = 0.05
        now_t = time.time()
        for line in self._lines.values():
            if line.queue and line.send_rtt and line.next_send_at > now_t:
                t = min(t, line.next_send_at - now_t)
        return max(t, 0.001)

    def _dispatch_loop(self) -> None:
        while not self._stop.is_set():
            with self._cond:
                batches = self._sendable()
                while not batches and not self._stop.is_set():
                    self._cond.wait(timeout=self._wait_timeout())
                    batches = self._sendable()
            if self._stop.is_set():
                return
            for line, task_ids in batches:
                self._dispatch(line, task_ids)

    def _dispatch(self, line: EndpointLine, task_ids: List[str]) -> None:
        specs: List[TaskSpec] = []
        for tid, task in zip(task_ids, self.task_store.get_many(task_ids)):
            if task is None or task.done:
                continue
            task.status = TaskStatus.DISPATCHED
            task.stamp("forwarder_sent")
            specs.append(TaskSpec(task_id=tid,
                                  function_id=task.function_id,
                                  container_type=task.container_type,
                                  payload=task.payload,
                                  warmth_key=task.warmth_key))
        if not specs:
            return
        # scatter-gather send: the envelope carries segment indices and the
        # packed payload buffers ride behind it as borrowed views — no
        # payload memcpy into the envelope (DESIGN.md §7)
        env, segs = to_wire_parts(TaskBatch(tasks=specs))
        # in-flight entries land BEFORE the send: a fast endpoint can
        # return a result before this thread re-acquires the lock, and
        # the result handler must find the entry to pop
        t = time.time()
        with self._lock:
            for spec in specs:
                line.in_flight[spec.task_id] = t
        ok = line.channel.send_parts_to_endpoint(env, segs, tag="tasks")
        with self._lock:
            if ok:
                if line.send_rtt:
                    line.next_send_at = t + line.send_rtt
                line.sent_since_credit += len(specs)
                line.dispatched += len(specs)
                line.task_envelopes += 1
                self.dispatched += len(specs)
                self.task_envelopes += 1
            else:
                # channel refused (disconnected / dropped): requeue in order
                for spec in specs:
                    line.in_flight.pop(spec.task_id, None)
                line.queue.extendleft(reversed([s.task_id for s in specs]))

    def _recv_loop(self) -> None:
        """Drains the hub. Messages arrive *packed*; the routing tag comes
        from the buffer header (peek, no payload deserialization), and only
        the protocol envelope is decoded here — task/result payloads inside
        it stay opaque byte frames until their consumer unpacks them
        (pack-once plane, DESIGN.md §5)."""
        while not self._stop.is_set():
            for eid, buf in self.hub.poll(timeout=0.05):
                with self._lock:
                    line = self._lines.get(eid)
                if line is None:
                    continue
                try:
                    msg = from_wire(buf.unpack())
                except (ProtocolError, SerializationError):
                    continue
                if isinstance(msg, Heartbeat):
                    self._handle_heartbeat(line, msg)
                elif isinstance(msg, Ack):
                    self._handle_ack(msg)
                elif isinstance(msg, ResultBatch):
                    self._handle_result_batch(line, msg)
                elif isinstance(msg, ResultMsg):
                    # legacy lone-result envelope (hand-built messages,
                    # older agents): same path, batch of one
                    self._handle_result_batch(
                        line, ResultBatch(results=[msg]))
                elif isinstance(msg, FnRequest):
                    self._handle_fn_request(line, msg)
                elif isinstance(msg, ShmAttach):
                    cb = self.on_shm_attach
                    if cb is not None:
                        cb(line, msg)
                elif isinstance(msg, (ResolvePeer, HubFetch, PeerData)):
                    cb = self.on_peer_msg
                    if cb is not None:
                        try:
                            cb(line, msg)
                        except Exception:
                            # a malformed signaling frame must not kill
                            # the shared recv loop; the requester times out
                            pass

    def _handle_heartbeat(self, line: EndpointLine, hb: Heartbeat) -> None:
        line.last_heartbeat = time.time()
        line.advertised = hb
        if hb.credits >= 0:
            with self._lock:
                line.sent_since_credit = 0     # credit window refreshed
        # feed measured cold-build costs to a cost-aware federation
        # router (observe_build, DESIGN.md §10) — the service installs
        # the hook when its EndpointRouter can consume them
        if hb.build_costs and self.on_build_costs is not None:
            self.on_build_costs(hb.build_costs)
        if not line.endpoint_connected:
            line.endpoint_connected = True          # reconnected
            with self._cond:
                self._cond.notify()                 # queued work can flow

    def _handle_ack(self, ack: Ack) -> None:
        # one store lock round-trip for the whole acked batch
        for task in self.task_store.get_many(ack.task_ids):
            if task is not None:
                task.t.setdefault("endpoint_recv",
                                  ack.t_endpoint_recv or now())

    def _handle_result_batch(self, line: EndpointLine,
                             batch: ResultBatch) -> None:
        """Resolve a whole ResultBatch with batch-granular locking: one
        pool-lock acquisition clears every member from the in-flight map,
        one store round-trip fetches the tasks, and one ``mark_done_many``
        wakes the waiters — lock traffic per *envelope*, not per task.
        Duplicate members (batched retransmission racing a requeued
        re-execution) are dropped by the ``task.done`` check, keeping the
        exactly-once contract batch-wise."""
        for ack in batch.acks:
            self._handle_ack(ack)
        if not batch.results:
            return
        line.result_envelopes += 1
        self.result_envelopes += 1
        with self._lock:
            for res in batch.results:
                line.in_flight.pop(res.task_id, None)
        tasks = self.task_store.get_many(
            [res.task_id for res in batch.results])
        done_ids: List[str] = []
        for res, task in zip(batch.results, tasks):
            if task is None or task.done:
                continue               # purged or duplicate — drop
            task.t.update(res.stamps)
            task.cold_start = res.cold_start
            task.worker_id = res.worker_id
            task.manager_id = res.manager_id
            if res.status == "SUCCESS":
                task.result = res.result
                task.status = TaskStatus.SUCCESS
            elif res.status == "LOST":
                task.error = res.error
                task.status = TaskStatus.LOST
            else:
                task.error = res.error
                task.remote_traceback = res.remote_traceback
                task.status = TaskStatus.FAILED
            task.stamp("result_stored")
            done_ids.append(res.task_id)
        line.results_received += len(done_ids)
        self.results_received += len(done_ids)
        self.task_store.mark_done_many(done_ids)

    def _handle_fn_request(self, line: EndpointLine, req: FnRequest) -> None:
        """Remote endpoint pulling a function body. Errors travel back in
        the response — the requesting fetch fails that one task's staging,
        never this shared recv loop."""
        if self.fn_resolver is None:
            resp = FnResponse(function_id=req.function_id,
                              error="service has no function resolver")
        else:
            try:
                blob, wants_env = self.fn_resolver(req.function_id)
                resp = FnResponse(function_id=req.function_id,
                                  payload=blob, wants_env=wants_env)
            except Exception as e:
                resp = FnResponse(function_id=req.function_id,
                                  error=f"{type(e).__name__}: {e}")
        line.channel.send_to_endpoint(to_wire(resp), tag="fn")

    def _monitor_loop(self) -> None:
        """Heartbeat-based endpoint liveness (paper: 30 s default; scaled
        down here). On loss: requeue that endpoint's in-flight tasks."""
        while not self._stop.is_set():
            time.sleep(self.heartbeat_timeout / 4)
            cutoff = time.time() - self.heartbeat_timeout
            for line in self.lines():
                if line.endpoint_connected and line.last_heartbeat < cutoff:
                    line.endpoint_connected = False
                    self.requeue_in_flight(line)

    def requeue_in_flight(self, line: EndpointLine) -> None:
        """Put the line's dispatched-but-unresolved tasks back at the head
        of its queue, preserving dispatch order (FIFO is kept: in-flight
        tasks left the queue before anything currently in it)."""
        with self._cond:
            pending = list(line.in_flight.keys())
            line.in_flight.clear()
            requeued = []
            for tid in pending:
                try:
                    task = self.task_store.get(tid)
                except KeyError:
                    continue
                if not task.done:
                    task.status = TaskStatus.PENDING
                    requeued.append(tid)
            line.queue.extendleft(reversed(requeued))
            line.requeues += len(requeued)
            self.requeues += len(requeued)
            self._cond.notify()
