"""Exception taxonomy for the FaaS runtime."""


class FuncXError(Exception):
    """Base."""


class AuthError(FuncXError):
    pass


class RegistrationError(FuncXError):
    pass


class TaskFailure(FuncXError):
    """Function raised; carries the remote traceback string."""

    def __init__(self, message: str, remote_traceback: str = ""):
        super().__init__(message)
        self.remote_traceback = remote_traceback


class TaskLost(FuncXError):
    """Task exceeded retry budget after worker/manager loss."""


class PayloadTooLarge(FuncXError):
    """Payload exceeds the 10 MB service limit (use DataRefs — paper §5.1)."""


class EndpointUnavailable(FuncXError):
    pass
