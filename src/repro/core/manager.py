"""Manager (paper §4.3, §6.2): represents one compute node; owns the node's
workers, advertises warm-container state + free capacity to the endpoint
agent, pulls task batches (internal batching §4.6), assigns tasks to workers
warm-first, and rebalances deployed containers proportionally to the
arriving task mix.
"""
from __future__ import annotations

import collections
import queue
import threading
import time
from typing import Callable, Dict, List, Optional

from .routing import ManagerInfo
from .tasks import now
from .warming import ContainerRegistry, proportional_allocation
from .worker import Worker, WorkItem, WorkResult


class Manager:
    def __init__(
        self,
        manager_id: str,
        n_workers: int,
        registry: ContainerRegistry,
        result_cb: Callable[[str, WorkResult], None],
        *,
        cache_slots: int = 1,
        idle_timeout: Optional[float] = None,
        prefetch: int = 0,
        prewarm: bool = True,
        worker_slowdown: float = 0.0,
        affinity_patience: float = 0.5,
    ):
        self.manager_id = manager_id
        self.registry = registry
        self.prefetch = prefetch
        self.prewarm = prewarm
        # how long a task waits for a BUSY warm container before we evict
        # a cold worker for it (avoids warm-container churn; bounded so
        # stragglers cannot starve the queue)
        self.affinity_patience = affinity_patience
        self._result_cb = result_cb
        self.workers: List[Worker] = [
            Worker(f"{manager_id}/w{i}", registry,
                   self._on_result, cache_slots=cache_slots,
                   idle_timeout=idle_timeout, slowdown=worker_slowdown)
            for i in range(n_workers)
        ]
        self.inbox: "queue.Queue[WorkItem]" = queue.Queue()
        self._in_flight: Dict[str, WorkItem] = {}
        self._in_flight_lock = threading.Lock()
        self._mix: collections.Counter = collections.Counter()
        self._stop = threading.Event()
        self._killed = False
        self.last_heartbeat = time.perf_counter()
        self._assign_thread = threading.Thread(
            target=self._assign_loop, daemon=True,
            name=f"manager-{manager_id}")

    # -- lifecycle ---------------------------------------------------------------
    def start(self) -> None:
        for w in self.workers:
            w.start()
        self._assign_thread.start()

    def stop(self) -> None:
        self._stop.set()
        for w in self.workers:
            w.stop()

    def kill(self) -> None:
        """Simulated node failure: everything in flight is lost (until the
        endpoint's heartbeat monitor notices and re-executes)."""
        self._killed = True
        self._stop.set()
        for w in self.workers:
            w.kill()

    @property
    def alive(self) -> bool:
        return not self._killed and not self._stop.is_set()

    # -- capacity / advertising (paper: managers advertise container types
    # and available capacity) -----------------------------------------------------
    def info(self) -> ManagerInfo:
        warm_idle: Dict[str, int] = collections.Counter()
        warm_total: Dict[str, int] = collections.Counter()
        idle = 0
        for w in self.workers:
            types = w.warm_types()
            for t in types:
                warm_total[t] += 1
            if w.idle:
                idle += 1
                for t in types:
                    warm_idle[t] += 1
        return ManagerInfo(
            manager_id=self.manager_id,
            idle_workers=idle,
            queued=self.inbox.qsize() + sum(1 for w in self.workers
                                            if not w.idle),
            warm_idle=dict(warm_idle),
            warm_total=dict(warm_total),
            capacity=len(self.workers),
        )

    def room(self) -> int:
        """How many more tasks this manager will accept right now
        (capacity − queued + prefetch) — internal batching window."""
        inf = self.info()
        return max(inf.capacity + self.prefetch - inf.queued, 0)

    # -- task intake ----------------------------------------------------------------
    def submit(self, item: WorkItem) -> None:
        with self._in_flight_lock:
            self._in_flight[item.task_id] = item
        self._mix[item.container_type] += 1
        self.inbox.put(item)

    def submit_batch(self, items: List[WorkItem]) -> None:
        for it in items:
            self.submit(it)
        self._rebalance()

    def in_flight(self) -> List[WorkItem]:
        with self._in_flight_lock:
            return list(self._in_flight.values())

    # -- internals --------------------------------------------------------------------
    def _on_result(self, res: WorkResult) -> None:
        with self._in_flight_lock:
            self._in_flight.pop(res.task_id, None)
        self.last_heartbeat = time.perf_counter()
        self._result_cb(self.manager_id, res)

    def _pick_worker(self, container_type: str,
                     patient: bool) -> Optional[Worker]:
        idle = [w for w in self.workers if w.idle]
        if not idle:
            return None
        warm = [w for w in idle if container_type in w.warm_types()]
        if warm:
            return warm[0]
        planned = [w for w in idle if w.target_type == container_type]
        if planned:
            return planned[0]
        empty = [w for w in idle if not w.warm_types()]
        if empty:
            return empty[0]
        # a BUSY worker has this type warm: within the patience window,
        # wait for it instead of evicting someone else's warm container
        if patient and any(container_type in w.warm_types()
                           for w in self.workers if not w.idle):
            return None
        # must evict someone: the least-demanded warm set loses
        def evict_cost(w: Worker) -> int:
            return sum(self._mix.get(t, 0) for t in w.warm_types())
        return min(idle, key=evict_cost)

    def _assign_loop(self) -> None:
        while not self._stop.is_set():
            self.last_heartbeat = time.perf_counter()
            try:
                item = self.inbox.get(timeout=0.05)
            except queue.Empty:
                continue
            first_seen = item.stamps.setdefault("manager_recv", now())
            patient = (now() - first_seen) < self.affinity_patience
            w = self._pick_worker(item.container_type, patient)
            if w is None:
                # no worker yet (all busy / waiting for warm affinity):
                # requeue at the tail so other types keep flowing
                self.inbox.put(item)
                if self.inbox.qsize() <= 1:
                    time.sleep(0.002)
                else:
                    time.sleep(0.0002)
                continue
            item.stamps["manager_assigned"] = now()
            w.submit(item)

    def _rebalance(self) -> None:
        """Paper §6.2: deploy containers per type proportionally to the
        received task mix; pre-warm planned types on idle workers.

        Stability matters: only workers that are EMPTY or whose warm types
        are in SURPLUS (deployed > target) are retargeted — otherwise
        repeated rebalances evict still-needed containers and the fleet
        thrashes (cold-start churn instead of warming)."""
        if not self._mix:
            return
        targets = proportional_allocation(dict(self._mix), len(self.workers))
        deployed: collections.Counter = collections.Counter()
        for w in self.workers:
            for t in w.warm_types():
                deployed[t] += 1
        deficits = {t: max(n - deployed.get(t, 0), 0)
                    for t, n in targets.items()}
        surplus = {t: max(deployed.get(t, 0) - targets.get(t, 0), 0)
                   for t in deployed}

        def retargetable(w: Worker) -> bool:
            wt = w.warm_types()
            if not wt:
                return True
            return all(surplus.get(t, 0) > 0 for t in wt)

        for w in self.workers:
            if not any(d > 0 for d in deficits.values()):
                break
            if not w.idle or not retargetable(w):
                continue
            if w.target_type is not None and \
                    deficits.get(w.target_type, 0) > 0:
                deficits[w.target_type] -= 1       # plan already in motion
                continue
            t = max(deficits, key=deficits.get)
            w.target_type = t
            deficits[t] -= 1
            for old in w.warm_types():
                surplus[old] = max(surplus.get(old, 0) - 1, 0)
            # pre-warm only EMPTY workers: plans steer placement, but a
            # container is never evicted for a prediction — only by an
            # actual task (prevents prewarm churn)
            if self.prewarm and not w.warm_types():
                w.prewarm(t)
