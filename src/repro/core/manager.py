"""Manager (paper §4.3, §6.2): represents one compute node; owns the node's
workers, advertises warm-container state + free capacity to the endpoint
agent, pulls task batches (internal batching §4.6), assigns tasks to workers
warm-first, and rebalances deployed containers proportionally to the
arriving task mix.
"""
from __future__ import annotations

import collections
import itertools
import queue
import threading
import time
from typing import Callable, Dict, List, Optional, Tuple

from .routing import ManagerInfo, WarmthView
from .tasks import now
from .warming import ContainerRegistry, proportional_allocation
from .worker import Worker, WorkItem, WorkResult


class _WorkerSnapshot:
    """One tick's view of worker availability for batch assignment: idle
    workers and their warm types are read once, then updated locally as
    placements consume them — the same warm-first policy as before, at
    one scan per *batch* instead of four passes per task."""

    __slots__ = ("workers", "idle", "warm", "_busy_warm")

    def __init__(self, workers: List[Worker]):
        self.workers = workers
        self.idle = [w for w in workers if w.idle]
        self.warm = {w: w.warm_types() for w in self.idle}
        self._busy_warm: Optional[set] = None      # lazy

    def busy_warm(self) -> set:
        if self._busy_warm is None:
            s = set()
            for w in self.workers:
                if not w.idle:
                    s.update(w.warm_types())
            self._busy_warm = s
        return self._busy_warm

    def consume(self, w: Worker) -> None:
        self.idle.remove(w)
        # w is busy now and keeps its warm types warm
        self.busy_warm().update(self.warm.pop(w, ()))

    def pick(self, container_type: str, patient: bool,
             mix: collections.Counter) -> Optional[Worker]:
        idle = self.idle
        if not idle:
            return None
        warm = self.warm
        for w in idle:                     # warm-first
            if container_type in warm[w]:
                return w
        for w in idle:                     # then the rebalancer's plan
            if w.target_type == container_type:
                return w
        for w in idle:                     # then an empty worker
            if not warm[w]:
                return w
        # a BUSY worker has this type warm: within the patience window,
        # wait for it instead of evicting someone else's warm container
        if patient and container_type in self.busy_warm():
            return None
        # must evict someone: the least-demanded warm set loses
        return min(idle, key=lambda w: sum(mix.get(t, 0) for t in warm[w]))


class Manager:
    def __init__(
        self,
        manager_id: str,
        n_workers: int,
        registry: ContainerRegistry,
        result_cb: Callable[[str, WorkResult], None],
        *,
        cache_slots: int = 1,
        idle_timeout: Optional[float] = None,
        prefetch: int = 0,
        prewarm: bool = True,
        worker_slowdown: float = 0.0,
        affinity_patience: float = 0.5,
    ):
        self.manager_id = manager_id
        self.registry = registry
        self.prefetch = prefetch
        self.prewarm = prewarm
        # how long a task waits for a BUSY warm container before we evict
        # a cold worker for it (avoids warm-container churn; bounded so
        # stragglers cannot starve the queue)
        self.affinity_patience = affinity_patience
        self._result_cb = result_cb
        self.workers: List[Worker] = [
            Worker(f"{manager_id}/w{i}", registry,
                   self._on_result, cache_slots=cache_slots,
                   idle_timeout=idle_timeout, slowdown=worker_slowdown)
            for i in range(n_workers)
        ]
        # Incrementally maintained advertisement (ROADMAP hot-path note 2):
        # the idle/warm scan runs only after a worker or warm-cache state
        # transition dirtied it — assign/complete time, not once per
        # dispatch cycle and heartbeat. ``version`` stamps every change so
        # the agent's 20 Hz heartbeat merge can key its own cache on it.
        self._info_dirty = True
        self._info_cache: Optional[
            Tuple[int, int, Dict[str, int], Dict[str, int]]] = None
        self._vc = itertools.count(1)
        self.version = next(self._vc)
        for w in self.workers:
            w.on_state_change = self._mark_dirty
            w.cache.on_change = self._mark_dirty
        self.inbox: "queue.Queue[WorkItem]" = queue.Queue()
        # Items that could not be placed yet (all workers busy, or warm
        # affinity worth waiting for) park here instead of being cycled
        # back through the inbox — re-checked once per assign tick, so a
        # stuck head item costs O(deferred), not O(inbox), per tick.
        self._deferred: "collections.deque[WorkItem]" = collections.deque()
        self.deferrals = 0                 # times an item was parked
        self._in_flight: Dict[str, WorkItem] = {}
        self._in_flight_lock = threading.Lock()
        self._mix: collections.Counter = collections.Counter()
        self._wake = threading.Event()     # a worker freed (retry deferred)
        self._stop = threading.Event()
        self._killed = False
        self.last_heartbeat = time.perf_counter()
        self._assign_thread = threading.Thread(
            target=self._assign_loop, daemon=True,
            name=f"manager-{manager_id}")

    # -- lifecycle ---------------------------------------------------------------
    def start(self) -> None:
        for w in self.workers:
            w.start()
        self._assign_thread.start()

    def stop(self) -> None:
        self._stop.set()
        for w in self.workers:
            w.stop()

    def kill(self) -> None:
        """Simulated node failure: everything in flight is lost (until the
        endpoint's heartbeat monitor notices and re-executes)."""
        self._killed = True
        self._stop.set()
        for w in self.workers:
            w.kill()

    @property
    def alive(self) -> bool:
        return not self._killed and not self._stop.is_set()

    # -- capacity / advertising (paper: managers advertise container types
    # and available capacity) -----------------------------------------------------
    def _mark_dirty(self) -> None:
        """Worker idle/busy or warm-set transition: invalidate the cached
        scan and move the version stamp (``next`` on an ``itertools.count``
        is atomic under the GIL — concurrent transitions never lose a
        bump, so a consumer keyed on ``version`` can never cache stale
        state forever)."""
        self._info_dirty = True
        self.version = next(self._vc)

    def info(self) -> ManagerInfo:
        """Advertisement snapshot. The worker scan (idle set + warm dicts)
        is cached and rebuilt only when dirty; the queue-depth terms are
        O(1) reads taken fresh every call. Returns a fresh ManagerInfo
        with copied dicts — callers (dispatch loop, routers) mutate their
        snapshots."""
        cached = self._info_cache
        if self._info_dirty or cached is None:
            # clear *before* scanning: a transition racing the scan
            # re-dirties and the next call rebuilds again
            self._info_dirty = False
            scans = [(w.warm_types(), w.idle) for w in self.workers]
            idle = sum(1 for _, is_idle in scans if is_idle)
            busy = len(scans) - idle
            view = WarmthView.tally(scans)
            cached = (idle, busy, view.idle, view.total)
            self._info_cache = cached
        idle, busy, warm_idle, warm_total = cached
        return ManagerInfo(
            manager_id=self.manager_id,
            idle_workers=idle,
            queued=self.inbox.qsize() + len(self._deferred) + busy,
            warm_idle=dict(warm_idle),
            warm_total=dict(warm_total),
            capacity=len(self.workers),
        )

    def room(self) -> int:
        """How many more tasks this manager will accept right now
        (capacity − queued + prefetch) — internal batching window."""
        inf = self.info()
        return max(inf.capacity + self.prefetch - inf.queued, 0)

    # -- task intake ----------------------------------------------------------------
    def submit(self, item: WorkItem) -> None:
        with self._in_flight_lock:
            self._in_flight[item.task_id] = item
        self._mix[item.container_type] += 1
        self.inbox.put(item)

    def submit_batch(self, items: List[WorkItem]) -> None:
        for it in items:
            self.submit(it)
        self._rebalance()

    def in_flight(self) -> List[WorkItem]:
        with self._in_flight_lock:
            return list(self._in_flight.values())

    # -- internals --------------------------------------------------------------------
    def _on_result(self, res: WorkResult) -> None:
        with self._in_flight_lock:
            self._in_flight.pop(res.task_id, None)
        self.last_heartbeat = time.perf_counter()
        if self._deferred:
            self._wake.set()               # freed worker: retry parked items
        self._result_cb(self.manager_id, res)

    def _assign_loop(self) -> None:
        """Pulls the inbox and places items on workers *batch-wise*: one
        worker-state snapshot (idle set + warm types) serves every item
        available this tick — the per-task 4-pass scan over all workers
        was a measurable hot path (§7.2.3). Items that cannot be placed
        yet — all workers busy, or a warm-affinity wait — park in
        ``_deferred`` and are re-tried once per tick. The old version
        re-queued the blocked head through the whole inbox, churning
        every other queued item past it (O(n²) under mixed container
        types); the side deque keeps unblocked types flowing while a
        blocked item costs only its own recheck."""
        while not self._stop.is_set():
            self.last_heartbeat = time.perf_counter()
            if self._deferred:
                # parked items: retry on worker-freed wake (or a short
                # tick as backstop), folding in any newly arrived item
                self._wake.wait(timeout=0.002)
                self._wake.clear()
                try:
                    item = self.inbox.get_nowait()
                except queue.Empty:
                    item = None
                self._assign_ready(item)
                continue
            try:
                item = self.inbox.get(timeout=0.05)
            except queue.Empty:
                continue
            self._assign_ready(item)

    def _assign_ready(self, item: Optional[WorkItem]) -> None:
        """Place parked items (FIFO), then ``item``, then whatever else
        the inbox already holds — all against one snapshot, updated
        locally as workers are consumed."""
        batch: List[WorkItem] = []
        for _ in range(len(self._deferred)):
            batch.append(self._deferred.popleft())
        if item is not None:
            batch.append(item)
        while len(batch) < 128:
            try:
                batch.append(self.inbox.get_nowait())
            except queue.Empty:
                break
        snap = _WorkerSnapshot(self.workers)
        for it in batch:
            self._place(it, snap)

    def _place(self, item: WorkItem, snap: "_WorkerSnapshot") -> bool:
        first_seen = item.stamps.setdefault("manager_recv", now())
        patient = (now() - first_seen) < self.affinity_patience
        w = None
        if item.warmth_key and item.warmth_key != item.container_type:
            # refined warmth (jit cache entry) beats bare container
            # warmth: take a worker already holding the artifact if one
            # is idle, else fall through to the container-type policy
            w = next((ww for ww in snap.idle
                      if item.warmth_key in snap.warm[ww]), None)
        if w is None:
            w = snap.pick(item.container_type, patient, self._mix)
        if w is None:
            self._deferred.append(item)
            self.deferrals += 1
            return False
        item.stamps["manager_assigned"] = now()
        w.submit(item)
        snap.consume(w)
        return True

    def _rebalance(self) -> None:
        """Paper §6.2: deploy containers per type proportionally to the
        received task mix; pre-warm planned types on idle workers.

        Stability matters: only workers that are EMPTY or whose warm types
        are in SURPLUS (deployed > target) are retargeted — otherwise
        repeated rebalances evict still-needed containers and the fleet
        thrashes (cold-start churn instead of warming)."""
        if not self._mix:
            return
        targets = proportional_allocation(dict(self._mix), len(self.workers))
        deployed: collections.Counter = collections.Counter()
        for w in self.workers:
            for t in w.warm_types():
                deployed[t] += 1
        deficits = {t: max(n - deployed.get(t, 0), 0)
                    for t, n in targets.items()}
        surplus = {t: max(deployed.get(t, 0) - targets.get(t, 0), 0)
                   for t in deployed}

        def retargetable(w: Worker) -> bool:
            wt = w.warm_types()
            if not wt:
                return True
            return all(surplus.get(t, 0) > 0 for t in wt)

        for w in self.workers:
            if not any(d > 0 for d in deficits.values()):
                break
            if not w.idle or not retargetable(w):
                continue
            if w.target_type is not None and \
                    deficits.get(w.target_type, 0) > 0:
                deficits[w.target_type] -= 1       # plan already in motion
                continue
            t = max(deficits, key=deficits.get)
            w.target_type = t
            deficits[t] -= 1
            for old in w.warm_types():
                surplus[old] = max(surplus.get(old, 0) - 1, 0)
            # pre-warm only EMPTY workers: plans steer placement, but a
            # container is never evicted for a prediction — only by an
            # actual task (prevents prewarm churn)
            if self.prewarm and not w.warm_types():
                w.prewarm(t)
