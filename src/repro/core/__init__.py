"""The paper's primary contribution: a federated FaaS runtime.

                 ┌ EndpointRouter (federation routing, §6.2↑)
service ── ForwarderPool ═╦═ endpoint agent ── managers ── workers
   (cloud tier,           ║    (resource tier)    (nodes)    (containers /
    O(1) threads)    ChannelHub                              compiled
                   + typed protocol                          executables)

One ForwarderPool multiplexes every endpoint's dispatch/recv/monitor over
a single event loop (ChannelHub select); messages on the wire are typed
protocol dataclasses; tasks submitted without an endpoint are routed
across the federation by a pluggable EndpointRouter. See DESIGN.md.
"""
from .auth import (
    ALL_SCOPES,
    AuthService,
    SCOPE_ENDPOINT,
    SCOPE_REGISTER_FUNCTION,
    SCOPE_RUN,
    SCOPE_TRANSFER,
    Token,
)
from .batching import (
    DynamicBatcher,
    SubmitCoalescer,
    split_arrays,
    stack_arrays,
)
from .client import FuncXClient
from .comms import (
    Channel,
    ChannelHub,
    LocalTransport,
    SegmentedFrame,
    ShmRing,
    ShmTransport,
    SocketReactor,
    TcpListener,
    TcpTransport,
    Transport,
    decode_frame,
    parse_hostport,
    segment_parts,
)
from .endpoint import (
    EndpointAgent,
    RemoteEndpointRunner,
    ResultCoalescer,
    WireFunctionClient,
)
from .interchange import (
    Interchange,
    LeafProvider,
    ThreadLeafProvider,
    spawn_interchange_process,
)
from .errors import (
    AuthError,
    EndpointUnavailable,
    FuncXError,
    PayloadTooLarge,
    RegistrationError,
    TaskFailure,
    TaskLost,
)
from .executor import FuncXExecutor
from .forwarder_pool import EndpointLine, ForwarderPool
from .manager import Manager
from .protocol import (
    Ack,
    FnRequest,
    FnResponse,
    Heartbeat,
    ProtocolError,
    Register,
    RegisterAck,
    ResultBatch,
    ResultMsg,
    ShmAttach,
    TaskBatch,
    TaskSpec,
    WIRE_STATS,
    from_wire,
    to_wire,
    to_wire_parts,
)
from .provisioning import (
    ElasticStrategy,
    LocalProvider,
    Provider,
    SimCloudProvider,
    SimSlurmProvider,
)
from .routing import (
    CostAwareRouter,
    EndpointInfo,
    EndpointRouter,
    LeastLoadedEndpointRouter,
    LocalityAwareRouter,
    ManagerInfo,
    RandomEndpointRouter,
    RandomRouter,
    Router,
    RoutingContext,
    WarmingAwareEndpointRouter,
    WarmingAwareRouter,
    WarmingHashRouter,
    WarmthView,
    make_router,
)
from .service import FuncXService, PAYLOAD_LIMIT, RegisteredFunction
from .tasks import BatchWaiter, Task, TaskStatus, TaskStore
from .warming import (
    Container,
    ContainerRegistry,
    ContainerSpec,
    WarmCache,
    proportional_allocation,
)
from .worker import Worker, WorkItem, WorkResult

__all__ = [
    "ALL_SCOPES", "Ack", "AuthError", "AuthService", "BatchWaiter",
    "Channel", "ChannelHub",
    "Container", "ContainerRegistry", "ContainerSpec", "CostAwareRouter",
    "DynamicBatcher", "ElasticStrategy", "EndpointAgent", "EndpointInfo",
    "EndpointLine", "EndpointRouter", "EndpointUnavailable", "FnRequest",
    "FnResponse", "ForwarderPool", "FuncXClient", "FuncXError",
    "FuncXExecutor",
    "FuncXService", "Heartbeat", "Interchange",
    "LeafProvider", "LeastLoadedEndpointRouter",
    "LocalProvider", "LocalTransport", "LocalityAwareRouter", "Manager",
    "ManagerInfo", "PAYLOAD_LIMIT", "PayloadTooLarge", "ProtocolError",
    "Provider", "RandomEndpointRouter", "RandomRouter", "Register",
    "RegisterAck", "RegisteredFunction", "RegistrationError",
    "RemoteEndpointRunner", "ResultBatch", "ResultCoalescer", "ResultMsg",
    "Router", "RoutingContext", "SCOPE_ENDPOINT",
    "SCOPE_REGISTER_FUNCTION", "SCOPE_RUN", "SCOPE_TRANSFER",
    "SegmentedFrame", "ShmAttach", "ShmRing", "ShmTransport",
    "SimCloudProvider", "SimSlurmProvider", "SocketReactor",
    "SubmitCoalescer", "Task",
    "TaskBatch",
    "TaskFailure", "TaskLost", "TaskSpec", "TaskStatus", "TaskStore",
    "TcpListener", "TcpTransport", "ThreadLeafProvider", "Token",
    "Transport", "WIRE_STATS",
    "WarmCache",
    "WarmingAwareEndpointRouter", "WarmingAwareRouter", "WarmingHashRouter",
    "WarmthView", "WireFunctionClient",
    "WorkItem", "WorkResult", "Worker", "decode_frame", "from_wire",
    "spawn_interchange_process",
    "make_router", "parse_hostport", "proportional_allocation",
    "segment_parts", "split_arrays", "stack_arrays", "to_wire",
    "to_wire_parts",
]
