"""The paper's primary contribution: a federated FaaS runtime.

service ── forwarder ═╦═ endpoint agent ── managers ── workers
   (cloud tier)       ║   (resource tier)     (nodes)    (containers /
                   channel                               compiled executables)
"""
from .auth import (
    ALL_SCOPES,
    AuthService,
    SCOPE_ENDPOINT,
    SCOPE_REGISTER_FUNCTION,
    SCOPE_RUN,
    SCOPE_TRANSFER,
    Token,
)
from .batching import DynamicBatcher, split_arrays, stack_arrays
from .client import FuncXClient
from .comms import Channel
from .endpoint import EndpointAgent
from .errors import (
    AuthError,
    EndpointUnavailable,
    FuncXError,
    PayloadTooLarge,
    RegistrationError,
    TaskFailure,
    TaskLost,
)
from .forwarder import Forwarder
from .manager import Manager
from .provisioning import (
    ElasticStrategy,
    LocalProvider,
    Provider,
    SimCloudProvider,
    SimSlurmProvider,
)
from .routing import (
    CostAwareRouter,
    LocalityAwareRouter,
    ManagerInfo,
    RandomRouter,
    Router,
    WarmingAwareRouter,
    make_router,
)
from .service import FuncXService, PAYLOAD_LIMIT, RegisteredFunction
from .tasks import Task, TaskStatus, TaskStore
from .warming import (
    Container,
    ContainerRegistry,
    ContainerSpec,
    WarmCache,
    proportional_allocation,
)
from .worker import Worker, WorkItem, WorkResult

__all__ = [
    "ALL_SCOPES", "AuthError", "AuthService", "Channel", "Container",
    "ContainerRegistry", "ContainerSpec", "CostAwareRouter",
    "DynamicBatcher", "ElasticStrategy", "EndpointAgent",
    "EndpointUnavailable", "Forwarder", "FuncXClient", "FuncXError",
    "FuncXService", "LocalProvider", "LocalityAwareRouter", "Manager",
    "ManagerInfo", "PAYLOAD_LIMIT", "PayloadTooLarge", "Provider",
    "RandomRouter", "RegisteredFunction", "RegistrationError", "Router",
    "SCOPE_ENDPOINT", "SCOPE_REGISTER_FUNCTION", "SCOPE_RUN",
    "SCOPE_TRANSFER", "SimCloudProvider", "SimSlurmProvider", "Task",
    "TaskFailure", "TaskLost", "TaskStatus", "TaskStore", "Token",
    "WarmCache", "WarmingAwareRouter", "WorkItem", "WorkResult", "Worker",
    "make_router", "proportional_allocation", "split_arrays",
    "stack_arrays",
]
