"""Futures-native SDK surface (DESIGN.md §8): the funcX paper's
``FuncXExecutor`` — a ``concurrent.futures``-style executor whose
batching amortizes the per-task costs that dominate FaaS latency (§5).

    ex = client.executor(endpoint_id=eid)
    fut = ex.submit(my_fn, {"x": 1})       # real concurrent.futures.Future
    fut.result()
    ex.shutdown(wait=True)

``submit`` parks the call on a client-side :class:`SubmitCoalescer`
(the mirror of the endpoint's ResultCoalescer): a lone submit flushes
inline on the caller's thread — zero added latency over ``client.run`` —
while a many-thread submit storm is drained by a dedicated flusher into
batches of ~``batch_size``, each landed with **one**
``FuncXService.submit_packed_batch`` call (token validated once, one
store lock, one pool enqueue per endpoint group → one ``TaskBatch`` wire
frame per endpoint). Payloads are packed once, on the submitting
caller's thread, via the existing pack-once fast path.

Futures resolve off the result plane's ``BatchWaiter`` machinery: one
harvest thread holds a single long-lived waiter, registers each flush's
task ids incrementally (``TaskStore.watch``), and wakes once per result
*batch*, not per task. It starts with the first outstanding future and
exits when none remain — an idle executor owns no polling thread.
Remote failures propagate as ``TaskFailure``/``TaskLost`` into the
future; ``cancel()`` before the flush removes the parked entry (the
flush skips futures whose ``set_running_or_notify_cancel`` fails);
``shutdown(wait=True)`` drains parked submissions and outstanding
futures.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future, wait as _wait_futures
from typing import Any, Callable, Dict, Iterable, List, Optional

from .batching import SubmitCoalescer
from .errors import TaskFailure, TaskLost
from .tasks import TaskStatus


class FuncXExecutor:
    """``concurrent.futures``-style executor over a :class:`FuncXClient`.

    ``fn`` may be a callable (auto-registered with the service on first
    use, cached per executor) or an already-registered function id
    string. ``endpoint_id=None`` — at construction or per submit — routes
    each flush across the federation via the service's EndpointRouter.
    """

    def __init__(self, client, *, endpoint_id: Optional[str] = None,
                 container_type: Optional[str] = None,
                 warmth_key: Optional[str] = None,
                 batch_size: int = 32, linger: float = 0.002,
                 harvest_grace: float = 0.2):
        self.client = client
        self.service = client.service
        self.endpoint_id = endpoint_id
        self.container_type = container_type
        self.warmth_key = warmth_key
        self._fn_ids: Dict[Callable, str] = {}
        self._fn_lock = threading.Lock()
        self._lock = threading.Lock()
        self._futures: Dict[str, Future] = {}   # task_id → outstanding future
        self._unwatched: List[str] = []         # flushed, not yet on the waiter
        self._harvester: Optional[threading.Thread] = None
        self._work_event = threading.Event()   # new ids handed to harvest
        self.harvest_grace = harvest_grace
        self._shutdown = False
        self._cancel_parked = False
        self.coalescer = SubmitCoalescer(self._ship, batch_size=batch_size,
                                         linger=linger,
                                         outstanding=self.outstanding)
        # gauges
        self.tasks_submitted = 0               # tasks landed on the service
        self.tasks_cancelled = 0               # parked entries cancelled

    # ------------------------------------------------------------- submission
    def _function_id(self, fn) -> str:
        if isinstance(fn, str):
            return fn
        fid = self._fn_ids.get(fn)
        if fid is None:
            with self._fn_lock:
                fid = self._fn_ids.get(fn)
                if fid is None:
                    fid = self._fn_ids[fn] = \
                        self.client.register_function(fn)
        return fid

    def submit(self, fn, data: Any = None, *,
               endpoint_id: Optional[str] = None,
               container_type: Optional[str] = None,
               warmth_key: Optional[str] = None) -> Future:
        """Park one invocation on the coalescer and return its Future.
        The payload is packed here, on the caller's thread — a 16-thread
        storm packs in parallel and the flusher only groups bytes.
        ``warmth_key`` flows into the flush's RoutingContext: federation
        and manager routing both steer toward workers already holding
        the named artifact (jit cache entry, DESIGN.md §10)."""
        if self._shutdown:
            raise RuntimeError("cannot submit after shutdown")
        fid = self._function_id(fn)
        packed = self.client.pack_payload(data)
        fut: Future = Future()
        self.coalescer.add((fid, endpoint_id or self.endpoint_id, packed,
                            container_type or self.container_type,
                            warmth_key or self.warmth_key, fut))
        return fut

    def map(self, fn, payloads: Iterable[Any], *,
            endpoint_id: Optional[str] = None,
            timeout: Optional[float] = None) -> List[Any]:
        """Submit one task per payload; results in input order (the
        streaming form is plain ``concurrent.futures.as_completed`` over
        the futures from :meth:`submit`)."""
        futs = [self.submit(fn, p, endpoint_id=endpoint_id)
                for p in payloads]
        return [f.result(timeout) for f in futs]

    # -------------------------------------------------------- coalescer flush
    def _ship(self, batch: List[tuple]) -> None:
        """One coalescer flush: skip cancelled entries, land the rest with
        a single ``submit_packed_batch`` (which groups them per resolved
        endpoint), map task ids onto futures, and make sure the harvest
        thread is running. Never raises — a failed flush resolves its
        futures with the exception instead."""
        if self._cancel_parked:            # shutdown(cancel_futures=True)
            for entry in batch:
                if entry[5].cancel():
                    self.tasks_cancelled += 1
            return
        live = []
        for entry in batch:
            # a future whose cancel() landed before the flush never
            # becomes a task; everything else transitions to RUNNING
            # here, so cancel() from now on returns False
            if entry[5].set_running_or_notify_cancel():
                live.append(entry)
            else:
                self.tasks_cancelled += 1
        if not live:
            return
        try:
            tids = self.service.submit_packed_batch(
                self.client.token,
                [(fid, eid, packed, ct, wk)
                 for fid, eid, packed, ct, wk, _ in live])
        except Exception as e:             # noqa: BLE001 — resolve futures
            for entry in live:
                entry[5].set_exception(e)
            return
        with self._lock:
            for tid, entry in zip(tids, live):
                self._futures[tid] = entry[5]
            self._unwatched.extend(tids)
            self.tasks_submitted += len(tids)
            self._ensure_harvester_locked()
        self._work_event.set()

    # ---------------------------------------------------------------- harvest
    def _ensure_harvester_locked(self) -> None:
        if self._harvester is None:
            t = threading.Thread(target=self._harvest_loop, daemon=True,
                                 name="executor-harvest")
            self._harvester = t
            t.start()

    @property
    def harvest_running(self) -> bool:
        return self._harvester is not None

    def outstanding(self) -> int:
        with self._lock:
            return len(self._futures) + len(self._unwatched)

    def _resolve_wave(self, store, done) -> None:
        """Resolve one waiter wake's worth of futures with two store
        round-trips — ``get_many`` + ``purge_many`` — instead of a
        wait/get/purge lock cycle per task (the same amortization
        ``get_batch_results`` does; this is where the executor beats a
        per-call ``client.run`` + ``get_result`` harvest)."""
        with self._lock:
            wave = [(tid, self._futures.pop(tid)) for tid in done
                    if tid in self._futures]
        tids = [tid for tid, _ in wave]
        try:
            tasks = store.get_many(tids)
        except Exception as e:             # noqa: BLE001 — propagate
            for _, fut in wave:
                fut.set_exception(e)
            return
        for (tid, fut), task in zip(wave, tasks):
            if task is None:               # purged underneath us
                fut.set_exception(KeyError(tid))
            elif task.status == TaskStatus.SUCCESS:
                fut.set_result(task.result_value())   # decode-once
            elif task.status == TaskStatus.LOST:
                fut.set_exception(TaskLost(task.error or "task lost"))
            else:
                fut.set_exception(TaskFailure(task.error or "task failed",
                                              task.remote_traceback))
        if self.service.purge_on_get:
            store.purge_many(tids)

    def _harvest_loop(self) -> None:
        """One long-lived BatchWaiter serves every outstanding future:
        each flush's ids are registered incrementally and a 32-result
        ResultBatch wakes this loop once. At zero outstanding it lingers
        ``harvest_grace`` seconds for the next wave (sequential lone
        submits reuse the thread instead of paying a spawn each), then
        exits — an idle executor owns no thread. The exit check and
        ``_ship``'s restart share ``self._lock``, so a racing flush
        either keeps this thread alive or starts a fresh one — never
        neither."""
        store = self.service.tasks
        waiter = store.make_waiter(())
        try:
            while True:
                with self._lock:
                    new = self._unwatched
                    self._unwatched = []
                    active = bool(new or self._futures)
                if new:
                    store.watch(waiter, new)
                if active:
                    done = waiter.wait(0.05)
                    if done:
                        self._resolve_wave(store, done)
                    continue
                # zero outstanding: linger for the next wave, then stop.
                # clear-before-check so a flush landing between the check
                # and the wait leaves the event set (no lost wakeup).
                self._work_event.clear()
                with self._lock:
                    pending = bool(self._unwatched or self._futures)
                if pending or self._work_event.wait(self.harvest_grace):
                    continue
                with self._lock:
                    if not self._unwatched and not self._futures:
                        self._harvester = None
                        return
        finally:
            store.close_waiter(waiter)

    # --------------------------------------------------------------- lifecycle
    def shutdown(self, wait: bool = True, *,
                 cancel_futures: bool = False) -> None:
        """Refuse new submissions; flush what is parked (or cancel it,
        with ``cancel_futures=True``); with ``wait=True`` block until
        every outstanding future resolved. ``wait=False`` returns after
        the final flush — results keep arriving on the harvest thread."""
        with self._lock:
            already = self._shutdown
            self._shutdown = True
        if cancel_futures:
            self._cancel_parked = True
        if not already:
            self.coalescer.close()         # final drain, ships or cancels
        if wait:
            with self._lock:
                futs = list(self._futures.values())
            _wait_futures(futs)

    def __enter__(self) -> "FuncXExecutor":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown(wait=True)
