"""Forwarder (paper §4.1): one per registered endpoint. Reads the
endpoint's service-side task queue, dispatches batches over the channel,
tracks in-flight tasks, merges results into the task store, and monitors
endpoint heartbeats — requeueing all in-flight tasks when the endpoint
disconnects and resuming on reconnect (paper §4.1 fault tolerance).
"""
from __future__ import annotations

import collections
import threading
import time
from typing import Deque, Dict, List, Optional

from .comms import Channel
from .tasks import Task, TaskStatus, TaskStore, now


class Forwarder:
    def __init__(
        self,
        endpoint_id: str,
        task_store: TaskStore,
        channel: Channel,
        *,
        batch_size: int = 32,
        heartbeat_timeout: float = 0.5,
        send_rtt: float = 0.0,          # per-message latency (benchmarks)
    ):
        self.endpoint_id = endpoint_id
        self.task_store = task_store
        self.channel = channel
        self.batch_size = batch_size
        self.heartbeat_timeout = heartbeat_timeout
        self.send_rtt = send_rtt

        self.queue: Deque[str] = collections.deque()
        self._qlock = threading.Lock()
        self._qcond = threading.Condition(self._qlock)
        self._in_flight: Dict[str, float] = {}
        self._if_lock = threading.Lock()
        self.last_heartbeat = time.time()
        self.endpoint_connected = True
        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        # metrics
        self.dispatched = 0
        self.results_received = 0
        self.requeues = 0

    # ------------------------------------------------------------------ control
    def start(self) -> None:
        for name, fn in [("dispatch", self._dispatch_loop),
                         ("recv", self._recv_loop),
                         ("monitor", self._monitor_loop)]:
            t = threading.Thread(target=fn, daemon=True,
                                 name=f"fwd-{self.endpoint_id}-{name}")
            t.start()
            self._threads.append(t)

    def stop(self) -> None:
        self._stop.set()
        with self._qcond:
            self._qcond.notify_all()

    @property
    def healthy(self) -> bool:
        return all(t.is_alive() for t in self._threads) and \
            not self._stop.is_set()

    # ------------------------------------------------------------------ intake
    def enqueue(self, task_id: str, front: bool = False) -> None:
        with self._qcond:
            if front:
                self.queue.appendleft(task_id)
            else:
                self.queue.append(task_id)
            self._qcond.notify()

    def queue_len(self) -> int:
        with self._qlock:
            return len(self.queue)

    def in_flight_count(self) -> int:
        with self._if_lock:
            return len(self._in_flight)

    # ------------------------------------------------------------------- loops
    def _dispatch_loop(self) -> None:
        while not self._stop.is_set():
            if not self.endpoint_connected or not self.channel.connected:
                time.sleep(0.02)
                continue
            batch: List[str] = []
            with self._qcond:
                while not self.queue and not self._stop.is_set():
                    self._qcond.wait(timeout=0.1)
                while self.queue and len(batch) < self.batch_size:
                    batch.append(self.queue.popleft())
            if self._stop.is_set() or not batch:
                continue
            envs = []
            for tid in batch:
                try:
                    task = self.task_store.get(tid)
                except KeyError:
                    continue
                if task.done:
                    continue
                task.status = TaskStatus.DISPATCHED
                task.stamp("forwarder_sent")
                envs.append({"task_id": tid,
                             "function_id": task.function_id,
                             "container_type": task.container_type,
                             "payload": task.payload})
            if not envs:
                continue
            if self.send_rtt:
                time.sleep(self.send_rtt)
            ok = self.channel.send_to_endpoint(
                {"type": "task_batch", "tasks": envs}, tag="tasks")
            if ok:
                with self._if_lock:
                    for env in envs:
                        self._in_flight[env["task_id"]] = time.time()
                self.dispatched += len(envs)
            else:
                # channel refused (disconnected / dropped): requeue in order
                with self._qcond:
                    for env in reversed(envs):
                        self.queue.appendleft(env["task_id"])

    def _recv_loop(self) -> None:
        while not self._stop.is_set():
            msg = self.channel.recv_at_service(timeout=0.05)
            if msg is None:
                continue
            env, _tag = msg
            kind = env.get("type")
            if kind == "heartbeat":
                self.last_heartbeat = time.time()
                if not self.endpoint_connected:
                    self.endpoint_connected = True      # reconnected
            elif kind == "ack":
                for tid in env.get("task_ids", []):
                    try:
                        task = self.task_store.get(tid)
                        task.t.setdefault("endpoint_recv",
                                          env.get("t_endpoint_recv", now()))
                    except KeyError:
                        pass
            elif kind == "result":
                self._handle_result(env)

    def _handle_result(self, env: dict) -> None:
        tid = env["task_id"]
        with self._if_lock:
            self._in_flight.pop(tid, None)
        try:
            task = self.task_store.get(tid)
        except KeyError:
            return
        if task.done:
            return
        task.t.update(env.get("stamps", {}))
        task.cold_start = env.get("cold_start", False)
        task.worker_id = env.get("worker_id")
        task.manager_id = env.get("manager_id")
        if env["status"] == "SUCCESS":
            task.result = env.get("result")
            task.status = TaskStatus.SUCCESS
        elif env["status"] == "LOST":
            task.error = env.get("error")
            task.status = TaskStatus.LOST
        else:
            task.error = env.get("error")
            task.remote_traceback = env.get("remote_traceback", "")
            task.status = TaskStatus.FAILED
        task.stamp("result_stored")
        self.results_received += 1
        self.task_store.mark_done(tid)

    def _monitor_loop(self) -> None:
        """Heartbeat-based endpoint liveness (paper: 30 s default; scaled
        down here). On loss: requeue all in-flight tasks."""
        while not self._stop.is_set():
            time.sleep(self.heartbeat_timeout / 4)
            if time.time() - self.last_heartbeat > self.heartbeat_timeout:
                if self.endpoint_connected:
                    self.endpoint_connected = False
                    self._requeue_in_flight()

    def _requeue_in_flight(self) -> None:
        with self._if_lock:
            pending = list(self._in_flight.keys())
            self._in_flight.clear()
        requeued = 0
        for tid in pending:
            try:
                task = self.task_store.get(tid)
            except KeyError:
                continue
            if not task.done:
                task.status = TaskStatus.PENDING
                self.enqueue(tid, front=True)
                requeued += 1
        self.requeues += requeued
