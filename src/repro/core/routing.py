"""Function routing (paper §6.2).

The funcX agent routes each task to a manager:

  1. prefer managers with a *warm* container of the required type, choosing
     the one with the most available warm workers (load balance);
  2. otherwise pick a manager at random (the paper's fallback and the
     baseline we benchmark against).

Beyond-paper routers:
  - ``CostAwareRouter`` scores managers by expected completion time
    (queue wait + cold-start cost when no warm container), using the
    endpoint's measured build times — a dry-run-informed scheduler.
  - ``LocalityAwareRouter`` breaks warm ties toward managers whose local
    store already holds the task's input refs.

All routers consume the same advertised ``ManagerInfo`` snapshots, so
policies are swappable per endpoint (paper: 'modular scheduling interfaces').
"""
from __future__ import annotations

import random
import threading
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence


@dataclass
class ManagerInfo:
    """What a manager advertises to the endpoint agent (paper §6.2)."""
    manager_id: str
    idle_workers: int
    queued: int
    warm_idle: Dict[str, int]          # container_type → idle workers warm
    warm_total: Dict[str, int]         # container_type → workers warm
    capacity: int                      # total workers
    local_keys: frozenset = frozenset()  # store keys held locally

    @property
    def free_room(self) -> int:
        return max(self.capacity - self.queued, 0)


class Router:
    name = "abstract"

    def route(self, container_type: str, managers: Sequence[ManagerInfo],
              input_keys: frozenset = frozenset()) -> Optional[str]:
        raise NotImplementedError


class RandomRouter(Router):
    """Paper's baseline: uniformly random among managers with room."""

    name = "random"

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)

    def route(self, container_type, managers, input_keys=frozenset()):
        if not managers:
            return None
        with_room = [m for m in managers if m.free_room > 0]
        pool = with_room or list(managers)
        return self.rng.choice(pool).manager_id


class WarmingAwareRouter(Router):
    """Paper §6.2: warm container first, most-available-warm-workers
    tie-break, random fallback."""

    name = "warming_aware"

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)

    def route(self, container_type, managers, input_keys=frozenset()):
        if not managers:
            return None
        warm = [m for m in managers if m.warm_idle.get(container_type, 0) > 0]
        if warm:
            best = max(warm, key=lambda m: m.warm_idle[container_type])
            return best.manager_id
        # second chance: warm-but-busy (task queues behind a warm worker,
        # still avoiding a cold start)
        warm_busy = [m for m in managers
                     if m.warm_total.get(container_type, 0) > 0
                     and m.free_room > 0]
        if warm_busy:
            best = max(warm_busy, key=lambda m: m.warm_total[container_type])
            return best.manager_id
        with_room = [m for m in managers if m.free_room > 0]
        pool = with_room or list(managers)
        return self.rng.choice(pool).manager_id


class WarmingHashRouter(WarmingAwareRouter):
    """Beyond-paper: warming-aware with a *consistent-hash* cold fallback.

    The paper falls back to uniform random when no warm container exists,
    which scatters each type across all managers and (under slot pressure)
    thrashes containers. Hashing the container type onto the manager ring
    creates type→manager affinity from the very first task, so the fleet
    converges to a stable specialization without any coordination."""

    name = "warming_hash"

    def route(self, container_type, managers, input_keys=frozenset()):
        if not managers:
            return None
        warm = [m for m in managers if m.warm_idle.get(container_type, 0) > 0]
        if warm:
            return max(warm,
                       key=lambda m: m.warm_idle[container_type]).manager_id
        warm_busy = [m for m in managers
                     if m.warm_total.get(container_type, 0) > 0
                     and m.free_room > 0]
        if warm_busy:
            return max(warm_busy,
                       key=lambda m: m.warm_total[container_type]).manager_id
        ordered = sorted(managers, key=lambda m: m.manager_id)
        h = hash(container_type)
        for probe in range(len(ordered)):        # linear probe past full ones
            m = ordered[(h + probe) % len(ordered)]
            if m.free_room > 0:
                return m.manager_id
        return ordered[h % len(ordered)].manager_id


class CostAwareRouter(Router):
    """Beyond-paper: minimize expected completion = queue_wait + cold_cost.

    ``cold_cost(type)`` defaults to the endpoint's running mean of measured
    build times per type; ``mean_task_s`` estimates queue drain rate."""

    name = "cost_aware"

    def __init__(self, seed: int = 0, default_cold_cost: float = 1.0,
                 mean_task_s: float = 0.05):
        self.rng = random.Random(seed)
        self.default_cold_cost = default_cold_cost
        self.mean_task_s = mean_task_s
        self._costs: Dict[str, float] = {}
        self._lock = threading.Lock()

    def observe_build(self, container_type: str, seconds: float) -> None:
        with self._lock:
            prev = self._costs.get(container_type)
            self._costs[container_type] = (seconds if prev is None
                                           else 0.8 * prev + 0.2 * seconds)

    def cold_cost(self, container_type: str) -> float:
        with self._lock:
            return self._costs.get(container_type, self.default_cold_cost)

    def route(self, container_type, managers, input_keys=frozenset()):
        if not managers:
            return None

        def score(m: ManagerInfo) -> float:
            wait = (m.queued / max(m.capacity, 1)) * self.mean_task_s
            cold = 0.0 if m.warm_total.get(container_type, 0) > 0 \
                else self.cold_cost(container_type)
            # small jitter to spread exact ties
            return wait + cold + self.rng.random() * 1e-6

        return min(managers, key=score).manager_id


class LocalityAwareRouter(WarmingAwareRouter):
    """Beyond-paper: among equally-warm managers prefer data locality."""

    name = "locality_aware"

    def route(self, container_type, managers, input_keys=frozenset()):
        if not managers:
            return None
        warm = [m for m in managers if m.warm_idle.get(container_type, 0) > 0]
        if warm and input_keys:
            def overlap(m):
                return len(input_keys & m.local_keys)
            best = max(warm, key=lambda m: (overlap(m),
                                            m.warm_idle[container_type]))
            return best.manager_id
        return super().route(container_type, managers, input_keys)


ROUTERS = {
    "random": RandomRouter,
    "warming_aware": WarmingAwareRouter,
    "warming_hash": WarmingHashRouter,
    "cost_aware": CostAwareRouter,
    "locality_aware": LocalityAwareRouter,
}


def make_router(name: str, **kw) -> Router:
    return ROUTERS[name](**kw)


# ---------------------------------------------------------------------------
# Federation-level routing (paper §4.1 + §6.2 lifted one tier up): the
# service picks an *endpoint* for a task submitted without one, the same
# way an endpoint agent picks a manager. Endpoint state comes from the
# ForwarderPool: service-side queue depth + in-flight counts are first-hand,
# endpoint-internal load and warm-container state ride in on heartbeats.
# ---------------------------------------------------------------------------

@dataclass
class EndpointInfo:
    """What the service knows about one endpoint when routing across the
    federation (the endpoint-level analogue of ``ManagerInfo``)."""
    endpoint_id: str
    connected: bool = True
    service_queue: int = 0             # tasks queued service-side
    in_flight: int = 0                 # dispatched, result not yet back
    queued: int = 0                    # heartbeat: pending inside endpoint
    idle_workers: int = 0              # heartbeat
    capacity: int = 0                  # heartbeat: total workers
    warm_idle: Dict[str, int] = field(default_factory=dict)
    warm_total: Dict[str, int] = field(default_factory=dict)

    @property
    def backlog(self) -> int:
        return self.service_queue + self.in_flight + self.queued

    @property
    def load(self) -> float:
        """Backlog normalized by capacity (uncapacitated endpoints —
        heartbeat not seen yet — count as capacity 1)."""
        return self.backlog / max(self.capacity, 1)

    def note_pick(self, container_type: str) -> None:
        """Feed a routing pick back into this snapshot (queue depth up,
        warm-idle and idle-workers down) so consecutive picks from the
        same snapshot — a routed batch or coalesced flush — spread over
        the fleet instead of all landing on the momentary best
        endpoint."""
        self.service_queue += 1
        if self.warm_idle.get(container_type, 0) > 0:
            self.warm_idle[container_type] -= 1
        if self.idle_workers > 0:
            self.idle_workers -= 1


class EndpointRouter:
    name = "abstract"

    def select(self, container_type: str,
               endpoints: Sequence[EndpointInfo]) -> Optional[str]:
        raise NotImplementedError

    def select_many(self, container_type: str,
                    endpoints: Sequence[EndpointInfo],
                    n: int) -> List[str]:
        """``n`` picks against one snapshot, with each pick fed back via
        :meth:`EndpointInfo.note_pick` before the next — the per-flush
        grouping primitive for coalesced submissions (DESIGN.md §8).
        Stops short (returned list < ``n``) only if the policy returns
        no endpoint."""
        out: List[str] = []
        for _ in range(n):
            eid = self.select(container_type, endpoints)
            if eid is None:
                break
            for e in endpoints:
                if e.endpoint_id == eid:
                    e.note_pick(container_type)
                    break
            out.append(eid)
        return out

    @staticmethod
    def _candidates(endpoints: Sequence[EndpointInfo]) -> List[EndpointInfo]:
        up = [e for e in endpoints if e.connected]
        return up or list(endpoints)


class RandomEndpointRouter(EndpointRouter):
    """Baseline: uniformly random among connected endpoints."""

    name = "random"

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)

    def select(self, container_type, endpoints):
        if not endpoints:
            return None
        return self.rng.choice(self._candidates(endpoints)).endpoint_id


class LeastLoadedEndpointRouter(EndpointRouter):
    """Pick the endpoint with the lowest backlog per unit of capacity."""

    name = "least_loaded"

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)

    def select(self, container_type, endpoints):
        if not endpoints:
            return None
        pool = self._candidates(endpoints)
        return min(pool, key=lambda e: (e.load,
                                        self.rng.random())).endpoint_id


class WarmingAwareEndpointRouter(EndpointRouter):
    """Paper §6.2 at federation scope: endpoints advertising an *idle warm*
    container of the required type win (most warm-idle first, least backlog
    tie-break); then endpoints where the type is warm but busy; then
    least-loaded — so the 61 % completion-time win from warming-aware
    manager routing compounds across the fleet."""

    name = "warming_aware"

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)

    def select(self, container_type, endpoints):
        if not endpoints:
            return None
        pool = self._candidates(endpoints)
        warm = [e for e in pool if e.warm_idle.get(container_type, 0) > 0]
        if warm:
            best = max(warm, key=lambda e: (e.warm_idle[container_type],
                                            -e.backlog))
            return best.endpoint_id
        warm_busy = [e for e in pool
                     if e.warm_total.get(container_type, 0) > 0]
        if warm_busy:
            best = max(warm_busy, key=lambda e: (e.warm_total[container_type],
                                                 -e.backlog))
            return best.endpoint_id
        return min(pool, key=lambda e: (e.load,
                                        self.rng.random())).endpoint_id


ENDPOINT_ROUTERS = {
    "random": RandomEndpointRouter,
    "least_loaded": LeastLoadedEndpointRouter,
    "warming_aware": WarmingAwareEndpointRouter,
}


def make_endpoint_router(name: str, **kw) -> EndpointRouter:
    return ENDPOINT_ROUTERS[name](**kw)
