"""Function routing (paper §6.2) behind one warmth-key mechanism.

The funcX agent routes each task to a manager:

  1. prefer managers with a *warm* copy of the expensive artifact the
     task needs — the paper's warm container, or (DESIGN.md §10) a
     jit-compiled executable — choosing the one with the most available
     warm workers (load balance);
  2. otherwise pick a manager at random (the paper's fallback and the
     baseline we benchmark against).

What "warm" means is named by a **warmth key**: by default the task's
container type, but any string advertised through the same
``warm_idle``/``warm_total`` heartbeat dicts (e.g.
``jit/<arch>/<step>/<bucket>`` for a compiled serving step). Every
placement decision flows through one :class:`RoutingContext` — container
warmth and jit warmth are two instances of the same mechanism — and all
advertised warm state is read and mutated through one
:class:`WarmthView` accessor.

Beyond-paper routers:
  - ``CostAwareRouter`` scores managers by expected completion time
    (queue wait + cold-start cost when no warm copy), using the
    endpoint's measured build times — a dry-run-informed scheduler.
  - ``LocalityAwareRouter`` breaks warm ties toward managers whose local
    store already holds the task's input refs.

All routers consume the same advertised ``ManagerInfo`` snapshots, so
policies are swappable per endpoint (paper: 'modular scheduling
interfaces'). The federation tier (``EndpointRouter``) applies the same
policies one level up, over ``EndpointInfo`` snapshots.

Every entry point takes a :class:`RoutingContext` — the PR 9 legacy
positional-string shims (``RoutingContext.coerce``, string ``route``/
``select``, ``make_endpoint_router``) are gone.
"""
from __future__ import annotations

import random
import threading
from dataclasses import dataclass, field
from typing import Dict, Iterable, List, Mapping, Optional, Sequence, Tuple


# ---------------------------------------------------------------------------
# RoutingContext — the one argument every routing decision takes
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class RoutingContext:
    """Everything a router may consider for one placement decision.

    ``warmth_key`` names the expensive, reusable artifact the task wants
    to land next to: a warm container type, a jit-compiled executable
    (``jit/<arch>/<step>/<bucket>``), anything a worker advertises
    through the warm dicts. Unset, it defaults to ``container_type`` —
    the paper's original behaviour. When an explicit warmth key
    *refines* the container type, the container type remains a fallback
    warmth key: jit-warm beats container-warm beats cold.

    ``hints`` is an open side channel (policy knobs, tenant tags) that
    concrete routers may consult; core routers ignore unknown hints.
    """
    warmth_key: Optional[str] = None
    container_type: str = "python"
    input_keys: frozenset = frozenset()
    hints: Mapping[str, object] = field(default_factory=dict)

    @property
    def key(self) -> str:
        """Primary warmth key: the explicit one, else the container type."""
        return self.warmth_key or self.container_type

    @property
    def warmth_keys(self) -> Tuple[str, ...]:
        """Warmth keys in preference order (primary, then the container
        type when an explicit warmth key refines it)."""
        if self.warmth_key and self.warmth_key != self.container_type:
            return (self.warmth_key, self.container_type)
        return (self.key,)


# ---------------------------------------------------------------------------
# WarmthView — the one parsing point for advertised warm state
# ---------------------------------------------------------------------------

class WarmthView:
    """Accessor over the advertised warm dicts (``{warmth_key: count}``).

    Three layers used to parse these shapes independently — the manager's
    worker scan, the endpoint agent's heartbeat merge, and the service's
    ``EndpointInfo`` snapshot (plus every router's reads). They all go
    through this view now, so a change to what a warmth key *is* (jit
    keys riding next to container types, DESIGN.md §10) lands in one
    place. The view wraps the owning snapshot's dicts — mutations
    (``note_pick``) write through.
    """

    __slots__ = ("idle", "total")

    def __init__(self, idle: Optional[Dict[str, int]] = None,
                 total: Optional[Dict[str, int]] = None):
        self.idle = idle if idle is not None else {}
        self.total = total if total is not None else {}

    # -- queries -------------------------------------------------------------
    def warm_idle(self, key: str) -> int:
        return self.idle.get(key, 0)

    def warm_total(self, key: str) -> int:
        return self.total.get(key, 0)

    def is_warm(self, ctx: "RoutingContext") -> bool:
        return any(self.warm_total(k) > 0 for k in ctx.warmth_keys)

    # -- mutation ------------------------------------------------------------
    def note_pick(self, key: str) -> None:
        """Feed one routing pick back: an idle warm worker for ``key`` is
        about to become busy."""
        if self.idle.get(key, 0) > 0:
            self.idle[key] -= 1

    def add(self, key: str, *, idle: int = 0, total: int = 0) -> None:
        if idle:
            self.idle[key] = self.idle.get(key, 0) + idle
        if total:
            self.total[key] = self.total.get(key, 0) + total

    # -- builders (the three call sites) --------------------------------------
    @classmethod
    def tally(cls, workers: Iterable[Tuple[Iterable[str], bool]]
              ) -> "WarmthView":
        """Manager tier: fold ``(warm_keys, is_idle)`` per worker into one
        advertisement."""
        view = cls()
        for keys, is_idle in workers:
            for k in keys:
                view.add(k, idle=1 if is_idle else 0, total=1)
        return view

    @classmethod
    def merge(cls, views: Iterable["WarmthView"]) -> "WarmthView":
        """Endpoint tier: sum per-manager advertisements into the
        heartbeat's fleet-wide dicts."""
        out = cls()
        for v in views:
            for k, n in v.idle.items():
                out.idle[k] = out.idle.get(k, 0) + n
            for k, n in v.total.items():
                out.total[k] = out.total.get(k, 0) + n
        return out

    @classmethod
    def from_heartbeat(cls, hb) -> "WarmthView":
        """Service tier: copy a heartbeat's advertised warm state into a
        routable (mutable, snapshot-local) view."""
        return cls(dict(hb.warm_idle), dict(hb.warm_total))


# ---------------------------------------------------------------------------
# Snapshots
# ---------------------------------------------------------------------------

@dataclass
class ManagerInfo:
    """What a manager advertises to the endpoint agent (paper §6.2)."""
    manager_id: str
    idle_workers: int
    queued: int
    warm_idle: Dict[str, int]          # warmth_key → idle workers warm
    warm_total: Dict[str, int]         # warmth_key → workers warm
    capacity: int                      # total workers
    local_keys: frozenset = frozenset()  # store keys held locally

    @property
    def free_room(self) -> int:
        return max(self.capacity - self.queued, 0)

    @property
    def warmth(self) -> WarmthView:
        """Write-through view over this snapshot's warm dicts."""
        return WarmthView(self.warm_idle, self.warm_total)


# ---------------------------------------------------------------------------
# Shared policy plumbing (both tiers)
# ---------------------------------------------------------------------------

class _SeededPolicy:
    """Shared seeded-RNG handling: every router in both tiers takes a
    ``seed`` and draws from its own ``random.Random`` (reproducible
    benchmarks, no cross-policy interference)."""

    name = "abstract"

    def __init__(self, seed: int = 0):
        self.rng = random.Random(seed)


# ---------------------------------------------------------------------------
# Manager-tier routers
# ---------------------------------------------------------------------------

class Router(_SeededPolicy):
    """Manager-tier routing policy. Policies implement
    :meth:`route_ctx`; :meth:`route` is the stable entry point and
    requires a :class:`RoutingContext`."""

    def route(self, ctx: RoutingContext,
              managers: Sequence[ManagerInfo]) -> Optional[str]:
        return self.route_ctx(ctx, managers)

    def route_ctx(self, ctx: RoutingContext,
                  managers: Sequence[ManagerInfo]) -> Optional[str]:
        raise NotImplementedError


class RandomRouter(Router):
    """Paper's baseline: uniformly random among managers with room."""

    name = "random"

    def route_ctx(self, ctx, managers):
        if not managers:
            return None
        with_room = [m for m in managers if m.free_room > 0]
        pool = with_room or list(managers)
        return self.rng.choice(pool).manager_id


class WarmingAwareRouter(Router):
    """Paper §6.2 generalized to warmth keys: warm-idle on the primary
    key first (most available warm workers wins), then warm-idle on the
    fallback key (container warm, jit cold), then warm-but-busy in the
    same key order (queue behind the warm copy rather than cold-start),
    then the cold fallback."""

    name = "warming_aware"

    def route_ctx(self, ctx, managers):
        if not managers:
            return None
        for key in ctx.warmth_keys:
            warm = [m for m in managers if m.warmth.warm_idle(key) > 0]
            if warm:
                best = max(warm, key=lambda m: m.warmth.warm_idle(key))
                return best.manager_id
        # second chance: warm-but-busy (task queues behind a warm worker,
        # still avoiding a cold start)
        for key in ctx.warmth_keys:
            warm_busy = [m for m in managers
                         if m.warmth.warm_total(key) > 0
                         and m.free_room > 0]
            if warm_busy:
                best = max(warm_busy,
                           key=lambda m: m.warmth.warm_total(key))
                return best.manager_id
        return self._cold(ctx, managers)

    def _cold(self, ctx, managers) -> Optional[str]:
        with_room = [m for m in managers if m.free_room > 0]
        pool = with_room or list(managers)
        return self.rng.choice(pool).manager_id


class WarmingHashRouter(WarmingAwareRouter):
    """Beyond-paper: warming-aware with a *consistent-hash* cold fallback.

    The paper falls back to uniform random when no warm copy exists,
    which scatters each warmth key across all managers and (under slot
    pressure) thrashes the caches. Hashing the key onto the manager ring
    creates key→manager affinity from the very first task, so the fleet
    converges to a stable specialization without any coordination."""

    name = "warming_hash"

    def _cold(self, ctx, managers):
        ordered = sorted(managers, key=lambda m: m.manager_id)
        h = hash(ctx.key)
        for probe in range(len(ordered)):        # linear probe past full ones
            m = ordered[(h + probe) % len(ordered)]
            if m.free_room > 0:
                return m.manager_id
        return ordered[h % len(ordered)].manager_id


class CostAwareRouter(Router):
    """Beyond-paper: minimize expected completion = queue_wait + cold_cost.

    ``cold_cost(key)`` defaults to the endpoint's running mean of measured
    build times per warmth key (fed by :meth:`observe_build` — the agent
    reports every cold build it sees, see DESIGN.md §10);
    ``mean_task_s`` estimates queue drain rate."""

    name = "cost_aware"

    def __init__(self, seed: int = 0, default_cold_cost: float = 1.0,
                 mean_task_s: float = 0.05):
        super().__init__(seed)
        self.default_cold_cost = default_cold_cost
        self.mean_task_s = mean_task_s
        self._costs: Dict[str, float] = {}
        self._lock = threading.Lock()

    def observe_build(self, warmth_key: str, seconds: float) -> None:
        with self._lock:
            prev = self._costs.get(warmth_key)
            self._costs[warmth_key] = (seconds if prev is None
                                       else 0.8 * prev + 0.2 * seconds)

    def cold_cost(self, warmth_key: str) -> float:
        with self._lock:
            return self._costs.get(warmth_key, self.default_cold_cost)

    def route_ctx(self, ctx, managers):
        if not managers:
            return None

        def score(m: ManagerInfo) -> float:
            wait = (m.queued / max(m.capacity, 1)) * self.mean_task_s
            cold = 0.0
            if not any(m.warmth.warm_total(k) > 0 for k in ctx.warmth_keys):
                cold = self.cold_cost(ctx.key)
            elif m.warmth.warm_total(ctx.key) == 0:
                # container warm, refined artifact (jit) still to build
                cold = self.cold_cost(ctx.key) \
                    - min(self.cold_cost(ctx.container_type),
                          self.cold_cost(ctx.key))
            # small jitter to spread exact ties
            return wait + cold + self.rng.random() * 1e-6

        return min(managers, key=score).manager_id


class LocalityAwareRouter(WarmingAwareRouter):
    """Beyond-paper: among equally-warm managers prefer data locality."""

    name = "locality_aware"

    def route_ctx(self, ctx, managers):
        if not managers:
            return None
        key = ctx.key
        warm = [m for m in managers if m.warmth.warm_idle(key) > 0]
        if warm and ctx.input_keys:
            def overlap(m):
                return len(ctx.input_keys & m.local_keys)
            best = max(warm, key=lambda m: (overlap(m),
                                            m.warmth.warm_idle(key)))
            return best.manager_id
        return super().route_ctx(ctx, managers)


# ---------------------------------------------------------------------------
# Federation-level routing (paper §4.1 + §6.2 lifted one tier up): the
# service picks an *endpoint* for a task submitted without one, the same
# way an endpoint agent picks a manager. Endpoint state comes from the
# ForwarderPool: service-side queue depth + in-flight counts are first-hand,
# endpoint-internal load and warm state ride in on heartbeats.
# ---------------------------------------------------------------------------

@dataclass
class EndpointInfo:
    """What the service knows about one endpoint when routing across the
    federation (the endpoint-level analogue of ``ManagerInfo``)."""
    endpoint_id: str
    connected: bool = True
    service_queue: int = 0             # tasks queued service-side
    in_flight: int = 0                 # dispatched, result not yet back
    queued: int = 0                    # heartbeat: pending inside endpoint
    idle_workers: int = 0              # heartbeat
    capacity: int = 0                  # heartbeat: total workers
    warm_idle: Dict[str, int] = field(default_factory=dict)
    warm_total: Dict[str, int] = field(default_factory=dict)

    @property
    def backlog(self) -> int:
        return self.service_queue + self.in_flight + self.queued

    @property
    def load(self) -> float:
        """Backlog normalized by capacity (uncapacitated endpoints —
        heartbeat not seen yet — count as capacity 1)."""
        return self.backlog / max(self.capacity, 1)

    @property
    def warmth(self) -> WarmthView:
        """Write-through view over this snapshot's warm dicts."""
        return WarmthView(self.warm_idle, self.warm_total)

    def note_pick(self, key) -> None:
        """Feed a routing pick back into this snapshot (queue depth up,
        warm-idle and idle-workers down) so consecutive picks from the
        same snapshot — a routed batch or coalesced flush — spread over
        the fleet instead of all landing on the momentary best endpoint.
        ``key`` is a warmth key or a RoutingContext."""
        self.service_queue += 1
        self.warmth.note_pick(key.key if isinstance(key, RoutingContext)
                              else key)
        if self.idle_workers > 0:
            self.idle_workers -= 1


class EndpointRouter(_SeededPolicy):
    """Federation-tier routing policy. Policies implement
    :meth:`select_ctx`; :meth:`select` is the stable entry point and
    requires a :class:`RoutingContext`."""

    def select(self, ctx: RoutingContext,
               endpoints: Sequence[EndpointInfo]) -> Optional[str]:
        return self.select_ctx(ctx, endpoints)

    def select_ctx(self, ctx: RoutingContext,
                   endpoints: Sequence[EndpointInfo]) -> Optional[str]:
        raise NotImplementedError

    def select_many(self, ctx: RoutingContext,
                    endpoints: Sequence[EndpointInfo],
                    n: int) -> List[str]:
        """``n`` picks against one snapshot, with each pick fed back via
        :meth:`EndpointInfo.note_pick` before the next — the per-flush
        grouping primitive for coalesced submissions (DESIGN.md §8).
        Stops short (returned list < ``n``) only if the policy returns
        no endpoint."""
        out: List[str] = []
        for _ in range(n):
            eid = self.select_ctx(ctx, endpoints)
            if eid is None:
                break
            for e in endpoints:
                if e.endpoint_id == eid:
                    e.note_pick(ctx)
                    break
            out.append(eid)
        return out

    @staticmethod
    def _candidates(endpoints: Sequence[EndpointInfo]) -> List[EndpointInfo]:
        up = [e for e in endpoints if e.connected]
        return up or list(endpoints)


class RandomEndpointRouter(EndpointRouter):
    """Baseline: uniformly random among connected endpoints."""

    name = "random"

    def select_ctx(self, ctx, endpoints):
        if not endpoints:
            return None
        return self.rng.choice(self._candidates(endpoints)).endpoint_id


class LeastLoadedEndpointRouter(EndpointRouter):
    """Pick the endpoint with the lowest backlog per unit of capacity."""

    name = "least_loaded"

    def select_ctx(self, ctx, endpoints):
        if not endpoints:
            return None
        pool = self._candidates(endpoints)
        return min(pool, key=lambda e: (e.load,
                                        self.rng.random())).endpoint_id


class WarmingAwareEndpointRouter(EndpointRouter):
    """Paper §6.2 at federation scope, generalized to warmth keys:
    endpoints advertising an *idle warm* copy for the primary key win
    (most warm-idle first, least backlog tie-break), then idle-warm on
    the fallback key, then warm-but-busy in the same key order, then
    least-loaded — so the 61 % completion-time win from warming-aware
    manager routing compounds across the fleet."""

    name = "warming_aware"

    def select_ctx(self, ctx, endpoints):
        if not endpoints:
            return None
        pool = self._candidates(endpoints)
        for key in ctx.warmth_keys:
            warm = [e for e in pool if e.warmth.warm_idle(key) > 0]
            if warm:
                best = max(warm, key=lambda e: (e.warmth.warm_idle(key),
                                                -e.backlog))
                return best.endpoint_id
        for key in ctx.warmth_keys:
            warm_busy = [e for e in pool
                         if e.warmth.warm_total(key) > 0]
            if warm_busy:
                best = max(warm_busy,
                           key=lambda e: (e.warmth.warm_total(key),
                                          -e.backlog))
                return best.endpoint_id
        return min(pool, key=lambda e: (e.load,
                                        self.rng.random())).endpoint_id


# ---------------------------------------------------------------------------
# One registry, two tiers
# ---------------------------------------------------------------------------

ROUTERS = {
    "random": RandomRouter,
    "warming_aware": WarmingAwareRouter,
    "warming_hash": WarmingHashRouter,
    "cost_aware": CostAwareRouter,
    "locality_aware": LocalityAwareRouter,
}

ENDPOINT_ROUTERS = {
    "random": RandomEndpointRouter,
    "least_loaded": LeastLoadedEndpointRouter,
    "warming_aware": WarmingAwareEndpointRouter,
}

_TIERS = {"manager": ROUTERS, "endpoint": ENDPOINT_ROUTERS}


def make_router(name: str, tier: str = "manager", **kw):
    """One factory for both tiers: ``make_router("warming_aware")`` builds
    the manager-tier policy an endpoint agent uses;
    ``make_router("warming_aware", tier="endpoint")`` the federation-tier
    policy the service uses."""
    try:
        registry = _TIERS[tier]
    except KeyError:
        raise KeyError(f"unknown routing tier {tier!r}; "
                       f"options: {sorted(_TIERS)}") from None
    try:
        cls = registry[name]
    except KeyError:
        raise KeyError(f"unknown {tier}-tier router {name!r}; "
                       f"options: {sorted(registry)}") from None
    return cls(**kw)
