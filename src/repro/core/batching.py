"""Batching (paper §4.6) + beyond-paper request coalescing.

The paper's two batching forms live elsewhere in the runtime:
  - *internal batching*: ForwarderPool.batch_size + Manager.prefetch (managers
    request many tasks on behalf of their workers);
  - *user-facing batching*: FuncXService.submit_batch / client.batch_run.

This module adds two more:

  - **SubmitCoalescer** — the client-side mirror of the endpoint's
    ResultCoalescer (DESIGN.md §8): submissions parked by many caller
    threads are drained into batched flushes, so the "millions of small
    callers" shape pays service/wire cost per *flush*, not per task.
    Used by :class:`~repro.core.executor.FuncXExecutor`.
  - **dynamic request coalescing** (``DynamicBatcher``) — concurrent
    invocations of the same function within a small window are stacked
    into one batched execution (one compiled program run for N requests)
    and the results are fanned back out. This is what turns the FaaS
    layer into a batched model server.
"""
from __future__ import annotations

import collections
import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, Deque, List, Optional, Sequence, Tuple

import numpy as np


class SubmitCoalescer:
    """Adaptive micro-batching for the submit path (DESIGN.md §8).

    Entries are opaque to the coalescer — it owns *when* batches ship,
    the caller's ``ship(entries)`` callback owns *how* (the executor
    groups them per resolved endpoint and lands them with one
    ``submit_packed_batch``). Two regimes, the same policy as the result
    plane's ResultCoalescer:

    - **idle line** — a submission arriving alone, with nothing else
      parked and nothing outstanding (``outstanding()`` is the
      executor's count of unresolved futures — the submit-side analogue
      of the result coalescer's results-still-to-come signal), flushes
      inline on the caller's own thread (no handoff, no linger, no
      timer): a lone ``executor.submit`` pays zero added latency over a
      direct ``client.run``;
    - **loaded line** (other submissions parked, or futures already in
      flight — a wave in progress) — the producer just appends
      (deque.append is atomic under the GIL; the kick Event is touched
      through an ``is_set()`` fast path) and the dedicated flusher
      thread drains everything pending in batches of at most
      ``batch_size``, holding an under-full batch open for a bounded
      *linger* so it fills toward ``batch_size``. A 16-thread submit
      storm thus ships ~batch_size tasks per flush.

    ``ship`` must not raise — the executor resolves per-entry futures
    itself; an exception escaping here would kill the flusher and strand
    parked work.
    """

    def __init__(self, ship: Callable[[List[Any]], None], *,
                 batch_size: int = 32, linger: float = 0.002,
                 outstanding: Optional[Callable[[], int]] = None):
        self._ship = ship
        self.batch_size = batch_size
        self.linger = linger
        self._outstanding = outstanding if outstanding is not None \
            else (lambda: 0)
        self._parked: Deque[Any] = collections.deque()
        self._kick = threading.Event()       # "pending work" signal
        self._flush_lock = threading.Lock()  # one drainer at a time
        self._stop = threading.Event()
        # gauges (submit-plane acceptance: flushes/task << 1 under storm)
        self.flushes = 0                     # ship() calls
        self.entries_shipped = 0
        self._thread = threading.Thread(target=self._flush_loop, daemon=True,
                                        name="submit-coalescer")
        self._thread.start()

    def close(self) -> None:
        """Stop the flusher, then drain whatever is parked — every
        accepted submission is shipped (or cancelled by the executor's
        ship callback), never silently dropped."""
        self._stop.set()
        self._kick.set()
        with self._flush_lock:
            self._drain()
        self._thread.join(timeout=2.0)

    def pending(self) -> int:
        return len(self._parked)

    # -- producers ---------------------------------------------------------
    def add(self, entry: Any) -> None:
        self._parked.append(entry)
        if self._stop.is_set():
            # flusher is gone (executor shutting down, a racing submit
            # slipped in): drain synchronously — blocking acquire, because
            # a kick nobody listens to would strand this entry
            with self._flush_lock:
                self._drain()
            return
        if len(self._parked) == 1 and self._outstanding() <= 0:
            # idle line: this submission is alone and no wave is in
            # flight — ship on this thread right now. If the flusher
            # happens to hold the lock it is actively draining and will
            # recheck; the kick covers the race window.
            if self._flush_lock.acquire(blocking=False):
                try:
                    self._drain(max_flushes=1)
                finally:
                    self._flush_lock.release()
            else:
                self._kick.set()
            return
        if not self._kick.is_set():          # lock-free in steady state —
            self._kick.set()                 # under storm the kick stays set

    # -- the flusher -------------------------------------------------------
    def _flush_loop(self) -> None:
        while not self._stop.is_set():
            if not self._parked:
                self._kick.wait(0.05)
                self._kick.clear()
                continue
            if self.linger > 0 and len(self._parked) < self.batch_size:
                # under-full batch with callers still appending: let it
                # fill. A plain bounded sleep — a lone submit never waits
                # on it because the idle line flushes inline on the
                # caller's thread instead of landing here.
                self._stop.wait(self.linger)
            with self._flush_lock:
                self._drain(max_flushes=1)

    def _drain(self, max_flushes: Optional[int] = None) -> None:
        flushed = 0
        while self._parked and (max_flushes is None
                                or flushed < max_flushes):
            batch: List[Any] = []
            while self._parked and len(batch) < self.batch_size:
                try:
                    batch.append(self._parked.popleft())
                except IndexError:         # racing drainer emptied it
                    break
            if not batch:
                return
            self._ship(batch)
            self.flushes += 1
            self.entries_shipped += len(batch)
            flushed += 1


def stack_arrays(payloads: Sequence[Any]) -> Any:
    """Default stack: dict-of-arrays payloads are concatenated on axis 0;
    scalar fields (e.g. "n_tokens") must agree and pass through."""
    first = payloads[0]
    if isinstance(first, dict):
        out = {}
        for k in first:
            v0 = np.asarray(first[k])
            if v0.ndim == 0:
                vals = {np.asarray(p[k]).item() for p in payloads}
                if len(vals) != 1:
                    raise ValueError(
                        f"cannot coalesce: scalar field {k!r} differs "
                        f"across requests ({vals})")
                out[k] = first[k]
            else:
                out[k] = np.concatenate([np.asarray(p[k]) for p in payloads],
                                        axis=0)
        return out
    return np.concatenate([np.asarray(p) for p in payloads], axis=0)


def split_arrays(result: Any, sizes: Sequence[int]) -> List[Any]:
    """Default split: slice axis 0 back into the per-request sizes;
    scalars replicate."""
    bounds = np.cumsum([0] + list(sizes))
    def cut(x, i):
        arr = np.asarray(x)
        if arr.ndim == 0:
            return x
        return arr[bounds[i]:bounds[i + 1]]
    if isinstance(result, dict):
        return [{k: cut(v, i) for k, v in result.items()}
                for i in range(len(sizes))]
    return [cut(result, i) for i in range(len(sizes))]


class DynamicBatcher:
    """Coalesce concurrent requests to one function into batched tasks.

    Requests are queued up to ``max_batch`` or ``max_wait`` seconds; each
    flush submits ONE task whose payload is the stacked batch. Downstream
    the whole funcX path (routing, warm containers) sees a single task, so
    per-task overhead is amortized — the §7.5 effect, applied per-request.
    """

    def __init__(
        self,
        submit_fn: Callable[[Any], str],          # payload → task_id
        result_fn: Callable[[str, float], Any],   # task_id → result
        *,
        max_batch: int = 8,
        max_wait: float = 0.01,
        batch_dim_key: Optional[str] = "tokens",
        stack_fn: Callable = stack_arrays,
        split_fn: Callable = split_arrays,
        result_timeout: float = 60.0,
    ):
        self.submit_fn = submit_fn
        self.result_fn = result_fn
        self.max_batch = max_batch
        self.max_wait = max_wait
        self.batch_dim_key = batch_dim_key
        self.stack_fn = stack_fn
        self.split_fn = split_fn
        self.result_timeout = result_timeout
        self._pending: List[Tuple[Any, Future, int]] = []
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="dyn-batcher")
        self._thread.start()
        self.batches_sent = 0
        self.requests_sent = 0

    def _size_of(self, payload: Any) -> int:
        if isinstance(payload, dict) and self.batch_dim_key in payload:
            return int(np.asarray(payload[self.batch_dim_key]).shape[0])
        return 1

    def submit(self, payload: Any) -> Future:
        fut: Future = Future()
        with self._cond:
            self._pending.append((payload, fut, self._size_of(payload)))
            if len(self._pending) >= self.max_batch:
                self._cond.notify()
        return fut

    def close(self) -> None:
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        self._thread.join(timeout=2.0)

    def _loop(self) -> None:
        while not self._stop.is_set():
            with self._cond:
                if not self._pending:
                    self._cond.wait(timeout=self.max_wait)
                    if not self._pending:
                        continue
                # window: let the batch fill up briefly
                deadline = time.perf_counter() + self.max_wait
                while (len(self._pending) < self.max_batch
                       and time.perf_counter() < deadline):
                    self._cond.wait(timeout=max(
                        deadline - time.perf_counter(), 0.0005))
                batch = self._pending[:self.max_batch]
                self._pending = self._pending[self.max_batch:]
            self._flush(batch)

    def _flush(self, batch) -> None:
        payloads = [b[0] for b in batch]
        futures = [b[1] for b in batch]
        sizes = [b[2] for b in batch]
        try:
            stacked = self.stack_fn(payloads) if len(payloads) > 1 \
                else payloads[0]
            task_id = self.submit_fn(stacked)
            self.batches_sent += 1
            self.requests_sent += len(payloads)
            result = self.result_fn(task_id, self.result_timeout)
            parts = (self.split_fn(result, sizes) if len(payloads) > 1
                     else [result])
            for fut, part in zip(futures, parts):
                fut.set_result(part)
        except Exception as e:          # noqa: BLE001 — propagate to callers
            for fut in futures:
                if not fut.done():
                    fut.set_exception(e)
