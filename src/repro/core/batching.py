"""Batching (paper §4.6) + beyond-paper request coalescing.

The paper's two batching forms live elsewhere in the runtime:
  - *internal batching*: ForwarderPool.batch_size + Manager.prefetch (managers
    request many tasks on behalf of their workers);
  - *user-facing batching*: FuncXService.submit_batch / client.batch_run.

This module adds the TPU-serving-native third form: **dynamic request
coalescing** — concurrent invocations of the same function within a small
window are stacked into one batched execution (one compiled program run for
N requests) and the results are fanned back out. This is what turns the
FaaS layer into a batched model server.
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import Future
from typing import Any, Callable, List, Optional, Sequence, Tuple

import numpy as np


def stack_arrays(payloads: Sequence[Any]) -> Any:
    """Default stack: dict-of-arrays payloads are concatenated on axis 0;
    scalar fields (e.g. "n_tokens") must agree and pass through."""
    first = payloads[0]
    if isinstance(first, dict):
        out = {}
        for k in first:
            v0 = np.asarray(first[k])
            if v0.ndim == 0:
                vals = {np.asarray(p[k]).item() for p in payloads}
                if len(vals) != 1:
                    raise ValueError(
                        f"cannot coalesce: scalar field {k!r} differs "
                        f"across requests ({vals})")
                out[k] = first[k]
            else:
                out[k] = np.concatenate([np.asarray(p[k]) for p in payloads],
                                        axis=0)
        return out
    return np.concatenate([np.asarray(p) for p in payloads], axis=0)


def split_arrays(result: Any, sizes: Sequence[int]) -> List[Any]:
    """Default split: slice axis 0 back into the per-request sizes;
    scalars replicate."""
    bounds = np.cumsum([0] + list(sizes))
    def cut(x, i):
        arr = np.asarray(x)
        if arr.ndim == 0:
            return x
        return arr[bounds[i]:bounds[i + 1]]
    if isinstance(result, dict):
        return [{k: cut(v, i) for k, v in result.items()}
                for i in range(len(sizes))]
    return [cut(result, i) for i in range(len(sizes))]


class DynamicBatcher:
    """Coalesce concurrent requests to one function into batched tasks.

    Requests are queued up to ``max_batch`` or ``max_wait`` seconds; each
    flush submits ONE task whose payload is the stacked batch. Downstream
    the whole funcX path (routing, warm containers) sees a single task, so
    per-task overhead is amortized — the §7.5 effect, applied per-request.
    """

    def __init__(
        self,
        submit_fn: Callable[[Any], str],          # payload → task_id
        result_fn: Callable[[str, float], Any],   # task_id → result
        *,
        max_batch: int = 8,
        max_wait: float = 0.01,
        batch_dim_key: Optional[str] = "tokens",
        stack_fn: Callable = stack_arrays,
        split_fn: Callable = split_arrays,
        result_timeout: float = 60.0,
    ):
        self.submit_fn = submit_fn
        self.result_fn = result_fn
        self.max_batch = max_batch
        self.max_wait = max_wait
        self.batch_dim_key = batch_dim_key
        self.stack_fn = stack_fn
        self.split_fn = split_fn
        self.result_timeout = result_timeout
        self._pending: List[Tuple[Any, Future, int]] = []
        self._lock = threading.Lock()
        self._cond = threading.Condition(self._lock)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._loop, daemon=True,
                                        name="dyn-batcher")
        self._thread.start()
        self.batches_sent = 0
        self.requests_sent = 0

    def _size_of(self, payload: Any) -> int:
        if isinstance(payload, dict) and self.batch_dim_key in payload:
            return int(np.asarray(payload[self.batch_dim_key]).shape[0])
        return 1

    def submit(self, payload: Any) -> Future:
        fut: Future = Future()
        with self._cond:
            self._pending.append((payload, fut, self._size_of(payload)))
            if len(self._pending) >= self.max_batch:
                self._cond.notify()
        return fut

    def close(self) -> None:
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        self._thread.join(timeout=2.0)

    def _loop(self) -> None:
        while not self._stop.is_set():
            with self._cond:
                if not self._pending:
                    self._cond.wait(timeout=self.max_wait)
                    if not self._pending:
                        continue
                # window: let the batch fill up briefly
                deadline = time.perf_counter() + self.max_wait
                while (len(self._pending) < self.max_batch
                       and time.perf_counter() < deadline):
                    self._cond.wait(timeout=max(
                        deadline - time.perf_counter(), 0.0005))
                batch = self._pending[:self.max_batch]
                self._pending = self._pending[self.max_batch:]
            self._flush(batch)

    def _flush(self, batch) -> None:
        payloads = [b[0] for b in batch]
        futures = [b[1] for b in batch]
        sizes = [b[2] for b in batch]
        try:
            stacked = self.stack_fn(payloads) if len(payloads) > 1 \
                else payloads[0]
            task_id = self.submit_fn(stacked)
            self.batches_sent += 1
            self.requests_sent += len(payloads)
            result = self.result_fn(task_id, self.result_timeout)
            parts = (self.split_fn(result, sizes) if len(payloads) > 1
                     else [result])
            for fut, part in zip(futures, parts):
                fut.set_result(part)
        except Exception as e:          # noqa: BLE001 — propagate to callers
            for fut in futures:
                if not fut.done():
                    fut.set_exception(e)
