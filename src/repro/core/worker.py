"""Worker (paper §4.3): executes one task at a time, optionally inside a
container — here, against a warm-cached execution environment (compiled
executable). Blocking single-responsibility loop, exactly as in the paper.
"""
from __future__ import annotations

import queue
import threading
import time
import traceback
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional

from ..serialization import PackedBuffer
from .tasks import now
from .warming import ContainerRegistry, WarmCache

_WARMUP = object()        # sentinel inbox item: pre-build a container

# Idle inbox wait. Long enough that an idle worker sleeps instead of
# spinning at 20 Hz; short enough that stop()/kill() and warm-cache reap
# deadlines are honoured promptly.
_IDLE_WAIT = 0.5


@dataclass
class WorkItem:
    task_id: str
    container_type: str
    fn: Callable
    wants_env: bool
    payload: Any
    stamps: Dict[str, float]
    # Warmth key refining the container type (DESIGN.md §10): names a
    # function-held artifact (e.g. a jit cache entry) this execution
    # creates/reuses; the worker advertises it warm after the run.
    warmth_key: str = ""


@dataclass
class WorkResult:
    task_id: str
    status: str                   # "SUCCESS" | "FAILED"
    result: Any = None
    error: Optional[str] = None
    remote_traceback: str = ""
    stamps: Dict[str, float] = None
    cold_start: bool = False
    build_time: float = 0.0
    worker_id: str = ""


class Worker(threading.Thread):
    def __init__(self, worker_id: str, registry: ContainerRegistry,
                 result_cb: Callable[[WorkResult], None],
                 cache_slots: int = 1,
                 idle_timeout: Optional[float] = None,
                 slowdown: float = 0.0):
        super().__init__(daemon=True, name=f"worker-{worker_id}")
        self.worker_id = worker_id
        self.cache = WarmCache(registry, slots=cache_slots,
                               idle_timeout=idle_timeout)
        self.result_cb = result_cb
        self.inbox: "queue.Queue" = queue.Queue(maxsize=4)
        self.busy = threading.Event()
        self.slowdown = slowdown          # straggler injection (tests)
        self.target_type: Optional[str] = None   # manager's proportional plan
        self.tasks_done = 0
        # idle/busy transition hook — the owning Manager dirties its
        # incrementally-maintained info() counters here instead of
        # re-scanning every worker per advertisement tick
        self.on_state_change: Optional[Callable[[], None]] = None
        self._stop = threading.Event()
        self._killed = False

    def _notify(self) -> None:
        cb = self.on_state_change
        if cb is not None:
            cb()

    # -- control ---------------------------------------------------------------
    def submit(self, item: WorkItem) -> None:
        self.busy.set()
        self.inbox.put(item)
        self._notify()

    def prewarm(self, container_type: str) -> None:
        self.inbox.put((_WARMUP, container_type))
        self._notify()

    def stop(self) -> None:
        self._stop.set()

    def kill(self) -> None:
        """Simulated node failure: stop without draining or reporting."""
        self._killed = True
        self._stop.set()

    @property
    def idle(self) -> bool:
        return not self.busy.is_set() and self.inbox.empty()

    def warm_types(self):
        return self.cache.warm_types()

    def _idle_wait(self) -> float:
        """How long the loop may block on the inbox: until the next warm
        container hits its idle timeout (so reaping happens on a deadline,
        not on every 20 Hz wakeup), capped at ``_IDLE_WAIT``."""
        deadline = self.cache.next_reap_deadline()
        if deadline is None:
            return _IDLE_WAIT
        return min(max(deadline - time.perf_counter(), 0.005), _IDLE_WAIT)

    # -- loop --------------------------------------------------------------------
    def run(self) -> None:
        while not self._stop.is_set():
            try:
                item = self.inbox.get(timeout=self._idle_wait())
            except queue.Empty:
                if not self.inbox.empty():
                    continue
                if self.busy.is_set():
                    self.busy.clear()
                    self._notify()
                deadline = self.cache.next_reap_deadline()
                if deadline is not None and time.perf_counter() >= deadline:
                    self.cache.reap()
                continue
            if self._killed:
                return
            if isinstance(item, tuple) and item[0] is _WARMUP:
                self.cache.get_or_build(item[1])
                if self.inbox.empty():
                    self.busy.clear()
                    self._notify()
                continue
            self._execute(item)
            if self.inbox.empty():
                self.busy.clear()
                self._notify()

    def _execute(self, item: WorkItem) -> None:
        stamps = dict(item.stamps)
        container, cold = self.cache.get_or_build(item.container_type)
        stamps["worker_start"] = now()
        try:
            if self.slowdown:
                time.sleep(self.slowdown)
            # Lazy unpack (DESIGN.md §5): the payload crossed every hop as
            # an opaque frame; this is its single decode, at the consumer,
            # just before the call. The buffer caches the decoded object,
            # so a speculative or requeued re-delivery costs no re-decode.
            payload = item.payload
            if isinstance(payload, PackedBuffer):
                payload = payload.unpack()
            if item.wants_env:
                result = item.fn(payload, container.env)
            else:
                result = item.fn(payload)
            status, error, tb = "SUCCESS", None, ""
        except Exception as e:              # noqa: BLE001 — remote fault
            result = None
            status = "FAILED"
            error = f"{type(e).__name__}: {e}"
            tb = traceback.format_exc()
        stamps["worker_end"] = now()
        if (status == "SUCCESS" and item.warmth_key
                and item.warmth_key != item.container_type):
            # the function-held artifact (jit cache entry, ...) now lives
            # in this worker's process: advertise it like a warm container
            self.cache.note_warm(item.warmth_key)
        self.tasks_done += 1
        if self._killed:
            return                           # result lost with the node
        self.result_cb(WorkResult(
            task_id=item.task_id, status=status, result=result, error=error,
            remote_traceback=tb, stamps=stamps, cold_start=cold,
            build_time=container.build_time if cold else 0.0,
            worker_id=self.worker_id))
