"""Elastic resource provisioning (paper §4.4, §6.3).

``Provider`` is the Parsl-provider-interface analogue: a uniform way to
acquire/release nodes (managers) from a local pool, a batch scheduler, or a
cloud — with realistic acquisition delays simulated for the latter two.

``ElasticStrategy`` is the monitoring+scaling component: provision more
nodes when the queued backlog outgrows what the current blocks can
absorb, release nodes idle past the timeout, bounded by
[min_blocks, max_blocks] and an aggressiveness knob — the paper's
strategy interface. Two properties matter at interchange scale
(DESIGN.md §11):

- scaling reads *queued backlog depth* (``endpoint.pending_tasks()``),
  not just the instantaneous pending-vs-idle comparison, so a deep
  absorbed burst provisions the whole shortfall in one decision
  (``backlog_per_block`` tasks per additional block);
- ``Provider.start_block``'s blocking acquisition sleep (slurm queue
  wait, cloud boot) runs on a background acquirer thread, never inside
  the strategy loop — a slow acquisition cannot stall scale-in
  decisions or delay the next observation tick. In-flight acquisitions
  are counted (``pending_blocks``) so the loop doesn't re-order what is
  already on the way.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Dict


class Provider:
    """Acquire/release manager nodes for an endpoint."""

    name = "abstract"

    def __init__(self, nodes_per_block: int = 1, workers_per_node: int = 4):
        self.nodes_per_block = nodes_per_block
        self.workers_per_node = workers_per_node

    def acquisition_delay(self) -> float:
        return 0.0

    def start_block(self, endpoint) -> list:
        """Returns the list of manager ids started (blocking; may sleep for
        the scheduler/cloud delay)."""
        delay = self.acquisition_delay()
        if delay > 0:
            time.sleep(delay)
        ids = []
        for _ in range(self.nodes_per_block):
            m = endpoint.add_manager(n_workers=self.workers_per_node)
            ids.append(m.manager_id)
        return ids

    def stop_block(self, endpoint, manager_ids: list) -> None:
        for mid in manager_ids:
            endpoint.remove_manager(mid)


class LocalProvider(Provider):
    name = "local"


class SimSlurmProvider(Provider):
    """Batch-scheduler queue wait: lognormal-ish delay around ``mean_wait``."""

    name = "slurm"

    def __init__(self, mean_wait: float = 0.2, jitter: float = 0.5,
                 seed: int = 0, **kw):
        super().__init__(**kw)
        self.mean_wait = mean_wait
        self.jitter = jitter
        self._rng = random.Random(seed)

    def acquisition_delay(self) -> float:
        return self.mean_wait * (1.0 + self.jitter * self._rng.random())


class SimCloudProvider(Provider):
    """Cloud instance boot delay (fixed-ish)."""

    name = "cloud"

    def __init__(self, boot_delay: float = 0.1, **kw):
        super().__init__(**kw)
        self.boot_delay = boot_delay

    def acquisition_delay(self) -> float:
        return self.boot_delay


class ElasticStrategy(threading.Thread):
    """Monitor + scale loop (paper §6.3).

    - scale OUT toward the block count the *queued backlog depth* asks
      for: with ``backlog_per_block`` set, ``ceil(pending /
      backlog_per_block)`` blocks (one decision provisions the whole
      shortfall of a deep absorbed burst); otherwise one extra block
      whenever pending > idle × aggressiveness. Bounded by max_blocks.
    - scale IN a block whose managers have all been idle > idle_timeout
      (down to min_blocks; paper default 2 min, configurable).

    Acquisitions run on background acquirer threads: the provider's
    blocking queue-wait/boot sleep never executes inside this loop, so
    scale-in keeps being evaluated while a slow block is on the way.
    """

    def __init__(self, endpoint, provider: Provider, *,
                 min_blocks: int = 1, max_blocks: int = 4,
                 aggressiveness: float = 1.0, idle_timeout: float = 2.0,
                 interval: float = 0.05, backlog_per_block: int = 0):
        super().__init__(daemon=True, name=f"strategy-{endpoint.endpoint_id}")
        self.endpoint = endpoint
        self.provider = provider
        self.min_blocks = min_blocks
        self.max_blocks = max_blocks
        self.aggressiveness = aggressiveness
        self.idle_timeout = idle_timeout
        self.interval = interval
        self.backlog_per_block = backlog_per_block
        self._blocks: Dict[str, list] = {}
        self._idle_since: Dict[str, float] = {}
        self._lock = threading.Lock()
        self._pending_blocks = 0
        self._stop = threading.Event()
        self.scale_out_events = 0
        self.scale_in_events = 0

    def blocks(self) -> int:
        with self._lock:
            return len(self._blocks)

    def pending_blocks(self) -> int:
        """Acquisitions launched but not yet landed (provider still in
        its queue-wait/boot sleep)."""
        with self._lock:
            return self._pending_blocks

    def stop(self) -> None:
        self._stop.set()

    # ------------------------------------------------------------- scale out
    def _desired_blocks(self, pending: int, idle: int, have: int) -> int:
        if self.backlog_per_block > 0:
            want = -(-pending // self.backlog_per_block)       # ceil
        else:
            want = have + (1 if pending > idle * self.aggressiveness
                           else 0)
        return max(self.min_blocks, min(self.max_blocks, want))

    def _launch_block(self) -> None:
        """Start one block acquisition off-loop. The pending count is
        bumped before the thread starts so the next tick's desired-vs-have
        comparison already sees it."""
        with self._lock:
            self._pending_blocks += 1
            self.scale_out_events += 1

        def acquire() -> None:
            try:
                ids = self.provider.start_block(self.endpoint)
                with self._lock:
                    self._blocks[f"block-{time.monotonic():.6f}"] = ids
            except Exception:
                pass
            finally:
                with self._lock:
                    self._pending_blocks -= 1

        threading.Thread(target=acquire, daemon=True,
                         name=f"acquire-{self.endpoint.endpoint_id}").start()

    def _ensure_min(self) -> None:
        with self._lock:
            have = len(self._blocks) + self._pending_blocks
        for _ in range(self.min_blocks - have):
            ids = self.provider.start_block(self.endpoint)
            with self._lock:
                self._blocks[
                    f"block{len(self._blocks)}-{time.monotonic():.3f}"] = ids

    # ------------------------------------------------------------------ loop
    def run(self) -> None:
        self._ensure_min()
        while not self._stop.is_set():
            time.sleep(self.interval)
            try:
                pending = self.endpoint.pending_tasks()
                idle = self.endpoint.idle_workers()
            except Exception:
                continue
            with self._lock:
                have = len(self._blocks) + self._pending_blocks
            want = self._desired_blocks(pending, idle, have)
            if want > have:
                for _ in range(want - have):
                    self._launch_block()
                continue
            # scale in: find a block fully idle past the timeout. Runs
            # every tick — even while acquisitions are sleeping in their
            # background threads.
            with self._lock:
                n_blocks = len(self._blocks)
                items = list(self._blocks.items())
            if n_blocks > self.min_blocks and pending == 0:
                now = time.monotonic()
                for bid, ids in items:
                    if self.endpoint.block_idle(ids):
                        since = self._idle_since.setdefault(bid, now)
                        if now - since > self.idle_timeout:
                            self.provider.stop_block(self.endpoint, ids)
                            with self._lock:
                                self._blocks.pop(bid, None)
                            self._idle_since.pop(bid, None)
                            self.scale_in_events += 1
                            break
                    else:
                        self._idle_since.pop(bid, None)
