"""Elastic resource provisioning (paper §4.4, §6.3).

``Provider`` is the Parsl-provider-interface analogue: a uniform way to
acquire/release nodes (managers) from a local pool, a batch scheduler, or a
cloud — with realistic acquisition delays simulated for the latter two.

``ElasticStrategy`` is the monitoring+scaling component: provision more
nodes when pending work exceeds idle capacity, release nodes idle past the
timeout, bounded by [min_blocks, max_blocks] and an aggressiveness knob —
exactly the paper's strategy interface.
"""
from __future__ import annotations

import random
import threading
import time
from typing import Dict


class Provider:
    """Acquire/release manager nodes for an endpoint."""

    name = "abstract"

    def __init__(self, nodes_per_block: int = 1, workers_per_node: int = 4):
        self.nodes_per_block = nodes_per_block
        self.workers_per_node = workers_per_node

    def acquisition_delay(self) -> float:
        return 0.0

    def start_block(self, endpoint) -> list:
        """Returns the list of manager ids started (blocking; may sleep for
        the scheduler/cloud delay)."""
        delay = self.acquisition_delay()
        if delay > 0:
            time.sleep(delay)
        ids = []
        for _ in range(self.nodes_per_block):
            m = endpoint.add_manager(n_workers=self.workers_per_node)
            ids.append(m.manager_id)
        return ids

    def stop_block(self, endpoint, manager_ids: list) -> None:
        for mid in manager_ids:
            endpoint.remove_manager(mid)


class LocalProvider(Provider):
    name = "local"


class SimSlurmProvider(Provider):
    """Batch-scheduler queue wait: lognormal-ish delay around ``mean_wait``."""

    name = "slurm"

    def __init__(self, mean_wait: float = 0.2, jitter: float = 0.5,
                 seed: int = 0, **kw):
        super().__init__(**kw)
        self.mean_wait = mean_wait
        self.jitter = jitter
        self._rng = random.Random(seed)

    def acquisition_delay(self) -> float:
        return self.mean_wait * (1.0 + self.jitter * self._rng.random())


class SimCloudProvider(Provider):
    """Cloud instance boot delay (fixed-ish)."""

    name = "cloud"

    def __init__(self, boot_delay: float = 0.1, **kw):
        super().__init__(**kw)
        self.boot_delay = boot_delay

    def acquisition_delay(self) -> float:
        return self.boot_delay


class ElasticStrategy(threading.Thread):
    """Monitor + scale loop (paper §6.3).

    - scale OUT when pending > idle × aggressiveness (up to max_blocks);
    - scale IN a block whose managers have all been idle > idle_timeout
      (down to min_blocks; paper default 2 min, configurable).
    """

    def __init__(self, endpoint, provider: Provider, *,
                 min_blocks: int = 1, max_blocks: int = 4,
                 aggressiveness: float = 1.0, idle_timeout: float = 2.0,
                 interval: float = 0.05):
        super().__init__(daemon=True, name=f"strategy-{endpoint.endpoint_id}")
        self.endpoint = endpoint
        self.provider = provider
        self.min_blocks = min_blocks
        self.max_blocks = max_blocks
        self.aggressiveness = aggressiveness
        self.idle_timeout = idle_timeout
        self.interval = interval
        self._blocks: Dict[str, list] = {}
        self._idle_since: Dict[str, float] = {}
        self._stop = threading.Event()
        self.scale_out_events = 0
        self.scale_in_events = 0

    def blocks(self) -> int:
        return len(self._blocks)

    def stop(self) -> None:
        self._stop.set()

    def _ensure_min(self) -> None:
        while len(self._blocks) < self.min_blocks:
            ids = self.provider.start_block(self.endpoint)
            self._blocks[f"block{len(self._blocks)}-{time.monotonic():.3f}"] = ids

    def run(self) -> None:
        self._ensure_min()
        while not self._stop.is_set():
            time.sleep(self.interval)
            try:
                pending = self.endpoint.pending_tasks()
                idle = self.endpoint.idle_workers()
            except Exception:
                continue
            # scale out
            if pending > idle * self.aggressiveness and \
                    len(self._blocks) < self.max_blocks:
                ids = self.provider.start_block(self.endpoint)
                self._blocks[f"block-{time.monotonic():.6f}"] = ids
                self.scale_out_events += 1
                continue
            # scale in: find a block fully idle past the timeout
            if len(self._blocks) > self.min_blocks and pending == 0:
                now = time.monotonic()
                for bid, ids in list(self._blocks.items()):
                    if self.endpoint.block_idle(ids):
                        since = self._idle_since.setdefault(bid, now)
                        if now - since > self.idle_timeout:
                            self.provider.stop_block(self.endpoint, ids)
                            del self._blocks[bid]
                            self._idle_since.pop(bid, None)
                            self.scale_in_events += 1
                            break
                    else:
                        self._idle_since.pop(bid, None)
