"""The funcX endpoint agent (paper §4.3).

Deployed "on" a compute resource (here: hosting a set of manager/worker
threads and, for model-serving functions, a device mesh). Responsibilities,
mirroring the paper:

- registers with the service; receives tasks from its forwarder channel and
  acks receipt (hierarchical queuing: tasks are cached at each layer until
  the next layer acknowledges);
- routes tasks to managers via a pluggable, warming-aware router (§6.2);
- collects results and returns them to the forwarder;
- heartbeats to the forwarder pool, advertising queue depth and
  warm-container state (the service's federation-level router feeds on
  these); detects *lost managers* via their heartbeats and re-executes
  their in-flight tasks (§4.3 fault tolerance);
- optional speculative re-execution of stragglers (beyond paper);
- optional elastic provisioning strategy (§6.3).

Deployment modes (DESIGN.md §2): the agent is transport-agnostic. In the
same-process mode it shares a ``Channel`` (LocalTransport) with the
service; in the federated mode this module doubles as the **endpoint-agent
entrypoint** —

    python -m repro.core.endpoint --connect HOST:PORT --token @token.json

— dialing the service's TCP listener, registering over the wire
(``Register``/``RegisterAck`` handshake), fetching function bodies on
demand (``FnRequest``/``FnResponse``), and surviving service restarts by
re-dialing + re-registering under the same endpoint id (the service then
requeues whatever was in flight).
"""
from __future__ import annotations

import argparse
import collections
import itertools
import pickle
import socket as _socket
import threading
import time
from time import monotonic as _monotonic
from typing import Any, Callable, Deque, Dict, List, Optional, Tuple

from ..data import (
    KVStore,
    SERVICE_PAYLOAD_LIMIT,
    TransferService,
    resolve_inputs,
    stage_outputs,
)
from ..serialization import PackedBuffer, SerializationError, pack_buffer
from .comms import (
    Channel,
    ShmRing,
    ShmTransport,
    TcpTransport,
    parse_hostport,
)
from .errors import RegistrationError
from .manager import Manager
from .protocol import (
    Ack,
    FnRequest,
    FnResponse,
    Heartbeat,
    PeerData,
    PeerGet,
    ProtocolError,
    Register,
    RegisterAck,
    ResolvePeerAck,
    ResultBatch,
    ResultMsg,
    ShmAttach,
    TaskBatch,
    TaskSpec,
    from_wire,
    to_wire,
    to_wire_parts,
)
from .routing import Router, RoutingContext, WarmthView, make_router
from .tasks import now
from .warming import ContainerRegistry
from .worker import WorkItem, WorkResult


class _BoundedSet:
    """Generation-bounded membership set — the duplicate-drop record for
    shipped results. A long-running agent used to grow ``_completed``
    forever; recency is all dedup needs (a duplicate arrives within a
    requeue/speculation window, not a million tasks later), so entries
    age out by generation rotation: adds go to the current generation,
    membership checks both, and when the current one reaches ``cap/2``
    it becomes the previous (dropping the old previous). The retention
    window is therefore between cap/2 and cap recent ids.

    The hot path is lock-free: dict reads and ``setdefault`` are atomic
    under the GIL, and the insert *is* the membership test (two managers
    completing the same speculated task race on one ``setdefault``; the
    loser sees the winner's token). A lock exists only to serialize the
    rare rotation."""

    __slots__ = ("cap", "_cur", "_prev", "_rotate_lock")

    def __init__(self, cap: int):
        self.cap = max(cap, 2)
        self._cur: Dict[str, object] = {}
        self._prev: Dict[str, object] = {}
        self._rotate_lock = threading.Lock()

    def add(self, key: str) -> bool:
        """True if newly added, False if already present."""
        if key in self._prev:
            return False
        token = object()
        if self._cur.setdefault(key, token) is not token:
            return False                   # lost the race / already there
        # re-check prev: a rotation between our prev-read and the
        # setdefault can move a racing winner's entry into _prev while
        # our insert lands in the fresh _cur — token identity tells our
        # own rotated entry apart from a true duplicate
        pv = self._prev.get(key)
        if pv is not None and pv is not token:
            return False
        if len(self._cur) > self.cap // 2 and \
                self._rotate_lock.acquire(blocking=False):
            try:
                if len(self._cur) > self.cap // 2:
                    self._prev = self._cur
                    self._cur = {}
            finally:
                self._rotate_lock.release()
        return True

    def __contains__(self, key: str) -> bool:
        return key in self._cur or key in self._prev

    def __len__(self) -> int:
        return len(self._cur) + len(self._prev)


class ResultCoalescer:
    """Adaptive micro-batching for the return path (DESIGN.md §6).

    Two regimes, chosen per completion:

    - **idle line** — the lone result's own thread flushes immediately
      (no handoff, no linger, no timer): single-task latency is
      untouched;
    - **loaded line** (more results outstanding upstream) — the producer
      just appends and a dedicated flusher thread drains everything
      pending into :class:`ResultBatch` envelopes of at most
      ``batch_size`` results, holding an under-full envelope open for a
      bounded *linger* so it fills toward ``batch_size``. Producers —
      worker callbacks and the agent recv loop — are never blocked by
      pack/send/linger work, so result shipping cannot stall task intake
      or execution; envelopes-per-task drops toward 1/batch_size.

    Receipt ``Ack``s coalesce the same way: they ride whatever envelope
    flushes next (an ack-only envelope never lingers — receipt stamps are
    carried data, so coalescing costs nothing, but delivery shouldn't
    idle-wait on a result that may be seconds away).

    Envelopes the channel refuses are parked in ``_unsent`` *as built*
    and retransmitted batch-wise by :meth:`flush_unsent` (heartbeat loop)
    once the link returns — the service drops per-member duplicates by
    task id, so a retransmitted batch racing a requeued re-execution
    stays exactly-once.
    """

    def __init__(self, send: Callable[[dict, list], bool], *,
                 batch_size: int = 32, linger: float = 0.002,
                 outstanding: Optional[Callable[[], int]] = None):
        self._send = send
        self.batch_size = batch_size
        self.linger = linger
        self._outstanding = outstanding if outstanding is not None \
            else (lambda: 0)
        # Producer path is lock-free: deque.append is atomic under the
        # GIL, and the kick Event is touched only through an `is_set()`
        # fast-path read. An earlier design funneled every completion
        # through one condition variable — with dozens of worker threads
        # on a small core count, stack samples showed the whole fleet
        # convoying on that lock while throughput collapsed.
        self._results: Deque[ResultMsg] = collections.deque()
        self._acks: Deque[Ack] = collections.deque()
        self._kick = threading.Event()     # "pending work" signal
        self._flush_lock = threading.Lock()    # one drainer at a time
        self._unsent: Deque[Tuple[dict, list]] = collections.deque()
        self._stop = threading.Event()
        # gauges (result-plane acceptance: envelopes-per-task < 1 under load)
        self.envelopes_sent = 0            # envelopes the channel accepted
        self.result_envelopes = 0          # ...of which carried ≥1 result
        self.results_sent = 0
        self.acks_sent = 0
        self.envelopes_parked = 0          # refused by the link, queued for
        #                                    retransmission
        self._thread = threading.Thread(target=self._flush_loop, daemon=True,
                                        name="result-coalescer")
        self._thread.start()

    def close(self) -> None:
        """Stop the flusher, then drain whatever is pending — every
        completed result is sent or parked, never silently dropped (the
        pre-coalescer path sent synchronously and had no stop window)."""
        self._stop.set()
        self._kick.set()
        with self._flush_lock:
            self._drain()

    # -- producers ---------------------------------------------------------
    def add_result(self, msg: ResultMsg) -> None:
        self._results.append(msg)
        if self._stop.is_set():
            # flusher is gone (agent stopping, workers still completing):
            # drain synchronously — blocking acquire, because falling back
            # to a kick nobody listens to would drop this result
            with self._flush_lock:
                self._drain()
            return
        if self._outstanding() <= 0:
            # idle line (or the tail of a load wave): ship on this thread
            # right now — no handoff, no linger. If the flusher happens to
            # hold the lock it is actively draining and will recheck; the
            # kick covers the race window.
            if self._flush_lock.acquire(blocking=False):
                try:
                    self._drain()
                finally:
                    self._flush_lock.release()
            else:
                self._kick.set()
            return
        if not self._kick.is_set():        # lock-free in steady state —
            self._kick.set()               # under load the kick stays set

    def add_ack(self, ack: Ack) -> None:
        """Acks never flush inline — the recv loop must get back to task
        intake; they ride the flusher's next envelope."""
        self._acks.append(ack)
        if not self._kick.is_set():
            self._kick.set()

    # -- the flusher -------------------------------------------------------
    def _flush_loop(self) -> None:
        while not self._stop.is_set():
            if not self._results and not self._acks:
                self._kick.wait(0.05)
                self._kick.clear()
                continue
            if (self.linger > 0 and self._results
                    and len(self._results) < self.batch_size
                    and self._outstanding() > 0):
                # under-full envelope with more results on the way: let it
                # fill. A plain bounded sleep — the tail never waits on it
                # because the last completion (outstanding == 0) flushes
                # inline on its own thread while we sleep outside the lock.
                self._stop.wait(self.linger)
            with self._flush_lock:
                self._drain(max_envelopes=1)

    def _drain(self, max_envelopes: Optional[int] = None) -> None:
        """Pop pending results/acks into envelopes and ship. Caller holds
        ``_flush_lock`` (single consumer); producers may append
        concurrently and anything landing after the final empty check is
        picked up by the flusher's next pass (kick/backstop)."""
        n_env = 0
        while True:
            n = min(len(self._results), self.batch_size)
            results = [self._results.popleft() for _ in range(n)]
            acks = []
            while self._acks:
                acks.append(self._acks.popleft())
            if not results and not acks:
                return
            # scatter-gather: large packed results ride behind the
            # envelope as borrowed segments — no memcpy into it (§7)
            env, segs = to_wire_parts(ResultBatch(results=results,
                                                  acks=acks))
            if self._send(env, segs):
                self.envelopes_sent += 1
                self.result_envelopes += 1 if results else 0
                self.results_sent += len(results)
                self.acks_sent += len(acks)
            else:
                self._unsent.append((env, segs))
                self.envelopes_parked += 1
            n_env += 1
            if max_envelopes is not None and n_env >= max_envelopes:
                return

    # -- retransmission (single consumer: the heartbeat loop) --------------
    def flush_unsent(self) -> None:
        """Retransmit parked envelopes in completion order until the link
        refuses again. Runs under ``_flush_lock`` so the gauge counters
        never race a concurrent drain (they feed the acceptance metrics;
        this path is cold)."""
        if not self._unsent:
            return
        with self._flush_lock:
            while self._unsent:
                env, segs = self._unsent[0]
                if not self._send(env, segs):
                    return
                self._unsent.popleft()
                self.envelopes_sent += 1
                n = len(env.get("results", ()))
                self.result_envelopes += 1 if n else 0
                self.results_sent += n
                self.acks_sent += len(env.get("acks", ()))

    @property
    def unsent_count(self) -> int:
        return len(self._unsent)


class EndpointAgent:
    def __init__(
        self,
        endpoint_id: str,
        channel: Channel,
        fetch_function: Callable[[str], Tuple[Callable, bool]],
        *,
        registry: Optional[ContainerRegistry] = None,
        router: str | Router = "warming_aware",
        store: Optional[KVStore] = None,
        transfer: Optional[TransferService] = None,
        heartbeat_interval: float = 0.05,
        manager_timeout: float = 1.0,
        max_retries: int = 2,
        speculation: bool = False,
        speculation_factor: float = 4.0,
        speculation_min: float = 0.25,
        stage_results: bool = True,
        stage_limit: int = SERVICE_PAYLOAD_LIMIT,
        extra_handler: Optional[Callable[[Any], None]] = None,
        result_batch: int = 32,
        result_linger: float = 0.002,
        dedup_capacity: int = 16384,
        dispatched_ttl: float = 900.0,
        peer_server: Optional[Any] = None,
        peer_client: Optional[Any] = None,
    ):
        self.endpoint_id = endpoint_id
        self.channel = channel
        self.fetch_function = fetch_function
        self.registry = registry or ContainerRegistry()
        self.router = (router if isinstance(router, Router)
                       else make_router(router))
        self.store = store
        self.transfer = transfer
        self.heartbeat_interval = heartbeat_interval
        self.manager_timeout = manager_timeout
        self.max_retries = max_retries
        self.speculation = speculation
        self.speculation_factor = speculation_factor
        self.speculation_min = speculation_min
        self.stage_results = stage_results
        # Stage-out threshold: results whose packed size exceeds it are
        # parked in the local store and travel as DataRefs. Defaults to
        # the paper's 10 MB service limit; shuffle-style workloads (and
        # the p2p benchmarks) lower it so intermediates become refs and
        # cross endpoint-to-endpoint instead of transiting the hub.
        self.stage_limit = stage_limit
        # Non-task wire messages (FnResponse, RegisterAck on a re-dial)
        # are routed here — the remote runner's hook into the recv loop.
        self.extra_handler = extra_handler
        # Peer data plane (DESIGN.md §9): the server answers other
        # endpoints' direct fetches; the client resolves cross-endpoint
        # DataRefs at stage-in. Its signaling (ResolvePeer/HubFetch) rides
        # this agent's hub channel.
        self.peer_server = peer_server
        self.peer_client = peer_client
        if peer_client is not None and peer_client.signal is None:
            peer_client.signal = self._send_signal

        self.managers: Dict[str, Manager] = {}
        self._managers_lock = threading.RLock()
        self._mgr_counter = itertools.count()

        self._queue: "collections.deque" = collections.deque()
        self._queue_lock = threading.Lock()
        self._queue_cond = threading.Condition(self._queue_lock)
        self._dispatch_parked = False      # dispatch waiting for free room

        self._fn_cache: Dict[str, Tuple[Callable, bool]] = {}
        self._retries: Dict[str, int] = {}
        # Duplicate-drop record, LRU-bounded (a long-running agent must
        # not grow per-task state forever; recency is all dedup needs).
        self._completed = _BoundedSet(dedup_capacity)
        self._dispatched_at: Dict[str, Tuple[float, TaskSpec, str]] = {}
        self.dispatched_ttl = dispatched_ttl
        self._next_sweep = _monotonic() + 5.0
        self._durations: collections.deque = collections.deque(maxlen=256)
        # Batched return path (DESIGN.md §6): results and receipt acks
        # coalesce into ResultBatch envelopes; envelopes the link refuses
        # are parked inside the coalescer and retransmitted by the
        # heartbeat loop once the link is back. Without that parking, a
        # result produced during an outage would be lost forever — the
        # task is already in _completed, so re-execution after the
        # requeue-on-disconnect would be dropped as a duplicate.
        self.coalescer = ResultCoalescer(
            self._ship_envelope, batch_size=result_batch,
            linger=result_linger, outstanding=self._outstanding)

        # Heartbeat merge cache: the 20 Hz loop re-merges the per-manager
        # warm/load dicts only when some manager's state version moved —
        # an idle or steady fleet costs one tuple compare per beat, not a
        # full Manager.info() scan + dict merge.
        self._hb_key: Optional[tuple] = None
        self._hb_state: Tuple[int, int, int, Dict[str, int], Dict[str, int]] \
            = (0, 0, 0, {}, {})
        # Per-warmth-key cold-build cost EWMA, fed by completed results
        # and advertised on the next heartbeat (Heartbeat.build_costs) —
        # the service's cost-aware federation router learns actual build
        # costs instead of guessing (DESIGN.md §10).
        self._build_costs: Dict[str, float] = {}
        self._build_costs_lock = threading.Lock()

        self._stop = threading.Event()
        self._threads: List[threading.Thread] = []
        self.strategy = None
        # metrics
        self.tasks_received = 0
        self.tasks_completed = 0
        self.tasks_reexecuted = 0
        self.speculative_dispatches = 0

    # ------------------------------------------------------------------ control
    def start(self) -> None:
        for name, fn in [("recv", self._recv_loop),
                         ("dispatch", self._dispatch_loop),
                         ("heartbeat", self._heartbeat_loop),
                         ("monitor", self._monitor_loop)]:
            t = threading.Thread(target=fn, daemon=True,
                                 name=f"ep-{self.endpoint_id}-{name}")
            t.start()
            self._threads.append(t)

    def stop(self) -> None:
        self._stop.set()
        self.coalescer.close()
        if self.strategy is not None:
            self.strategy.stop()
        if self.peer_server is not None:
            self.peer_server.close()
        if self.peer_client is not None:
            self.peer_client.close()
        with self._managers_lock:
            for m in self.managers.values():
                m.stop()
        with self._queue_cond:
            self._queue_cond.notify_all()

    # ---------------------------------------------------------------- managers
    def add_manager(self, n_workers: int = 4, **kw) -> Manager:
        mid = f"{self.endpoint_id}/m{next(self._mgr_counter)}"
        m = Manager(mid, n_workers, self.registry, self._on_result, **kw)
        m.start()
        with self._managers_lock:
            self.managers[mid] = m
        return m

    def remove_manager(self, manager_id: str) -> None:
        with self._managers_lock:
            m = self.managers.pop(manager_id, None)
        if m is not None:
            m.stop()

    def kill_manager(self, manager_id: str) -> None:
        """Test hook: simulated node failure."""
        with self._managers_lock:
            m = self.managers.get(manager_id)
        if m is not None:
            m.kill()

    def _alive_managers(self) -> List[Manager]:
        with self._managers_lock:
            return [m for m in self.managers.values() if m.alive]

    # ------------------------------------------------------------------ metrics
    def pending_tasks(self) -> int:
        with self._queue_lock:
            q = len(self._queue)
        return q + sum(m.inbox.qsize() for m in self._alive_managers())

    def idle_workers(self) -> int:
        return sum(m.info().idle_workers for m in self._alive_managers())

    def block_idle(self, manager_ids: List[str]) -> bool:
        with self._managers_lock:
            ms = [self.managers.get(i) for i in manager_ids]
        return all(m is not None and m.alive and
                   m.info().idle_workers == len(m.workers) and
                   m.inbox.qsize() == 0 for m in ms)

    # ------------------------------------------------------------------- loops
    def _recv_loop(self) -> None:
        while not self._stop.is_set():
            wire = self.channel.recv_at_endpoint(timeout=0.05)
            if wire is None:
                continue
            env, _tag = wire
            try:
                msg = from_wire(env)
            except (ProtocolError, SerializationError):
                continue           # poison message: drop, keep the loop
            if isinstance(msg, TaskBatch):
                t_recv = now()
                for spec in msg.tasks:
                    spec.stamps["endpoint_recv"] = t_recv
                self._enqueue_batch(msg.tasks)
                # receipt ack rides the next result envelope (or its own
                # immediately if none is in flight) — coalesced return path
                self.coalescer.add_ack(
                    Ack(task_ids=[s.task_id for s in msg.tasks],
                        t_endpoint_recv=t_recv))
            elif isinstance(msg, PeerGet):
                # hub-relay serving: the service pulls a key from our
                # store over the already-authenticated hub channel
                self._serve_hub_get(msg)
            elif (isinstance(msg, (ResolvePeerAck, PeerData))
                  and self.peer_client is not None
                  and self.peer_client.handle_signal(msg)):
                pass                   # matched a waiting peer fetch
            elif self.extra_handler is not None:
                try:
                    self.extra_handler(msg)
                except Exception:
                    pass               # a bad handler never kills recv

    def _send_signal(self, msg: Any) -> bool:
        """PeerClient's signaling sender: one message to the service."""
        return self.channel.send_to_service(to_wire(msg), tag="peer")

    def _serve_hub_get(self, msg: PeerGet) -> None:
        """Answer a relayed fetch (rung 3 of the fallback ladder): no
        token check — the hub channel authenticated at Register."""
        if self.store is None:
            reply = PeerData(req_id=msg.req_id, key=msg.key, ok=False,
                             error="endpoint has no store")
        else:
            try:
                data = self.store.get_raw(msg.key)
                reply = PeerData(req_id=msg.req_id, key=msg.key, ok=True,
                                 data=data)
            except KeyError:
                reply = PeerData(req_id=msg.req_id, key=msg.key, ok=False,
                                 error=f"no such key: {msg.key}")
            except Exception as e:     # noqa: BLE001 — report, serve on
                reply = PeerData(req_id=msg.req_id, key=msg.key, ok=False,
                                 error=f"{type(e).__name__}: {e}")
        env, segs = to_wire_parts(reply)
        self.channel.send_parts_to_service(env, segs, tag="peer")

    def _enqueue(self, spec: TaskSpec, front: bool = False) -> None:
        self.tasks_received += 1
        with self._queue_cond:
            if front:
                self._queue.appendleft(spec)
            else:
                self._queue.append(spec)
            self._queue_cond.notify()

    def _enqueue_batch(self, specs: List[TaskSpec]) -> None:
        """One queue-lock acquisition per received TaskBatch — the recv
        loop used to take it once per member spec, contending with the
        dispatch loop 32× per envelope."""
        self.tasks_received += len(specs)
        with self._queue_cond:
            self._queue.extend(specs)
            self._queue_cond.notify()

    def _resolve_fn(self, function_id: str) -> Tuple[Callable, bool]:
        if function_id not in self._fn_cache:
            self._fn_cache[function_id] = self.fetch_function(function_id)
        return self._fn_cache[function_id]

    def _make_item(self, spec: TaskSpec) -> WorkItem:
        # requeued items after manager loss carry their resolved fn
        if spec.resolved is not None:
            fn, wants_env = spec.resolved
            payload = spec.payload
        else:
            fn, wants_env = self._resolve_fn(spec.function_id)
            payload = spec.payload
            if self.store is not None:
                if isinstance(payload, PackedBuffer):
                    # Pack-once plane: the payload stays an opaque frame
                    # unless it *can* contain DataRefs. Refs only survive
                    # serialization via pickle (nd/msgpack/json reject the
                    # dataclass), so the header method — no payload decode
                    # — decides whether stage-in must look inside.
                    if payload.method == "pickle":
                        payload = resolve_inputs(
                            payload.unpack(), self.endpoint_id,
                            self.store, self.transfer,
                            peer=self.peer_client)
                else:
                    payload = resolve_inputs(payload, self.endpoint_id,
                                             self.store, self.transfer,
                                             peer=self.peer_client)
        return WorkItem(
            task_id=spec.task_id,
            container_type=spec.container_type,
            fn=fn, wants_env=wants_env, payload=payload,
            stamps=dict(spec.stamps),
            warmth_key=spec.warmth_key)

    def _dispatch_loop(self) -> None:
        """Routes queued tasks to managers. Manager state (warm types, free
        room) is snapshotted once per iteration and updated locally while a
        whole batch of queued tasks is routed against it — amortizing the
        snapshot cost is what sustains >1k tasks/s per agent (§7.2.3)."""
        while not self._stop.is_set():
            with self._queue_cond:
                while not self._queue and not self._stop.is_set():
                    self._queue_cond.wait(timeout=0.1)
                if self._stop.is_set():
                    return
                batch = []
                while self._queue and len(batch) < 256:
                    batch.append(self._queue.popleft())

            managers = self._alive_managers()
            infos = [m.info() for m in managers]
            by_id = {m.manager_id: m for m in managers}
            # room derives from the same snapshot — Manager.room() would
            # re-scan every worker a second time per cycle, and this loop
            # is the serial feed stage (§7.2.3 hot path)
            room = {inf.manager_id:
                    max(inf.capacity + by_id[inf.manager_id].prefetch
                        - inf.queued, 0)
                    for inf in infos}
            per_manager: Dict[str, list] = {}
            leftovers = []
            for spec in batch:
                ctx = RoutingContext(warmth_key=spec.warmth_key or None,
                                     container_type=spec.container_type)
                target = self.router.route(ctx, infos)
                if target is None or room.get(target, 0) <= 0:
                    # the router's choice is saturated: requeue and retry
                    # against a fresh snapshot (never override the policy
                    # with first-fit — that would erase warm affinity)
                    leftovers.append(spec)
                    continue
                room[target] -= 1
                for inf in infos:          # keep the snapshot coherent
                    if inf.manager_id == target:
                        inf.queued += 1
                        view = inf.warmth
                        for key in ctx.warmth_keys:
                            if view.warm_idle(key) > 0:
                                view.note_pick(key)
                                break
                        inf.idle_workers = max(inf.idle_workers - 1, 0)
                        break
                try:
                    item = self._make_item(spec)
                except Exception as e:         # fn fetch / stage-in failure
                    self._send_failure(spec.task_id,
                                       f"staging: {type(e).__name__}: {e}")
                    continue
                self._dispatched_at[item.task_id] = (
                    time.perf_counter(), spec, target)
                per_manager.setdefault(target, []).append(item)
            for mid, items in per_manager.items():
                by_id[mid].submit_batch(items)
            if leftovers:
                # saturated: park the overflow and wait for a completion
                # (worker callbacks notify the cond) instead of polling —
                # a freed worker resumes dispatch immediately, an idle
                # wait costs nothing
                self._dispatch_parked = True
                with self._queue_cond:
                    for spec in reversed(leftovers):
                        self._queue.appendleft(spec)
                    self._queue_cond.wait(0.002)
                self._dispatch_parked = False

    def _on_result(self, manager_id: str, res: WorkResult) -> None:
        if not self._completed.add(res.task_id):
            return                 # duplicate (speculation / requeue) — drop
        self._retries.pop(res.task_id, None)
        disp = self._dispatched_at.pop(res.task_id, None)
        if disp is not None:
            self._durations.append(time.perf_counter() - disp[0])
            if res.cold_start and res.build_time > 0.0:
                spec = disp[1]
                self._observe_build(spec.warmth_key or spec.container_type,
                                    res.build_time)
        self.tasks_completed += 1
        # a worker just freed: wake the dispatch loop iff it parked
        # overflow waiting for room (plain flag read keeps the common
        # case lock-free — grabbing the queue lock on every completion
        # would contend with the dispatch loop itself)
        if self._dispatch_parked:
            with self._queue_cond:
                self._queue_cond.notify()
        result = res.result
        if res.status == "SUCCESS":
            # Pack the result exactly once (DESIGN.md §5). The same bytes
            # serve the stage-out size decision, the store write (if the
            # result is parked behind a DataRef), and the wire frame; the
            # service stores them opaquely and get_result decodes once.
            try:
                packed = pack_buffer(result, tag="ret")
            except Exception as e:
                # Unserializable result. A store with object semantics
                # (DeviceStore) can still park the *live* object behind a
                # DataRef — the pre-PR escape hatch for device-resident
                # results; otherwise the task fails with the real reason.
                staged = None
                if self.stage_results and self.store is not None:
                    try:
                        staged = stage_outputs(
                            result, self.endpoint_id, self.store,
                            key_prefix=f"task/{res.task_id}",
                            location=self._peer_location())
                    except Exception:
                        staged = None
                if staged is None or staged is result:
                    self._send_failure(
                        res.task_id,
                        f"result serialization: {type(e).__name__}: {e}")
                    return
                self._send_result(ResultMsg(
                    task_id=res.task_id, status=res.status,
                    result=pack_buffer(staged, tag="ret"),
                    error=res.error, remote_traceback=res.remote_traceback,
                    stamps=res.stamps, cold_start=res.cold_start,
                    build_time=res.build_time, worker_id=res.worker_id,
                    manager_id=manager_id))
                return
            if (self.stage_results and self.store is not None
                    and len(packed) > self.stage_limit):
                staged = stage_outputs(result, self.endpoint_id, self.store,
                                       key_prefix=f"task/{res.task_id}",
                                       packed=packed,
                                       limit=self.stage_limit,
                                       location=self._peer_location())
                packed = pack_buffer(staged, tag="ret")   # tiny DataRef
            result = packed
        self._send_result(ResultMsg(
            task_id=res.task_id, status=res.status, result=result,
            error=res.error, remote_traceback=res.remote_traceback,
            stamps=res.stamps, cold_start=res.cold_start,
            build_time=res.build_time, worker_id=res.worker_id,
            manager_id=manager_id))

    def _observe_build(self, key: str, seconds: float) -> None:
        """Cold-build feedback, both tiers (fixes the dead observe_build
        hook): the agent's own router learns immediately; the service's
        federation router learns from the EWMA advertised in the next
        heartbeat's ``build_costs``."""
        observe = getattr(self.router, "observe_build", None)
        if observe is not None:
            observe(key, seconds)
        with self._build_costs_lock:
            prev = self._build_costs.get(key)
            self._build_costs[key] = (seconds if prev is None
                                      else 0.8 * prev + 0.2 * seconds)

    def _peer_location(self) -> str:
        """Producer address hint stamped into outgoing DataRefs."""
        srv = self.peer_server
        return srv.address if srv is not None else ""

    def _send_failure(self, task_id: str, error: str,
                      status: str = "FAILED") -> None:
        self._completed.add(task_id)
        self._retries.pop(task_id, None)
        self._send_result(ResultMsg(
            task_id=task_id, status=status, error=error))

    def _send_result(self, msg: ResultMsg) -> None:
        """Hand one outcome to the result coalescer (DESIGN.md §6): it
        ships immediately on an idle line, rides a ResultBatch under
        load, and is parked for batch-wise retransmission if the link
        refuses (the service drops duplicates by task id, so a
        retransmit racing a requeued re-execution stays exactly-once)."""
        self.coalescer.add_result(msg)

    def _ship_envelope(self, env: dict, segments: list) -> bool:
        return self.channel.send_parts_to_service(env, segments,
                                                  tag="results")

    def _outstanding(self) -> int:
        """Results still expected imminently — the coalescer's linger
        gate. Lock-free advisory reads: both containers shrink to zero
        when the line goes idle, which is the only answer that matters."""
        return len(self._dispatched_at) + len(self._queue)

    def _heartbeat_loop(self) -> None:
        while not self._stop.is_set():
            self.coalescer.flush_unsent()
            self.channel.send_to_service(to_wire(self._heartbeat()), tag="hb")
            time.sleep(self.heartbeat_interval)

    def _heartbeat(self) -> Heartbeat:
        """Liveness + load/warm advertisement (consumed by the service's
        federation-level EndpointRouter). The merged dicts are rebuilt
        only when a manager's version stamp moved since the last beat."""
        managers = self._alive_managers()
        key = tuple((m.manager_id, m.version) for m in managers)
        if key != self._hb_key:
            views = []
            capacity = idle = queued = 0
            for m in managers:
                inf = m.info()
                capacity += inf.capacity
                idle += inf.idle_workers
                queued += inf.queued
                views.append(inf.warmth)
            merged = WarmthView.merge(views)
            self._hb_state = (capacity, idle, queued,
                              merged.idle, merged.total)
            self._hb_key = key
        capacity, idle, queued, warm_idle, warm_total = self._hb_state
        with self._queue_lock:
            queued += len(self._queue)
        # store inventory advertisement (peer plane): O(1) counter reads;
        # the version stamp lets the service invalidate peer grants for
        # producers whose store has mutated since the grant was minted
        sv = sk = sb = 0
        if self.store is not None:
            try:
                inv = self.store.inventory()
                sv, sk, sb = inv.version, inv.keys, inv.nbytes
            except Exception:
                pass
        with self._build_costs_lock:
            build_costs = dict(self._build_costs)
        return Heartbeat(endpoint_id=self.endpoint_id, ts=time.time(),
                         queued=queued, idle_workers=idle, capacity=capacity,
                         warm_idle=warm_idle, warm_total=warm_total,
                         build_costs=build_costs,
                         store_version=sv, store_keys=sk, store_bytes=sb)

    # -- fault tolerance: lost managers & stragglers --------------------------
    def _monitor_loop(self) -> None:
        while not self._stop.is_set():
            time.sleep(self.heartbeat_interval)
            self._check_lost_managers()
            if self.speculation:
                self._check_stragglers()
            if _monotonic() >= self._next_sweep:
                self._sweep_dispatched()
                self._next_sweep = _monotonic() + 5.0

    def _sweep_dispatched(self) -> None:
        """Evict stale ``_dispatched_at`` entries: tasks whose result
        already shipped (defensive — the happy path pops on completion)
        and tasks in flight longer than ``dispatched_ttl`` (a wedged
        worker would otherwise pin its entry — and the straggler
        detector's interest in it — forever)."""
        cutoff = time.perf_counter() - self.dispatched_ttl
        for task_id, (t0, _spec, _mid) in list(self._dispatched_at.items()):
            if task_id in self._completed or t0 < cutoff:
                self._dispatched_at.pop(task_id, None)

    def _check_lost_managers(self) -> None:
        cutoff = time.perf_counter() - self.manager_timeout
        with self._managers_lock:
            items = list(self.managers.items())
        for mid, m in items:
            if m.alive and m.last_heartbeat >= cutoff:
                continue
            if not m.alive or m.last_heartbeat < cutoff:
                # paper §4.3: lost tasks are re-executed (if permitted)
                lost = m.in_flight()
                with self._managers_lock:
                    self.managers.pop(mid, None)
                m.stop()
                for item in lost:
                    if item.task_id in self._completed:
                        continue
                    self._dispatched_at.pop(item.task_id, None)
                    retries = self._retries.get(item.task_id, 0) + 1
                    self._retries[item.task_id] = retries
                    if retries > self.max_retries:
                        self._send_failure(
                            item.task_id,
                            f"lost after {retries - 1} retries "
                            f"(manager {mid} failed)", status="LOST")
                    else:
                        self.tasks_reexecuted += 1
                        self._enqueue(TaskSpec(
                            task_id=item.task_id, function_id="",
                            container_type=item.container_type,
                            warmth_key=item.warmth_key,
                            payload=item.payload, stamps=item.stamps,
                            resolved=(item.fn, item.wants_env)), front=True)

    def _check_stragglers(self) -> None:
        if len(self._durations) < 4:
            return
        mean = sum(self._durations) / len(self._durations)
        threshold = max(self.speculation_min, self.speculation_factor * mean)
        now_s = time.perf_counter()
        for task_id, (t0, spec, mid) in list(self._dispatched_at.items()):
            if task_id in self._completed:
                continue
            if now_s - t0 > threshold:
                # speculative duplicate on a different manager
                others = [m for m in self._alive_managers()
                          if m.manager_id != mid and m.room() > 0]
                if not others:
                    continue
                try:
                    item = self._make_item(spec)
                except Exception:
                    continue
                others[0].submit_batch([item])
                self.speculative_dispatches += 1
                # push threshold forward so we don't spam duplicates
                self._dispatched_at[task_id] = (now_s, spec, mid)


# ---------------------------------------------------------------------------
# Federated deployment: the endpoint-agent entrypoint (TcpTransport side).
# ---------------------------------------------------------------------------

def demo_noop(data):
    """Module-level demo function: resolvable by reference from any
    process with ``repro`` on its path (plain pickle ships module-level
    functions by name — the cross-process analogue of funcX's serialized
    function bodies)."""
    return None


def demo_square(data):
    x = data["x"] if isinstance(data, dict) else data
    return x * x


def demo_sleep(data):
    time.sleep(float(data.get("s", 0.0)) if isinstance(data, dict) else 0.0)
    return None


def demo_produce(data):
    """Mint an ``n``-byte blob whose content encodes ``seed`` — returned
    whole so the agent's stage-out turns it into a DataRef whenever it
    exceeds the stage limit (peer-plane benchmarks & examples)."""
    n = int(data.get("n", 65536))
    seed = int(data.get("seed", 0))
    return bytes([seed % 251]) * n


def demo_gather(data):
    """Sum the sizes of ``parts`` — each element arrives as real bytes
    because stage-in resolved any DataRefs before execution."""
    return sum(len(p) for p in data["parts"])


def spawn_endpoint_process(address, token: str, *,
                           name: str = "remote-endpoint",
                           n_managers: int = 1, workers: int = 4,
                           shm: bool = True, peer: bool = True,
                           store_kind: str = "memory",
                           stage_limit: Optional[int] = None,
                           containers: str = "", stderr=None):
    """Spawn ``python -m repro.core.endpoint`` as a child process and block
    until it prints its readiness line. Returns ``(proc, endpoint_id)``.

    The one place the spawn recipe lives (benchmarks, tests, and examples
    all call it): PYTHONPATH gains this package's ``src`` root so the
    child resolves ``repro`` no matter the caller's cwd, and ``token`` may
    be the raw credential string or an ``@file`` reference.
    """
    import os
    import subprocess
    import sys
    import tempfile
    if not isinstance(address, str):
        address = f"{address[0]}:{address[1]}"
    src_dir = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    env = dict(os.environ)
    env["PYTHONPATH"] = src_dir + os.pathsep + env.get("PYTHONPATH", "")
    # stderr goes to an unbounded temp file, not a pipe: a chatty child
    # can never fill a pipe buffer and wedge, and the capture is still
    # readable when the readiness line never appears
    capture = tempfile.TemporaryFile("w+") if stderr is None else None
    argv = [sys.executable, "-m", "repro.core.endpoint",
            "--connect", address, "--token", token, "--name", name,
            "--managers", str(n_managers), "--workers", str(workers),
            "--store", store_kind]
    if stage_limit is not None:
        argv += ["--stage-limit", str(stage_limit)]
    if containers:
        argv += ["--containers", containers]
    if not shm:
        argv.append("--no-shm")
    if not peer:
        argv.append("--no-peer")
    proc = subprocess.Popen(
        argv,
        env=env, stdout=subprocess.PIPE,
        stderr=capture if capture is not None else stderr, text=True)
    line = (proc.stdout.readline() or "").strip()
    if not line.startswith("ENDPOINT_READY"):
        proc.terminate()
        err = ""
        if capture is not None:
            proc.wait(timeout=5)
            capture.seek(0)
            err = capture.read()
        raise RuntimeError(
            f"endpoint subprocess failed (got {line!r}): {err[-2000:]}")
    if capture is not None:
        capture.close()                # child keeps its own fd
    return proc, line.split()[1]


class WireFunctionClient:
    """Endpoint-side function fetch over the channel.

    ``fetch`` is the agent's ``fetch_function`` hook: it sends an
    ``FnRequest`` and blocks until the matching ``FnResponse`` arrives via
    :meth:`handle_response` (wired into the agent recv loop through
    ``extra_handler``). Requests are re-sent about once a second until
    answered, so a request lost to a link drop is recovered after the
    re-dial instead of hanging the fetch.
    """

    def __init__(self, channel: Channel, timeout: float = 15.0):
        self.channel = channel
        self.timeout = timeout
        self._lock = threading.Lock()
        self._pending: Dict[str, dict] = {}

    def fetch(self, function_id: str) -> Tuple[Callable, bool]:
        with self._lock:
            box = self._pending.get(function_id)
            if box is None:
                box = {"event": threading.Event(), "resp": None}
                self._pending[function_id] = box
        deadline = time.time() + self.timeout
        next_send = 0.0
        try:
            while not box["event"].is_set():
                now_t = time.time()
                if now_t >= deadline:
                    raise RegistrationError(
                        f"function fetch timed out: {function_id}")
                if now_t >= next_send:
                    ok = self.channel.send_to_service(
                        to_wire(FnRequest(function_id=function_id)),
                        tag="fn")
                    next_send = now_t + (1.0 if ok else 0.1)
                box["event"].wait(0.1)
        finally:
            with self._lock:
                self._pending.pop(function_id, None)
        resp: FnResponse = box["resp"]
        if resp.error:
            raise RegistrationError(
                f"service refused function {function_id}: {resp.error}")
        fn = pickle.loads(resp.payload)
        return fn, resp.wants_env

    def handle_response(self, resp: FnResponse) -> None:
        with self._lock:
            box = self._pending.get(resp.function_id)
        if box is not None:
            box["resp"] = resp
            box["event"].set()


class RemoteEndpointRunner:
    """Owns one federated endpoint: dial → register → run the agent.

    The TcpTransport re-dials on its own after any connection loss; this
    runner's ``on_connect`` hook re-sends ``Register`` with the already
    assigned endpoint id, and the service answers by swapping the new
    channel under the endpoint's line and requeueing its in-flight tasks —
    so a service listener restart costs retransmission, never task loss.
    """

    def __init__(self, address: "str | Tuple[str, int]", token: str, *,
                 name: str = "remote-endpoint", n_managers: int = 1,
                 workers_per_manager: int = 4, router: str = "warming_aware",
                 heartbeat_interval: float = 0.05,
                 register_timeout: float = 30.0,
                 shm: bool = True,
                 peer: bool = True,
                 peer_host: str = "127.0.0.1",
                 manager_kw: Optional[dict] = None, **agent_kw):
        self.address = (parse_hostport(address)
                        if isinstance(address, str) else address)
        self._token = token
        self.name = name
        self.n_managers = n_managers
        self.workers_per_manager = workers_per_manager
        self.router = router
        self.heartbeat_interval = heartbeat_interval
        self.register_timeout = register_timeout
        self.shm = shm                 # advertise shared-memory support
        self.shm_attached = False
        self.peer = peer               # run the peer data plane (DESIGN §9)
        self.peer_host = peer_host
        self.manager_kw = manager_kw or {}
        self.agent_kw = agent_kw
        self.endpoint_id: Optional[str] = None
        self.channel: Optional[Channel] = None
        self.transport: Optional[TcpTransport] = None
        self.agent: Optional[EndpointAgent] = None
        self.fns: Optional[WireFunctionClient] = None
        self.peer_server = None
        self.peer_client = None
        self.re_registrations = 0
        self.rejected = False          # re-registration refused by service

    # -- lifecycle ------------------------------------------------------------
    def start(self) -> str:
        """Dial, register, start managers/workers. Returns the endpoint id
        the service assigned (blocks up to ``register_timeout``).

        ``on_connect`` is installed *before* the first dial: until the
        handshake assigns an endpoint id it is a guarded no-op, and from
        then on every re-dial — even one racing agent/manager startup —
        re-registers under that id. Installing it after start-up would
        leave a window where a drop re-dials without re-registering and
        the endpoint wedges (the service would just keep discarding the
        unregistered connection's heartbeats)."""
        if self.peer:
            # the peer server must listen before Register so the handshake
            # can advertise its address; a store is mandatory for serving
            from ..data import InMemoryKVStore
            from .peer import PeerServer
            store = self.agent_kw.get("store")
            if store is None:
                store = InMemoryKVStore()
                self.agent_kw["store"] = store
            self.peer_server = PeerServer("", store, host=self.peer_host)
        self.transport = TcpTransport(connect=self.address,
                                      on_connect=self._re_register)
        self.channel = Channel(transport=self.transport)
        self.endpoint_id = self._handshake()
        self.fns = WireFunctionClient(self.channel)
        # The client side of the peer plane is always on: even with the
        # server disabled (``peer=False``: nothing to advertise, nothing
        # listening) a consumer still needs PeerClient.fetch_raw so
        # cross-endpoint refs resolve via the hub relay — that IS the
        # fallback lane the benchmarks compare against.
        from .peer import PeerClient
        self.peer_client = PeerClient(self.endpoint_id)
        self.agent = EndpointAgent(
            self.endpoint_id, self.channel, self.fns.fetch,
            router=self.router, heartbeat_interval=self.heartbeat_interval,
            extra_handler=self._handle_extra,
            peer_server=self.peer_server, peer_client=self.peer_client,
            **self.agent_kw)
        for _ in range(self.n_managers):
            self.agent.add_manager(n_workers=self.workers_per_manager,
                                   **self.manager_kw)
        self.agent.start()
        return self.endpoint_id

    def stop(self) -> None:
        if self.agent is not None:
            self.agent.stop()          # closes peer server/client too
        elif self.peer_server is not None:
            self.peer_server.close()   # handshake never completed
        if self.channel is not None:
            self.channel.close()

    # -- handshake ------------------------------------------------------------
    def _register_msg(self, endpoint_id: str = "") -> dict:
        peer_addr = (self.peer_server.address
                     if self.peer_server is not None else "")
        return to_wire(Register(name=self.name, token=self._token,
                                endpoint_id=endpoint_id,
                                host=_socket.gethostname(), shm=self.shm,
                                peer_addr=peer_addr))

    def _handshake(self) -> str:
        """First registration: the agent recv loop is not running yet, so
        the ack is read straight off the channel."""
        deadline = time.time() + self.register_timeout
        while time.time() < deadline:
            if not self.channel.send_to_service(self._register_msg(),
                                                tag="register"):
                time.sleep(0.05)       # still dialing (backoff in transport)
                continue
            wire = self.channel.recv_at_endpoint(timeout=2.0)
            if wire is None:
                continue               # resend; duplicates are ignored
            env, _tag = wire
            try:
                msg = from_wire(env)
            except (ProtocolError, SerializationError):
                continue
            if isinstance(msg, RegisterAck):
                if not msg.ok:
                    raise RegistrationError(
                        f"registration refused: {msg.error}")
                self.endpoint_id = msg.endpoint_id
                self._apply_peer_secret(msg)
                self._maybe_attach_shm(msg)
                return msg.endpoint_id
        raise RegistrationError(
            f"no RegisterAck from {self.address} "
            f"within {self.register_timeout}s")

    # -- shared-memory fast path (DESIGN.md §7) -------------------------------
    def _maybe_attach_shm(self, ack: RegisterAck) -> None:
        """The RegisterAck carried a ring-pair offer: attach both segments,
        confirm over TCP, then switch the channel onto the
        :class:`ShmTransport`. Any failure sends a decline (so the service
        unlinks the pending rings) and stays on plain TCP — graceful
        fallback, never a wedge."""
        offer = ack.shm
        if not offer or self.channel is None:
            return
        decline = None
        if not self.shm or self.shm_attached \
                or isinstance(self.channel.transport, ShmTransport):
            decline = "shm declined"
        else:
            try:
                tx = ShmRing.attach(offer["e2s"])     # endpoint writes e2s
            except Exception as e:
                decline = f"{type(e).__name__}: {e}"
            else:
                try:
                    rx = ShmRing.attach(offer["s2e"])  # ...and reads s2e
                except Exception as e:
                    tx.close()
                    decline = f"{type(e).__name__}: {e}"
        if decline is not None:
            self.channel.send_to_service(to_wire(ShmAttach(
                endpoint_id=self.endpoint_id or "", ok=False,
                ring=offer.get("s2e", ""), error=decline)), tag="shm")
            return
        # confirm over TCP *before* switching: the service installs its
        # side when the confirm arrives, and because doorbells ride the
        # same TCP stream, every pre-switch frame sorts before the first
        # ring frame on both sides
        if not self.channel.send_to_service(to_wire(ShmAttach(
                endpoint_id=self.endpoint_id or "", ok=True,
                ring=offer["s2e"])), tag="shm"):
            tx.close()
            rx.close()
            return
        self.channel.transport = ShmTransport(self.transport, tx=tx, rx=rx)
        self.shm_attached = True

    def _teardown_shm(self) -> None:
        """Drop back to the raw TCP transport (connection loss: the rings
        die with the link — the service unlinked them when it saw the
        drop; in-ring frames are recovered by requeue-on-disconnect)."""
        ch = self.channel
        tr = ch.transport if ch is not None else None
        if isinstance(tr, ShmTransport):
            ch.transport = self.transport
            tr.release_rings()
        self.shm_attached = False

    def _re_register(self) -> None:
        """TcpTransport.on_connect: runs on the reader thread right after
        a successful re-dial."""
        if self.channel is None or self.endpoint_id is None:
            return
        self.re_registrations += 1
        self._teardown_shm()           # rings died with the old connection
        self.channel.reconnect()
        self.channel.send_to_service(self._register_msg(self.endpoint_id),
                                     tag="register")

    def _apply_peer_secret(self, ack: RegisterAck) -> None:
        """Arm the PeerServer with the id + secret the service assigned —
        from here on it can validate peer-tokens offline. The secret is
        stable across re-attach, so outstanding consumer grants survive a
        re-registration."""
        if self.peer_server is None or not ack.peer_secret:
            return
        self.peer_server.endpoint_id = ack.endpoint_id
        try:
            self.peer_server.set_secret(bytes.fromhex(ack.peer_secret))
        except ValueError:
            pass

    def _handle_extra(self, msg: Any) -> None:
        if isinstance(msg, FnResponse) and self.fns is not None:
            self.fns.handle_response(msg)
        elif isinstance(msg, RegisterAck):
            if msg.ok:
                # ack for a re-registration: a fresh ring offer may ride
                # it, and the peer secret is re-delivered
                self._apply_peer_secret(msg)
                self._maybe_attach_shm(msg)
            else:
                # Re-registration refused (e.g. a fully restarted service
                # no longer knows this endpoint id). Tasks already queued
                # keep executing; the flag tells operators a fresh `start`
                # (new registration, new id) is needed.
                self.rejected = True


def main(argv: Optional[List[str]] = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.core.endpoint",
        description="Federated endpoint agent: connect to a FuncXService "
                    "TCP listener, register, and serve tasks with local "
                    "managers/workers (paper §4.3 deployed for real).")
    p.add_argument("--connect", required=True, metavar="HOST:PORT",
                   help="address of the service listener "
                        "(FuncXService.listen())")
    p.add_argument("--token", default="",
                   help="bearer token: Token.encode() JSON, or @FILE to "
                        "read it from a file")
    p.add_argument("--name", default="remote-endpoint")
    p.add_argument("--managers", type=int, default=1)
    p.add_argument("--workers", type=int, default=4,
                   help="workers per manager")
    p.add_argument("--router", default="warming_aware")
    p.add_argument("--heartbeat", type=float, default=0.05,
                   help="heartbeat interval, seconds")
    p.add_argument("--no-shm", action="store_true",
                   help="stay on TCP even when the service offers a "
                        "same-host shared-memory ring")
    p.add_argument("--no-peer", action="store_true",
                   help="disable the peer data plane: cross-endpoint "
                        "DataRefs resolve via the hub relay only")
    p.add_argument("--store", default="memory",
                   choices=["memory", "sharedfs", "device"],
                   help="local store kind (sharedfs uses a temp dir)")
    p.add_argument("--stage-limit", type=int, default=SERVICE_PAYLOAD_LIMIT,
                   help="stage-out threshold in bytes: results packing "
                        "larger than this become DataRefs into the local "
                        "store (default: the 10 MB service limit)")
    p.add_argument("--containers", default="", metavar="MODULE:FUNC",
                   help="container-spec installer: import MODULE and call "
                        "FUNC(registry) before serving — how subprocess "
                        "endpoints learn real ContainerSpecs (e.g. "
                        "repro.serve.fabric:install for the jit model zoo)")
    args = p.parse_args(argv)
    token = args.token
    if token.startswith("@"):
        with open(token[1:]) as f:
            token = f.read().strip()
    from ..data import make_store
    if args.store == "sharedfs":
        import tempfile
        store = make_store("sharedfs", root=tempfile.mkdtemp(
            prefix="repro-ep-store-"))
    else:
        store = make_store(args.store)
    registry = None
    if args.containers:
        import importlib
        mod_name, _, fn_name = args.containers.partition(":")
        installer = getattr(importlib.import_module(mod_name), fn_name)
        registry = ContainerRegistry()
        installer(registry)
    runner = RemoteEndpointRunner(
        args.connect, token, name=args.name, n_managers=args.managers,
        workers_per_manager=args.workers, router=args.router,
        heartbeat_interval=args.heartbeat, shm=not args.no_shm,
        peer=not args.no_peer, store=store, stage_limit=args.stage_limit,
        registry=registry)
    eid = runner.start()
    # parseable readiness line — parents wait on this before submitting
    # (field 2 is the endpoint id; the shm/peer markers tell benches which
    # planes actually engaged)
    peer_addr = (runner.peer_server.address
                 if runner.peer_server is not None else "0")
    print(f"ENDPOINT_READY {eid} shm={1 if runner.shm_attached else 0} "
          f"peer={peer_addr}", flush=True)
    try:
        while True:
            time.sleep(0.5)
    except KeyboardInterrupt:
        pass
    finally:
        runner.stop()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
