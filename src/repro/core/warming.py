"""Container management (paper §6) — TPU adaptation.

A funcX *container type* maps to a **compile signature** and a warm
container to a **cached compiled executable** (DESIGN.md §2): the expensive,
type-specific artifact a worker must construct before serving a function is
the XLA build, with the same cost profile as Table 3's 8–10 s HPC container
cold starts.

``ContainerSpec.build()`` performs the cold start (a real ``jax.jit``
compile for model functions; a configurable delay for benchmark containers).
``WarmCache`` implements the paper's warming policies: keep-warm with idle
timeout (§6.1), LRU under bounded slots, and the extensibility hook for
other strategies.
"""
from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Tuple


@dataclass
class ContainerSpec:
    container_type: str
    build: Callable[[], Any] = lambda: None
    teardown: Callable[[Any], None] = lambda env: None
    # benchmark containers: emulate instantiation cost (Table 3) without JIT
    simulated_cold_start: float = 0.0


@dataclass
class Container:
    spec: ContainerSpec
    env: Any
    built_at: float
    build_time: float
    last_used: float
    uses: int = 0

    @property
    def container_type(self) -> str:
        return self.spec.container_type


class ContainerRegistry:
    """Service/endpoint-level registry of container specs (image registry).

    Beyond enumerated specs, a *spec factory* can claim a key prefix
    (``register_factory("jit/", fn)``): on a registry miss the factory
    mints the spec for that concrete type on first demand. This is how
    the serving fabric (DESIGN.md §10) exposes the whole model zoo —
    every ``jit/<arch>/<step>/<bucket>`` combination — without
    enumerating the cross product up front."""

    def __init__(self):
        self._specs: Dict[str, ContainerSpec] = {}
        self._factories: List[Tuple[str, Callable[[str], ContainerSpec]]] = []
        self._lock = threading.RLock()

    def register(self, spec: ContainerSpec) -> None:
        with self._lock:
            self._specs[spec.container_type] = spec

    def register_factory(self, prefix: str,
                         factory: Callable[[str], ContainerSpec]) -> None:
        """``factory(container_type) -> ContainerSpec`` for any type
        starting with ``prefix``. Later registrations win (prepended)."""
        with self._lock:
            self._factories.insert(0, (prefix, factory))

    def get(self, container_type: str) -> ContainerSpec:
        with self._lock:
            spec = self._specs.get(container_type)
            if spec is not None:
                return spec
            factories = list(self._factories)
        for prefix, factory in factories:
            if container_type.startswith(prefix):
                spec = factory(container_type)
                if spec is not None:
                    self.register(spec)
                    return spec
        with self._lock:
            if container_type not in self._specs:
                # bare python environment — no build cost
                self._specs[container_type] = ContainerSpec(container_type)
            return self._specs[container_type]

    def types(self) -> List[str]:
        with self._lock:
            return list(self._specs)


@dataclass
class WarmStats:
    cold_starts: int = 0
    warm_hits: int = 0
    evictions: int = 0
    build_time: float = 0.0


class WarmCache:
    """Per-worker warm-container cache.

    policy:
      - "idle_timeout": keep warm until idle > ``idle_timeout`` (paper §6.1,
        default 10 min there; seconds here), reaped by ``reap()``.
      - "lru": bounded ``slots``; evict least-recently-used on pressure.
    """

    def __init__(self, registry: ContainerRegistry, slots: int = 1,
                 idle_timeout: Optional[float] = None, policy: str = "lru"):
        self.registry = registry
        self.slots = slots
        self.idle_timeout = idle_timeout
        self.policy = policy
        self._warm: Dict[str, Container] = {}
        self._noted: Dict[str, float] = {}   # warmth keys sans container
        self._lock = threading.RLock()
        self.stats = WarmStats()
        # warm-set membership change hook (Manager's incremental info())
        self.on_change: Optional[Callable[[], None]] = None

    def _notify(self) -> None:
        cb = self.on_change
        if cb is not None:
            cb()

    # -- queries -------------------------------------------------------------
    def warm_types(self) -> List[str]:
        """Every warmth key this worker is warm for: built containers
        plus noted keys (function-held artifacts, see note_warm)."""
        with self._lock:
            if not self._noted:
                return list(self._warm)
            out = list(self._warm)
            out.extend(k for k in self._noted if k not in self._warm)
            return out

    def is_warm(self, container_type: str) -> bool:
        with self._lock:
            return (container_type in self._warm
                    or container_type in self._noted)

    # -- warmth without a container -------------------------------------------
    def note_warm(self, key: str) -> None:
        """Advertise warmth for an artifact this worker holds *outside*
        the container cache — e.g. a function-managed jit cache keyed by
        a task's warmth_key (DESIGN.md §10). Noted keys ride
        ``warm_types()`` into the same heartbeat dicts as containers;
        they occupy no slot and are bounded LRU-style on their own."""
        with self._lock:
            self._noted.pop(key, None)
            self._noted[key] = time.perf_counter()
            while len(self._noted) > max(self.slots * 4, 8):
                self._noted.pop(next(iter(self._noted)))
        self._notify()

    # -- acquire -------------------------------------------------------------
    def get_or_build(self, container_type: str) -> Tuple[Container, bool]:
        """Returns (container, cold_start?)."""
        with self._lock:
            c = self._warm.get(container_type)
            if c is not None:
                c.last_used = time.perf_counter()
                c.uses += 1
                self.stats.warm_hits += 1
                return c, False
        # cold start — build outside the lock (it can take seconds)
        spec = self.registry.get(container_type)
        t0 = time.perf_counter()
        if spec.simulated_cold_start:
            time.sleep(spec.simulated_cold_start)
        env = spec.build()
        build_time = time.perf_counter() - t0
        c = Container(spec, env, t0, build_time, time.perf_counter(), 1)
        with self._lock:
            while len(self._warm) >= self.slots:
                self._evict_one()
            self._warm[container_type] = c
            self.stats.cold_starts += 1
            self.stats.build_time += build_time
        self._notify()
        return c, True

    def _evict_one(self) -> None:
        if not self._warm:
            return
        victim_key = min(self._warm, key=lambda k: self._warm[k].last_used)
        victim = self._warm.pop(victim_key)
        try:
            victim.spec.teardown(victim.env)
        except Exception:
            pass
        self.stats.evictions += 1

    def reap(self) -> int:
        """Release containers idle past the timeout (paper §6.1). Returns
        the number reaped."""
        if self.idle_timeout is None:
            return 0
        cutoff = time.perf_counter() - self.idle_timeout
        n = 0
        with self._lock:
            for key in list(self._warm):
                if self._warm[key].last_used < cutoff:
                    victim = self._warm.pop(key)
                    try:
                        victim.spec.teardown(victim.env)
                    except Exception:
                        pass
                    self.stats.evictions += 1
                    n += 1
        if n:
            self._notify()
        return n

    def next_reap_deadline(self) -> Optional[float]:
        """``time.perf_counter()`` moment the oldest-idle warm container
        becomes reapable, or None when there is nothing to reap. Lets the
        worker loop block until a deadline instead of polling ``reap()``
        on every idle wakeup."""
        if self.idle_timeout is None:
            return None
        with self._lock:
            if not self._warm:
                return None
            oldest = min(c.last_used for c in self._warm.values())
        return oldest + self.idle_timeout

    def drop(self, container_type: str) -> None:
        with self._lock:
            c = self._warm.pop(container_type, None)
        if c is not None:
            self._notify()


def proportional_allocation(task_mix: Dict[str, int],
                            n_slots: int) -> Dict[str, int]:
    """Paper §6.2: 'the number of deployed containers for a function type is
    proportional to the number of received tasks of this type' (e.g. 30% of
    tasks of type A and 10 containers → 3 of type A). Largest-remainder
    rounding; every present type gets ≥ 1 slot while slots remain."""
    total = sum(task_mix.values())
    if total == 0 or n_slots == 0:
        return {}
    raw = {t: n_slots * c / total for t, c in task_mix.items()}
    alloc = {t: int(v) for t, v in raw.items()}
    # guarantee presence
    for t in sorted(raw, key=lambda t: raw[t] - alloc[t], reverse=True):
        if sum(alloc.values()) >= n_slots:
            break
        if alloc[t] == 0:
            alloc[t] = 1
    # largest remainders
    while sum(alloc.values()) < n_slots:
        t = max(raw, key=lambda t: raw[t] - alloc[t])
        alloc[t] += 1
        raw[t] = alloc[t]  # stop re-picking the same type forever
    return {t: v for t, v in alloc.items() if v > 0}
