"""The cloud-hosted funcX service (paper §4.1).

Maintains the registries (users, functions, endpoints, containers), the
task store and per-endpoint queues + forwarders, enforces auth scopes and
the 10 MB payload limit, exposes the REST-shaped API (register / submit /
status / result), runs health checks that restart dead forwarders, and
purges results after retrieval.
"""
from __future__ import annotations

import pickle
import inspect
import threading
import time
import uuid
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from ..data import (
    InMemoryKVStore,
    KVStore,
    TransferService,
)
from ..serialization import pack
from .auth import (
    ALL_SCOPES,
    AuthService,
    SCOPE_ENDPOINT,
    SCOPE_REGISTER_FUNCTION,
    SCOPE_RUN,
    Token,
)
from .comms import Channel
from .endpoint import EndpointAgent
from .errors import (
    AuthError,
    EndpointUnavailable,
    PayloadTooLarge,
    RegistrationError,
    TaskFailure,
    TaskLost,
)
from .forwarder import Forwarder
from .tasks import Task, TaskStatus, TaskStore
from .warming import ContainerRegistry, ContainerSpec

PAYLOAD_LIMIT = 10 * 1024 * 1024          # paper §5.1


@dataclass
class RegisteredFunction:
    function_id: str
    name: str
    fn: Callable
    wants_env: bool
    container_type: str
    owner: str
    allowed: Optional[frozenset]          # None → owner only; set → shared
    description: str = ""

    def authorized(self, identity: str) -> bool:
        if identity == self.owner:
            return True
        return self.allowed is not None and (
            "*" in self.allowed or identity in self.allowed)


@dataclass
class EndpointRecord:
    endpoint_id: str
    name: str
    owner: str
    channel: Channel
    forwarder: Forwarder
    created: float = field(default_factory=time.time)

    @property
    def connected(self) -> bool:
        return self.forwarder.endpoint_connected


class FuncXService:
    def __init__(self, *, heartbeat_timeout: float = 0.5,
                 payload_limit: int = PAYLOAD_LIMIT,
                 purge_on_get: bool = True,
                 forwarder_batch: int = 32,
                 health_interval: float = 0.25):
        self.auth = AuthService()
        self.tasks = TaskStore()
        self.containers = ContainerRegistry()
        self.transfer = TransferService()
        self.functions: Dict[str, RegisteredFunction] = {}
        self.endpoints: Dict[str, EndpointRecord] = {}
        self._lock = threading.RLock()
        self.heartbeat_timeout = heartbeat_timeout
        self.payload_limit = payload_limit
        self.purge_on_get = purge_on_get
        self.forwarder_batch = forwarder_batch
        self._stop = threading.Event()
        self._health = threading.Thread(target=self._health_loop,
                                        daemon=True, name="svc-health")
        self._health_interval = health_interval
        self._health.start()
        # metrics
        self.submitted = 0
        self.forwarder_restarts = 0

    def shutdown(self) -> None:
        self._stop.set()
        with self._lock:
            for rec in self.endpoints.values():
                rec.forwarder.stop()
                rec.channel.close()

    # ------------------------------------------------------------------- users
    def register_user(self, name: str,
                      scopes: Sequence[str] = tuple(ALL_SCOPES)) -> Token:
        self.auth.register_identity(name)
        return self.auth.issue(name, scopes)

    # --------------------------------------------------------------- functions
    def register_function(self, token: Token, fn: Callable, *,
                          name: Optional[str] = None,
                          container_type: str = "python",
                          allowed: Optional[Sequence[str]] = None,
                          description: str = "") -> str:
        owner = self.auth.validate(token, SCOPE_REGISTER_FUNCTION)
        params = list(inspect.signature(fn).parameters)
        wants_env = len(params) >= 2
        fid = str(uuid.uuid4())
        rf = RegisteredFunction(
            function_id=fid, name=name or fn.__name__, fn=fn,
            wants_env=wants_env, container_type=container_type, owner=owner,
            allowed=frozenset(allowed) if allowed is not None else None,
            description=description)
        with self._lock:
            self.functions[fid] = rf
        return fid

    def update_function(self, token: Token, function_id: str,
                        fn: Callable) -> None:
        identity = self.auth.validate(token, SCOPE_REGISTER_FUNCTION)
        with self._lock:
            rf = self.functions[function_id]
            if rf.owner != identity:
                raise AuthError("only the owner may update a function")
            rf.fn = fn
            rf.wants_env = len(inspect.signature(fn).parameters) >= 2

    def export_function(self, function_id: str) -> Tuple[Callable, bool]:
        """Endpoint-side fetch+cache hook. funcX ships dill-serialized
        bodies; module-level functions round-trip through pickle here, and
        closures (e.g. jitted model steps) pass by reference — same-process
        deployment (see DESIGN.md §2)."""
        with self._lock:
            rf = self.functions[function_id]
        try:
            fn = pickle.loads(pickle.dumps(rf.fn))
        except Exception:
            fn = rf.fn
        return fn, rf.wants_env

    # --------------------------------------------------------------- containers
    def register_container(self, spec: ContainerSpec) -> None:
        self.containers.register(spec)

    # ---------------------------------------------------------------- endpoints
    def register_endpoint(self, token: Token, name: str, *,
                          channel: Optional[Channel] = None
                          ) -> Tuple[str, Channel]:
        owner = self.auth.validate(token, SCOPE_ENDPOINT)
        eid = str(uuid.uuid4())
        channel = channel or Channel()
        fwd = Forwarder(eid, self.tasks, channel,
                        batch_size=self.forwarder_batch,
                        heartbeat_timeout=self.heartbeat_timeout)
        fwd.start()
        rec = EndpointRecord(eid, name, owner, channel, fwd)
        with self._lock:
            self.endpoints[eid] = rec
        return eid, channel

    def make_endpoint(self, token: Token, name: str, *,
                      n_managers: int = 1, workers_per_manager: int = 4,
                      store: Optional[KVStore] = None,
                      router: str = "warming_aware",
                      manager_kw: Optional[dict] = None,
                      **agent_kw) -> Tuple[str, EndpointAgent]:
        """Convenience: register + construct + start a wired EndpointAgent
        (what `funcx-endpoint start` does on a resource)."""
        eid, channel = self.register_endpoint(token, name)
        store = store if store is not None else InMemoryKVStore()
        self.transfer.register_endpoint(eid, store)
        agent = EndpointAgent(
            eid, channel, self.export_function,
            registry=self.containers, router=router, store=store,
            transfer=self.transfer,
            heartbeat_interval=self.heartbeat_timeout / 5, **agent_kw)
        for _ in range(n_managers):
            agent.add_manager(n_workers=workers_per_manager,
                              **(manager_kw or {}))
        agent.start()
        return eid, agent

    # -------------------------------------------------------------- discovery
    # (the paper's §10 future work: "APIs that allow users to manage and
    # discover functions and endpoints")
    def search_functions(self, token: Token, pattern: str = "") -> List[dict]:
        identity = self.auth.validate(token, SCOPE_RUN)
        out = []
        with self._lock:
            fns = list(self.functions.values())
        for rf in fns:
            if pattern.lower() in rf.name.lower() and rf.authorized(identity):
                out.append({"function_id": rf.function_id, "name": rf.name,
                            "container_type": rf.container_type,
                            "owner": rf.owner,
                            "description": rf.description})
        return out

    def list_endpoints(self, token: Token) -> List[dict]:
        self.auth.validate(token, SCOPE_RUN)
        with self._lock:
            recs = list(self.endpoints.values())
        return [{"endpoint_id": r.endpoint_id, "name": r.name,
                 "owner": r.owner, "connected": r.connected,
                 "queued": r.forwarder.queue_len(),
                 "in_flight": r.forwarder.in_flight_count()}
                for r in recs]

    # ------------------------------------------------------------------- submit
    def submit(self, token: Token, function_id: str, endpoint_id: str,
               payload: Any = None, *,
               container_type: Optional[str] = None) -> str:
        identity = self.auth.validate(token, SCOPE_RUN)
        with self._lock:
            rf = self.functions.get(function_id)
            rec = self.endpoints.get(endpoint_id)
        if rf is None:
            raise RegistrationError(f"unknown function {function_id}")
        if rec is None:
            raise EndpointUnavailable(f"unknown endpoint {endpoint_id}")
        if not rf.authorized(identity):
            raise AuthError(
                f"{identity} is not authorized to run {rf.name}")
        size = len(pack(payload))
        if size > self.payload_limit:
            raise PayloadTooLarge(
                f"payload {size}B > {self.payload_limit}B; stage via "
                f"DataRef + TransferService (paper §5.1)")
        task = Task(function_id=function_id, endpoint_id=endpoint_id,
                    payload=payload,
                    container_type=container_type or rf.container_type)
        task.stamp("submit")
        self.tasks.put(task)
        rec.forwarder.enqueue(task.task_id)
        task.stamp("service_queued")
        self.submitted += 1
        return task.task_id

    def submit_batch(self, token: Token,
                     requests: Sequence[Tuple[str, str, Any]]) -> List[str]:
        """User-facing batching (§4.6): one call, many tasks."""
        return [self.submit(token, fid, eid, payload)
                for fid, eid, payload in requests]

    # ------------------------------------------------------------------ results
    def status(self, task_id: str) -> TaskStatus:
        return self.tasks.get(task_id).status

    def get_task(self, task_id: str) -> Task:
        return self.tasks.get(task_id)

    def get_result(self, task_id: str, timeout: float = 30.0) -> Any:
        if not self.tasks.wait(task_id, timeout):
            raise TimeoutError(f"task {task_id} not done in {timeout}s")
        task = self.tasks.get(task_id)
        try:
            if task.status == TaskStatus.SUCCESS:
                return task.result
            if task.status == TaskStatus.LOST:
                raise TaskLost(task.error or "task lost")
            raise TaskFailure(task.error or "task failed",
                              task.remote_traceback)
        finally:
            if self.purge_on_get:
                self.tasks.purge(task_id)

    def get_batch_results(self, task_ids: Sequence[str],
                          timeout: float = 30.0) -> List[Any]:
        deadline = time.time() + timeout
        return [self.get_result(tid, max(deadline - time.time(), 0.001))
                for tid in task_ids]

    # ------------------------------------------------------------------- health
    def _health_loop(self) -> None:
        """Service self-healing (paper §4.1: liveness checks + automatic
        restart)."""
        while not self._stop.is_set():
            time.sleep(self._health_interval)
            with self._lock:
                recs = list(self.endpoints.values())
            for rec in recs:
                if not rec.forwarder.healthy and not self._stop.is_set():
                    old = rec.forwarder
                    old.stop()
                    fwd = Forwarder(rec.endpoint_id, self.tasks, rec.channel,
                                    batch_size=self.forwarder_batch,
                                    heartbeat_timeout=self.heartbeat_timeout)
                    # carry over the queue
                    fwd.queue.extend(old.queue)
                    fwd.start()
                    rec.forwarder = fwd
                    self.forwarder_restarts += 1
